#!/usr/bin/env python
"""Docs-consistency gate: every fenced ``json`` block in the user-facing
docs must parse as a strict RunSpec (DESIGN.md §13).

The docs promise that their examples are runnable; this script makes the
promise load-bearing.  It extracts every ```json fenced block from the
files below, feeds each through ``RunSpec.from_dict`` (the same strict
parser ``repro run`` uses — unknown keys, bad enums, and conflicting
sections all raise), and fails with file/line context on the first
non-conforming block.

Import-light on purpose: ``repro.api.spec`` pulls in no jax, so this
runs anywhere in under a second.

Usage::

    PYTHONPATH=src python tools/check_doc_specs.py [files...]

With no arguments it checks the default doc set (README.md and
docs/runspec.md, relative to the repo root).
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_DOCS = ("README.md", "docs/runspec.md", "docs/observability.md")

_FENCE_RE = re.compile(
    r"^```json[ \t]*\n(.*?)^```[ \t]*$", re.MULTILINE | re.DOTALL
)


def iter_json_blocks(text: str):
    """Yield ``(line_number, block_text)`` for every ```json fence."""
    for m in _FENCE_RE.finditer(text):
        line = text.count("\n", 0, m.start()) + 1
        yield line, m.group(1)


def _rel(path: pathlib.Path) -> str:
    try:
        return str(path.relative_to(REPO_ROOT))
    except ValueError:
        return str(path)


def check_file(path: pathlib.Path) -> list[str]:
    from repro.api.spec import RunSpec, SpecError

    errors = []
    text = path.read_text()
    n_blocks = 0
    for line, block in iter_json_blocks(text):
        n_blocks += 1
        where = f"{_rel(path)}:{line}"
        try:
            payload = json.loads(block)
        except json.JSONDecodeError as e:
            errors.append(f"{where}: not valid JSON: {e}")
            continue
        try:
            spec = RunSpec.from_dict(payload)
        except SpecError as e:
            errors.append(f"{where}: not a valid RunSpec: {e}")
            continue
        # the round-trip guarantee the spec layer advertises
        round_tripped = RunSpec.from_dict(
            json.loads(json.dumps(spec.to_dict()))
        )
        if round_tripped != spec:
            errors.append(f"{where}: spec does not round-trip losslessly")
    print(f"{_rel(path)}: {n_blocks} spec block(s)")
    return errors


def main(argv: list[str]) -> int:
    paths = [pathlib.Path(a) for a in argv] or [
        REPO_ROOT / d for d in DEFAULT_DOCS
    ]
    missing = [p for p in paths if not p.is_file()]
    if missing:
        for p in missing:
            print(f"missing doc file: {p}", file=sys.stderr)
        return 2
    errors = []
    for p in paths:
        errors.extend(check_file(p))
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if errors:
        return 1
    print("all doc spec blocks parse as strict RunSpecs")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, str(REPO_ROOT / "src"))
    raise SystemExit(main(sys.argv[1:]))
