"""Distributed LP: shard_map engine over a device mesh.

2-D decomposition (DESIGN.md §6):

* seed columns sharded over the ``data`` axis — columns are independent
  propagations, so this axis needs NO communication (the Giraph analogue is
  running disjoint seed sweeps on disjoint workers, which the paper cannot
  do because its sweep is sequential);
* edges sharded over the ``model`` axis — each shard owns E/k edges,
  computes a partial aggregate for ALL nodes, and a ``psum`` over the edge
  axis completes the superstep (the Giraph analogue is workers exchanging
  messages at the superstep barrier).

Per-device state: F_local (N, s/data). Per-iteration wire traffic:
one psum of (N, s/data) over the ``model`` axis — this is THE collective
the roofline analysis tracks for the LP core.

Straggler mitigation (beyond-paper): ``stale_sync=k`` refreshes the remote
contribution every k rounds only — between refreshes a shard iterates with
its own edges live and others' aggregates stale.  For a contraction mapping
this still converges (the stale operator is a perturbed contraction), and it
cuts the collective term by ~k×; the tests assert fixed-point agreement.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.hints import shard_map_compat

from repro.core.blocked_csr import (
    blocked_csr_from_network,
    split_blocked_csr_from_network,
)
from repro.core.network import NormalizedNetwork
from repro.core.solver import LPConfig, SolveResult
from repro.graph.segment import segment_sum
from repro.parallel.collectives import compressed_psum


@dataclasses.dataclass
class ShardedLPArrays:
    """Host-side prepared arrays: edge shards stacked on a leading axis."""

    src: np.ndarray   # (k, Ep) int32 — fused operator edges
    dst: np.ndarray   # (k, Ep) int32
    w: np.ndarray     # (k, Ep) float32 (pre-scaled: αβ·scale·het ∪ α·hom)
    num_nodes: int
    beta2: float


def _shard_edges(src, dst, w, num_edge_shards: int):
    """Slice a destination-sorted edge triple into k equal shards.

    Inputs come from ``BlockedCSR.to_edges(include_pads=False)``: slots
    are row-major (dst non-decreasing) with the zero-weight tile padding
    already dropped, so equal slices are destination-contiguous — each
    shard's segment-sum output band stays localized, same property the
    COO prep sorted for — and a segment-sum never touches pad slots
    (which on skewed graphs would multiply per-superstep work).
    """
    e = src.shape[0]
    per = max(1, -(-e // num_edge_shards))
    pad = per * num_edge_shards - e
    src = np.concatenate([src, np.zeros(pad, np.int32)])
    dst = np.concatenate([dst, np.zeros(pad, np.int32)])
    w = np.concatenate([w.astype(np.float32), np.zeros(pad, np.float32)])
    return (
        src.reshape(num_edge_shards, per),
        dst.reshape(num_edge_shards, per),
        w.reshape(num_edge_shards, per),
    )


def prepare_sharded_operator(
    norm: NormalizedNetwork, cfg: LPConfig, num_edge_shards: int
) -> ShardedLPArrays:
    """Fused DHLP-2 operator as edge shards, derived from blocked-CSR.

    The blocked-CSR operator (DESIGN.md §11) is the shared format: shards
    are flat slices of its row-major slot storage, so the sharded engine
    consumes exactly the operator the sparse/kernel engines aggregate.
    """
    scale = cfg.resolved_hetero_scale(norm.num_types)
    beta = 1.0 - cfg.alpha
    bcsr = blocked_csr_from_network(
        norm, alpha=cfg.alpha, hetero_scale=scale
    )
    src, dst, w = bcsr.to_edges(include_pads=False)
    src, dst, w = _shard_edges(src, dst, w, num_edge_shards)
    return ShardedLPArrays(
        src=src,
        dst=dst,
        w=w,
        num_nodes=norm.num_nodes,
        beta2=beta * beta,
    )


def build_sharded_dhlp2(
    mesh: Mesh,
    *,
    num_nodes: int,
    beta2: float,
    sigma: float,
    max_iter: int,
    seed_mode: str,
    edge_axis: str = "model",
    seed_axis: str = "data",
    stale_sync: int = 1,
    compression: str = "none",
):
    """Returns a jit-compiled sharded DHLP-2 solver fn(src, dst, w, Y, F0).

    Input shardings: edge arrays P(edge_axis, None); Y and the warm-start
    state F0 P(None, seed_axis) (pass Y as F0 for a cold solve).
    Output: F with P(None, seed_axis), iteration count (replicated).
    """

    def shard_body(src, dst, w, Y, F0):
        # src/dst/w: (1, Ep) local edge shard; Y/F0: (N, s_local)
        src, dst, w = src[0], dst[0], w[0]
        Y = Y.astype(jnp.float32)
        F0 = F0.astype(jnp.float32)

        def local_agg(F):
            msgs = w[:, None] * F[src]
            return segment_sum(msgs, dst, num_nodes)

        # The loop predicate must be uniform across EVERY device in the
        # mesh: collectives inside a while body deadlock if participants
        # disagree on the trip count (seed shards converge at different
        # rounds, and the mesh's device assignment may place them in the
        # same collective clique).  We carry a globally-reduced
        # "anyone still active" scalar — a 4-byte pmax per round.
        def cond(state):
            _, _, it, _, _, global_active = state
            return jnp.logical_and(it < max_iter, global_active > 0)

        def body(state):
            F, active, it, col_iters, remote, _ = state
            base = Y if seed_mode == "fixed" else F
            local = local_agg(F)
            if stale_sync <= 1:
                agg = compressed_psum(
                    local, edge_axis, compression=compression
                )
                remote_n = agg - local  # kept for state-shape stability
            else:
                # staleness switch must also be trip-uniform: it is a pure
                # function of `it`, which is uniform by construction.
                do_sync = (it % stale_sync) == 0
                fresh = lax.cond(
                    do_sync,
                    lambda l: compressed_psum(
                        l, edge_axis, compression=compression
                    ) - l,
                    lambda l: remote,
                    local,
                )
                remote_n = fresh
                agg = local + fresh
            Fn = beta2 * base + agg
            Fn = jnp.where(active[None, :], Fn, F)
            delta = jnp.max(jnp.abs(Fn - F), axis=0)
            still = jnp.logical_and(active, ~(delta < sigma))
            col_iters = col_iters + active.astype(jnp.int32)
            ga = lax.pmax(
                jnp.any(still).astype(jnp.int32), (seed_axis, edge_axis)
            )
            return Fn, still, it + 1, col_iters, remote_n, ga

        s = Y.shape[1]
        state0 = (
            F0,
            jnp.ones((s,), dtype=bool),
            jnp.asarray(0, jnp.int32),
            jnp.zeros((s,), jnp.int32),
            jnp.zeros((num_nodes, s), jnp.float32),
            jnp.asarray(1, jnp.int32),
        )
        F, _, iters, col_iters, _, _ = lax.while_loop(cond, body, state0)
        # iteration counts differ across seed shards; report local columns'.
        return F, jnp.reshape(iters, (1,)), col_iters

    mapped = shard_map_compat(
        shard_body,
        mesh=mesh,
        in_specs=(
            P(edge_axis, None),
            P(edge_axis, None),
            P(edge_axis, None),
            P(None, seed_axis),
            P(None, seed_axis),
        ),
        out_specs=(P(None, seed_axis), P(seed_axis), P(seed_axis)),
        check=False,
    )
    return jax.jit(mapped)


def build_sharded_round(
    mesh: Mesh,
    *,
    num_nodes: int,
    beta2: float,
    edge_axis: str = "model",
    seed_axis: str = "data",
    compression: str = "none",
):
    """One fused fixed-seed DHLP-2 round on fused edge shards.

    The engine ``round`` contract (DESIGN.md §11.1): ``β²Y + A_eff @ F``
    with the same edge-sharded aggregation + psum as one superstep of the
    full solver — serve-side incremental hint refresh on a pod runs this
    per demoted column batch.
    """

    def shard_body(src, dst, w, F, Y):
        src, dst, w = src[0], dst[0], w[0]
        F = F.astype(jnp.float32)
        Y = Y.astype(jnp.float32)
        local = segment_sum(w[:, None] * F[src], dst, num_nodes)
        agg = compressed_psum(local, edge_axis, compression=compression)
        return beta2 * Y + agg

    mapped = shard_map_compat(
        shard_body,
        mesh=mesh,
        in_specs=(
            P(edge_axis, None),
            P(edge_axis, None),
            P(edge_axis, None),
            P(None, seed_axis),
            P(None, seed_axis),
        ),
        out_specs=P(None, seed_axis),
        check=False,
    )
    return jax.jit(mapped)


def build_sharded_dhlp1(
    mesh: Mesh,
    *,
    num_nodes: int,
    alpha: float,
    sigma: float,
    max_iter: int,
    max_inner: int,
    seed_mode: str,
    edge_axis: str = "model",
    seed_axis: str = "data",
    compression: str = "none",
):
    """Sharded DHLP-1: outer hetero injection + inner homogeneous solve.

    Takes SEPARATE hetero and homo edge shards (the algorithms mix them
    with different schedules).  Both loops carry globally-uniform
    predicates (pmax over the whole mesh) so in-loop collectives cannot
    deadlock across shards — same discipline as the DHLP-2 engine.
    """
    beta = 1.0 - alpha

    def shard_body(h_src, h_dst, h_w, m_src, m_dst, m_w, Y, F0):
        h_src, h_dst, h_w = h_src[0], h_dst[0], h_w[0]
        m_src, m_dst, m_w = m_src[0], m_dst[0], m_w[0]
        Y = Y.astype(jnp.float32)
        F0 = F0.astype(jnp.float32)

        def agg(src, dst, w, F):
            local = segment_sum(w[:, None] * F[src], dst, num_nodes)
            return compressed_psum(local, edge_axis, compression=compression)

        def inner(Yp, F0, active):
            def icond(istate):
                _, _, it, ga = istate
                return jnp.logical_and(it < max_inner, ga > 0)

            def ibody(istate):
                F, iact, it, _ = istate
                Fn = beta * Yp + alpha * agg(m_src, m_dst, m_w, F)
                Fn = jnp.where(iact[None, :], Fn, F)
                delta = jnp.max(jnp.abs(Fn - F), axis=0)
                still = jnp.logical_and(iact, ~(delta < sigma))
                ga = lax.pmax(
                    jnp.any(still).astype(jnp.int32), (seed_axis, edge_axis)
                )
                return Fn, still, it + 1, ga

            F, _, inner_it, _ = lax.while_loop(
                icond, ibody,
                (F0, active, jnp.asarray(0, jnp.int32),
                 jnp.asarray(1, jnp.int32)),
            )
            return F, inner_it

        def cond(state):
            _, _, it, _, ga = state
            return jnp.logical_and(it < max_iter, ga > 0)

        def body(state):
            F, active, it, tot_inner, _ = state
            src_lbl = Y if seed_mode == "fixed" else F
            Yp = beta * src_lbl + alpha * agg(h_src, h_dst, h_w, F)
            Fn, inner_it = inner(Yp, F, active)
            Fn = jnp.where(active[None, :], Fn, F)
            delta = jnp.max(jnp.abs(Fn - F), axis=0)
            still = jnp.logical_and(active, ~(delta < sigma))
            ga = lax.pmax(
                jnp.any(still).astype(jnp.int32), (seed_axis, edge_axis)
            )
            return Fn, still, it + 1, tot_inner + inner_it, ga

        s = Y.shape[1]
        state0 = (
            F0,
            jnp.ones((s,), dtype=bool),
            jnp.asarray(0, jnp.int32),
            jnp.asarray(0, jnp.int32),
            jnp.asarray(1, jnp.int32),
        )
        F, _, iters, tot_inner, _ = lax.while_loop(cond, body, state0)
        return F, jnp.reshape(iters, (1,)), jnp.reshape(tot_inner, (1,))

    mapped = shard_map_compat(
        shard_body,
        mesh=mesh,
        in_specs=(
            P(edge_axis, None), P(edge_axis, None), P(edge_axis, None),
            P(edge_axis, None), P(edge_axis, None), P(edge_axis, None),
            P(None, seed_axis),
            P(None, seed_axis),
        ),
        out_specs=(P(None, seed_axis), P(seed_axis), P(seed_axis)),
        check=False,
    )
    return jax.jit(mapped)


def _prepare_split_operator(
    norm: NormalizedNetwork, cfg: LPConfig, num_edge_shards: int
):
    """Hetero and homo edge shards (scaled) from the blocked-CSR operators.

    The blocked-CSR pair is the same format the sparse engine's DHLP-1
    buckets aggregate; its row-major slots flatten to destination-sorted
    shards directly.
    """
    scale = cfg.resolved_hetero_scale(norm.num_types)
    het_csr, hom_csr = split_blocked_csr_from_network(
        norm, hetero_scale=scale
    )
    het = _shard_edges(*het_csr.to_edges(include_pads=False), num_edge_shards)
    hom = _shard_edges(*hom_csr.to_edges(include_pads=False), num_edge_shards)
    return het, hom


@dataclasses.dataclass
class ShardedPrepared:
    """Device-ready operator shards + the compiled solver for one mesh."""

    mesh: Mesh
    num_nodes: int
    arrays: Tuple[jax.Array, ...]
    solver: object
    alg: str
    edge_axis: str
    seed_axis: str


class ShardedHeteroLP:
    """Distributed solver running on an explicit device mesh."""

    def __init__(
        self,
        config: LPConfig = LPConfig(),
        *,
        stale_sync: int = 1,
        compression: str = "none",
    ):
        self.config = config
        self.stale_sync = stale_sync
        self.compression = compression
        self._prep_cache: Optional[Tuple[object, Mesh, ShardedPrepared]] = None

    def prepare(
        self,
        norm: NormalizedNetwork,
        mesh: Mesh,
        *,
        edge_axis: str = "model",
        seed_axis: str = "data",
    ) -> ShardedPrepared:
        """Shard the operator and build the compiled solver once per
        (network, mesh, axes) — repeat solves skip re-upload AND re-trace."""
        cache = self._prep_cache
        if (
            cache is not None
            and cache[0] is norm
            and cache[1] is mesh
            and cache[2].edge_axis == edge_axis
            and cache[2].seed_axis == seed_axis
        ):
            return cache[2]
        cfg = self.config
        k_edges = mesh.shape[edge_axis]
        n = norm.num_nodes
        if cfg.alg == "dhlp1":
            het, hom = _prepare_split_operator(norm, cfg, k_edges)
            solver = build_sharded_dhlp1(
                mesh,
                num_nodes=n,
                alpha=cfg.alpha,
                sigma=cfg.sigma,
                max_iter=cfg.max_iter,
                max_inner=cfg.max_inner,
                seed_mode=cfg.resolved_seed_mode(),
                edge_axis=edge_axis,
                seed_axis=seed_axis,
                compression=self.compression,
            )
            arrays = tuple(
                jnp.asarray(a) for a in (*het, *hom)
            )
        else:
            arrs = prepare_sharded_operator(norm, cfg, k_edges)
            solver = build_sharded_dhlp2(
                mesh,
                num_nodes=n,
                beta2=arrs.beta2,
                sigma=cfg.sigma,
                max_iter=cfg.max_iter,
                seed_mode=cfg.resolved_seed_mode(),
                edge_axis=edge_axis,
                seed_axis=seed_axis,
                stale_sync=self.stale_sync,
                compression=self.compression,
            )
            arrays = (
                jnp.asarray(arrs.src),
                jnp.asarray(arrs.dst),
                jnp.asarray(arrs.w),
            )
        prep = ShardedPrepared(
            mesh=mesh,
            num_nodes=n,
            arrays=arrays,
            solver=solver,
            alg=cfg.alg,
            edge_axis=edge_axis,
            seed_axis=seed_axis,
        )
        self._prep_cache = (norm, mesh, prep)
        return prep

    def solve_prepared(
        self,
        prep: ShardedPrepared,
        Y: np.ndarray,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        cfg = self.config
        n = prep.num_nodes
        k_seeds = prep.mesh.shape[prep.seed_axis]
        Y = np.asarray(Y)
        if Y.ndim == 1:
            Y = Y[:, None]
        s = Y.shape[1]
        pad_s = (-s) % k_seeds
        if pad_s:
            Y = np.concatenate([Y, np.zeros((n, pad_s), Y.dtype)], axis=1)
        if F0 is None:
            F0 = Y
        else:
            F0 = np.asarray(F0)
            if F0.ndim == 1:
                F0 = F0[:, None]
            if pad_s:
                F0 = np.concatenate(
                    [F0, np.zeros((n, pad_s), F0.dtype)], axis=1
                )
        Yd = jnp.asarray(Y, jnp.float32)
        F0d = jnp.asarray(F0, jnp.float32)

        if prep.alg == "dhlp1":
            F, iters, tot_inner = prep.solver(*prep.arrays, Yd, F0d)
            outer = int(np.max(np.asarray(iters)))
            return SolveResult(
                F=np.asarray(F, np.float64)[:, :s],
                outer_iters=outer,
                inner_iters=int(np.max(np.asarray(tot_inner))),
                converged=bool(outer < cfg.max_iter),
            )
        F, iters, col_iters = prep.solver(*prep.arrays, Yd, F0d)
        outer = int(np.max(np.asarray(iters)))
        return SolveResult(
            F=np.asarray(F, np.float64)[:, :s],
            outer_iters=outer,
            inner_iters=0,
            converged=bool(outer < cfg.max_iter),
            per_column_iters=np.asarray(col_iters)[:s],
        )

    def run(
        self,
        norm: NormalizedNetwork,
        mesh: Mesh,
        seeds: Optional[np.ndarray] = None,
        *,
        edge_axis: str = "model",
        seed_axis: str = "data",
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        n = norm.num_nodes
        Y = np.eye(n, dtype=np.float32) if seeds is None else seeds
        prep = self.prepare(
            norm, mesh, edge_axis=edge_axis, seed_axis=seed_axis
        )
        return self.solve_prepared(prep, Y, F0=F0)
