"""Collective helpers: compression + decomposition tricks.

Distributed-optimization features used by the LP engine and the train loop:

* ``compressed_psum`` — all-reduce in a lower precision (bf16, or int8 with
  per-tensor scale + stochastic rounding).  On a 1000-node cluster the LP
  aggregate / gradient all-reduce is interconnect-bound; halving or
  quartering bytes moves the collective roofline term directly.
* ``psum_scatter`` wrapper — reduce-scatter + all-gather decomposition of an
  all-reduce, the standard trick that lets XLA overlap each half with
  compute on different tensors.
* ``ring_allreduce_ppermute`` — explicit ring schedule via
  ``lax.ppermute``; used where we want manual overlap with compute chunks
  (and to make the collective visible/tunable in the HLO rather than left
  to the compiler).

All functions must be called inside ``shard_map`` with the named axis bound.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def _stochastic_round_int8(x: jax.Array, scale: jax.Array, key) -> jax.Array:
    """Quantize x/scale to int8 with stochastic rounding."""
    y = x / scale
    y = jnp.clip(y, -127.0, 127.0)
    floor = jnp.floor(y)
    frac = y - floor
    rnd = jax.random.uniform(key, y.shape, dtype=y.dtype)
    return (floor + (rnd < frac)).astype(jnp.int8)


def compressed_psum(
    x: jax.Array,
    axis_name: str,
    *,
    compression: str = "none",
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """psum with optional wire compression.

    compression:
      - "none": plain fp32 psum.
      - "bf16": cast to bf16 before the collective (2x fewer bytes), fp32 out.
      - "int8": per-tensor absmax scale, stochastic rounding (needs ``key``).
        The scale itself is maxed across the axis first (small collective).
    """
    if compression == "none":
        return lax.psum(x, axis_name)
    if compression == "bf16":
        return lax.psum(x.astype(jnp.bfloat16), axis_name).astype(x.dtype)
    if compression == "int8":
        if key is None:
            raise ValueError("int8 compression needs a PRNG key")
        absmax = jnp.max(jnp.abs(x))
        absmax = lax.pmax(absmax, axis_name)
        scale = jnp.maximum(absmax / 127.0, 1e-12)
        q = _stochastic_round_int8(x, scale, key)
        # int8 summands can overflow int8; accumulate in int32 on the wire.
        acc = lax.psum(q.astype(jnp.int32), axis_name)
        return acc.astype(x.dtype) * scale
    raise ValueError(f"unknown compression {compression!r}")


def psum_scatter_then_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """all-reduce = reduce-scatter + all-gather (overlappable halves)."""
    scattered = lax.psum_scatter(x, axis_name, tiled=True)
    return lax.all_gather(scattered, axis_name, tiled=True)


def ring_allreduce_ppermute(x: jax.Array, axis_name: str) -> jax.Array:
    """Explicit (k−1)-step ring all-reduce using collective_permute.

    Equivalent to psum; written out so the schedule appears as k−1
    ``collective-permute`` ops in the HLO that the compiler can interleave
    with compute issued between steps.
    """
    # lax.axis_size is a newer-JAX addition; psum of a Python scalar
    # constant-folds to the static axis size on older releases.
    axis_size = getattr(lax, "axis_size", None)
    k = axis_size(axis_name) if axis_size is not None else lax.psum(1, axis_name)
    if k == 1:
        return x
    perm = [(i, (i + 1) % k) for i in range(k)]

    def step(carry, _):
        acc, buf = carry
        buf = lax.ppermute(buf, axis_name, perm)
        return (acc + buf, buf), None

    (acc, _), _ = lax.scan(step, (x, x), None, length=k - 1)
    return acc


def grad_allreduce(
    grads,
    axis_name: str,
    *,
    compression: str = "none",
    key: Optional[jax.Array] = None,
):
    """Tree-wide gradient all-reduce with optional compression."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if key is not None:
        keys = list(jax.random.split(key, len(leaves)))
    else:
        keys = [None] * len(leaves)
    out = [
        compressed_psum(leaf, axis_name, compression=compression, key=k)
        for leaf, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)
