"""Activation-sharding hints.

GSPMD's propagation from input shardings alone makes poor choices around
gathers (token embedding of a vocab-sharded table by batch-sharded ids) —
measured on granite train_4k it REPLICATED all activations, costing 13GB+
temp per device for a single dense layer.  The standard fix (the
MaxText/"logical axis rules" playbook) is explicit
``with_sharding_constraint`` on activations at layer boundaries.

Model code refers to LOGICAL axes; launchers register the physical mesh:

    set_ambient_mesh(mesh)        # dryrun / train driver, before tracing
    x = shard_hint(x, BATCH, None, TP)

``shard_hint`` is a no-op when no mesh is registered (unit tests, CPU
runs) and silently drops axes that don't exist in / divide the dim.
"""
from __future__ import annotations

import threading
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axes
BATCH = "__batch__"      # data-parallel axes: ("pod", "data") ∩ mesh
TP = "__model__"         # tensor/expert-parallel axis: "model"

_state = threading.local()


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where available, else ``None``.

    ``jax.sharding.AxisType`` only exists in newer JAX releases; on older
    installs ``jax.make_mesh`` takes no ``axis_types`` and every axis is
    implicitly Auto, so omitting the kwarg is the exact equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis_types across JAX versions."""
    kwargs = {}
    types = auto_axis_types(len(axes))
    if types is not None:
        kwargs["axis_types"] = types
    return jax.make_mesh(shape, axes, **kwargs)


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map`` with a ``check_vma`` kwarg; older
    releases have ``jax.experimental.shard_map.shard_map`` with the same
    flag named ``check_rep``.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)


def set_ambient_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_ambient_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def _resolve(axis, mesh) -> Optional[Union[str, Tuple[str, ...]]]:
    if axis is None:
        return None
    if axis == BATCH:
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes or None
    if axis == TP:
        return "model" if "model" in mesh.axis_names else None
    return axis if axis in mesh.axis_names else None


def _axes_size(axes, mesh) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def shard_hint(x: jax.Array, *spec) -> jax.Array:
    mesh = get_ambient_mesh()
    if mesh is None:
        return x
    if len(spec) != x.ndim:
        raise ValueError(f"spec rank {len(spec)} != array rank {x.ndim}")
    resolved = []
    for axis, dim in zip(spec, x.shape):
        r = _resolve(axis, mesh)
        if r is not None and dim % _axes_size(r, mesh) != 0:
            r = None
        resolved.append(r)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved))
    )
