"""Planted-truth evaluation protocols over scenario bundles.

Two protocols, both scored against the generator's construction ground
truth (noise edges are neither positives nor negatives — they are
excluded from every evaluation set):

* **recovery** — hide a fraction of the planted positives of one pair,
  re-solve on any engine-registry backend, and rank the held-out entries
  against the true negatives of the same rows.  Seeds are only the rows
  that lost an edge (capped at ``max_entities``), so the protocol scales
  to the million-edge scenarios where all-pairs solves are off the
  table.
* **k-fold CV** — the paper's Table 2 protocol (``eval/cv.py``) with the
  positive set overridden to the planted truth, so it runs unchanged on
  any T-type scenario.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.network import HeteroNetwork, TypePair, seeds_for_nodes
from repro.core.solver import LPConfig
from repro.eval.cv import FoldResult, cross_validate
from repro.eval.metrics import auc_score, aupr_score
from repro.scenarios.base import ScenarioBundle


def default_lp_config(sigma: float = 1e-4) -> LPConfig:
    """Serving-grade solve config: fused DHLP-2, fixed seeds."""
    return LPConfig(alg="dhlp2", sigma=sigma, seed_mode="fixed")


@dataclasses.dataclass
class RecoveryProblem:
    """A masked solve whose answer is scored against planted truth."""

    bundle: ScenarioBundle
    pair: TypePair
    masked_net: HeteroNetwork
    #: seed columns — one per evaluated entity (rows of ``pair``'s block)
    Y: np.ndarray
    rows: np.ndarray          # (B,) local row ids within type pair[0]
    heldout: np.ndarray       # (n_i, n_j) bool — hidden planted positives
    negatives: np.ndarray     # (n_i, n_j) bool — true negatives
    target_slice: slice

    @property
    def num_heldout(self) -> int:
        return int(self.heldout[self.rows].sum())

    def scores_from_F(self, F: np.ndarray) -> np.ndarray:
        """(B, n_j) score block for the evaluated rows."""
        return np.asarray(F[self.target_slice, :], dtype=np.float64).T

    def metrics(self, F: np.ndarray) -> Dict[str, float]:
        scores = self.scores_from_F(F)
        s, labels = [], []
        for b, u in enumerate(self.rows):
            mask = self.heldout[u] | self.negatives[u]
            s.append(scores[b, mask])
            labels.append(self.heldout[u, mask])
        sv = np.concatenate(s)
        lv = np.concatenate(labels)
        return {
            "recovery_auc": auc_score(sv, lv),
            "recovery_aupr": aupr_score(sv, lv),
            "eval_entities": float(len(self.rows)),
            "heldout_edges": float(self.num_heldout),
        }


def make_recovery_problem(
    bundle: ScenarioBundle,
    pair: Optional[TypePair] = None,
    *,
    holdout_frac: float = 0.1,
    max_entities: int = 32,
    seed: int = 0,
) -> RecoveryProblem:
    """Hide ``holdout_frac`` of the pair's planted positives; seed the
    rows that lost one (subsampled to ``max_entities``)."""
    pair = bundle.eval_pair if pair is None else (min(pair), max(pair))
    net = bundle.network
    R = net.R[pair]
    planted = bundle.truth[pair] & (R > 0)
    pos = np.argwhere(planted)
    if len(pos) < 2:
        raise ValueError(f"pair {pair} has too few planted positives")
    rng = np.random.default_rng(seed)
    n_hold = max(1, int(len(pos) * holdout_frac))
    sel = pos[rng.choice(len(pos), size=n_hold, replace=False)]
    heldout = np.zeros_like(planted)
    heldout[sel[:, 0], sel[:, 1]] = True

    rows = np.unique(sel[:, 0])
    if len(rows) > max_entities:
        rows = rng.choice(rows, size=max_entities, replace=False)
        rows.sort()
    i, j = pair
    masked = net.with_masked_fold(pair, heldout)
    Y = seeds_for_nodes(net.num_nodes, list(net.offsets[i] + rows))
    off_j = net.offsets[j]
    return RecoveryProblem(
        bundle=bundle,
        pair=pair,
        masked_net=masked,
        Y=Y,
        rows=rows,
        heldout=heldout,
        negatives=(R == 0) & ~bundle.truth[pair],
        target_slice=slice(off_j, off_j + net.sizes[j]),
    )


def solve_recovery(
    problem: RecoveryProblem,
    backend: str = "auto",
    *,
    lp: Optional[LPConfig] = None,
    **engine_kw,
):
    """Run the masked solve on one registry backend; returns SolveResult."""
    from repro.engine import make_engine

    cfg = lp or default_lp_config()
    engine = make_engine(
        backend,
        cfg,
        num_nodes=problem.masked_net.num_nodes,
        **engine_kw,
    )
    return engine.run(problem.masked_net, seeds=problem.Y)


def recovery_auc(
    bundle: ScenarioBundle,
    backend: str = "auto",
    *,
    pair: Optional[TypePair] = None,
    holdout_frac: float = 0.1,
    max_entities: int = 32,
    seed: int = 0,
    lp: Optional[LPConfig] = None,
    **engine_kw,
) -> Dict[str, float]:
    """Convenience: problem + solve + metrics in one call."""
    problem = make_recovery_problem(
        bundle,
        pair,
        holdout_frac=holdout_frac,
        max_entities=max_entities,
        seed=seed,
    )
    res = solve_recovery(problem, backend, lp=lp, **engine_kw)
    out = problem.metrics(res.F)
    out["outer_iters"] = float(res.outer_iters)
    return out


def backend_solver_fn(
    bundle: ScenarioBundle,
    pair: TypePair,
    backend: str = "auto",
    *,
    lp: Optional[LPConfig] = None,
    engine=None,
    **engine_kw,
):
    """A ``cross_validate``-compatible solver over a registry backend.

    Seeds every node of the pair's source type and returns the
    ``(n_i, n_j)`` predicted score block — the full-matrix protocol the
    small scenarios use for k-fold CV.  Pass a prebuilt ``engine`` to
    reuse one instance across every fold (the Session API does).
    """
    from repro.engine import make_engine

    i, j = min(pair), max(pair)
    cfg = lp or default_lp_config()

    def solver(masked_net: HeteroNetwork) -> np.ndarray:
        nonlocal engine
        if engine is None:
            engine = make_engine(
                backend, cfg, num_nodes=masked_net.num_nodes, **engine_kw
            )
        off_i, off_j = masked_net.offsets[i], masked_net.offsets[j]
        n_i, n_j = masked_net.sizes[i], masked_net.sizes[j]
        Y = seeds_for_nodes(
            masked_net.num_nodes, list(range(off_i, off_i + n_i))
        )
        res = engine.run(masked_net, seeds=Y)
        return np.asarray(res.F[off_j : off_j + n_j, :], np.float64).T

    return solver


def scenario_cross_validate(
    bundle: ScenarioBundle,
    *,
    pair: Optional[TypePair] = None,
    backend: str = "auto",
    k: int = 5,
    seed: int = 0,
    lp: Optional[LPConfig] = None,
    engine=None,
) -> List[FoldResult]:
    """The Table 2 k-fold protocol against the scenario's planted truth."""
    pair = bundle.eval_pair if pair is None else (min(pair), max(pair))
    positives = bundle.truth[pair] & (bundle.network.R[pair] > 0)
    return cross_validate(
        bundle.network,
        pair,
        backend_solver_fn(bundle, pair, backend, lp=lp, engine=engine),
        k=k,
        seed=seed,
        positives=positives,
    )
