"""Scenario & workload subsystem (DESIGN.md §12).

Named generators of heterogeneous-network workloads: each produces a
:class:`ScenarioBundle` — network + planted truth + optional delta
stream + optional serve query trace — behind a string-keyed registry,
so benches, eval, serving, and the ``repro scenario`` CLI all
name workloads the same way the engine registry names backends.
"""
from repro.scenarios.arrivals import (
    ARRIVAL_PROCESSES,
    arrival_times,
    build_trace,
    zipf_entities,
)
from repro.scenarios.base import (
    CACHE_MIN_EDGES,
    QueryTrace,
    ScenarioBundle,
    ScenarioInfo,
    TimedDelta,
    available_scenarios,
    cache_dir,
    cache_path,
    generate,
    get_scenario,
    list_rows,
    register_scenario,
    scaled_sizes,
)
from repro.scenarios.evaluate import (
    RecoveryProblem,
    backend_solver_fn,
    default_lp_config,
    make_recovery_problem,
    recovery_auc,
    scenario_cross_validate,
    solve_recovery,
)
from repro.scenarios.generators import (
    KPartiteSpec,
    PlantedKPartite,
    planted_kpartite,
    sizes_for_edges,
)

# importing the library registers the built-in scenarios
from repro.scenarios import library as _library  # noqa: F401,E402

__all__ = [
    "ARRIVAL_PROCESSES",
    "CACHE_MIN_EDGES",
    "KPartiteSpec",
    "PlantedKPartite",
    "QueryTrace",
    "RecoveryProblem",
    "ScenarioBundle",
    "ScenarioInfo",
    "TimedDelta",
    "arrival_times",
    "available_scenarios",
    "backend_solver_fn",
    "build_trace",
    "cache_dir",
    "cache_path",
    "default_lp_config",
    "generate",
    "get_scenario",
    "list_rows",
    "make_recovery_problem",
    "planted_kpartite",
    "recovery_auc",
    "register_scenario",
    "scaled_sizes",
    "scenario_cross_validate",
    "sizes_for_edges",
    "solve_recovery",
    "zipf_entities",
]
