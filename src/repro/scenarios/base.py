"""Scenario registry: named workloads = network + truth + deltas + trace.

A *scenario* is everything one experiment needs, bundled:

* a :class:`~repro.core.network.HeteroNetwork` with an arbitrary
  type-count schema,
* the planted ground-truth positives per association pair (what CV and
  recovery protocols score against),
* optionally a timed :class:`~repro.core.network.GraphDelta` stream (the
  serve layer's incremental-update workload), and
* optionally a serve query trace with a configurable arrival process
  (``repro.scenarios.arrivals``).

Builders register under a string key with
``@register_scenario("name", description=...)`` and have signature
``fn(scale: float, seed: int, **kw) -> ScenarioBundle``; ``scale``
multiplies the scenario's nominal size (node counts or target edges) so
one registration serves both the CI fast pass (``scale << 1``) and the
full-scale cell.  ``repro scenario`` lists/generates/solves them;
``bench/matrix.py`` crosses them with the engine-backend registry.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import tempfile
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.network import GraphDelta, HeteroNetwork, TypePair


@dataclasses.dataclass(frozen=True)
class TimedDelta:
    """A graph edit scheduled at ``t`` seconds into the workload."""

    t: float
    delta: GraphDelta


@dataclasses.dataclass(frozen=True)
class QueryTrace:
    """A serve workload: arrival-stamped ranking queries.

    Columns are parallel arrays (event *i* = ``(t[i], entity[i],
    target_type[i])``); ``t`` is seconds from trace start,
    non-decreasing.  ``process`` names the arrival process that generated
    the timestamps (poisson | bursty | diurnal).
    """

    t: np.ndarray            # (Q,) float64, sorted
    entity: np.ndarray       # (Q,) int32 global node ids
    target_type: np.ndarray  # (Q,) int32
    process: str
    rate_qps: float
    horizon_s: float

    def __len__(self) -> int:
        return int(self.t.shape[0])


@dataclasses.dataclass
class ScenarioBundle:
    """One generated scenario instance (see module docstring)."""

    name: str
    network: HeteroNetwork
    #: planted positives per pair — boolean arrays shaped like ``R[pair]``
    truth: Dict[TypePair, np.ndarray]
    #: the pair recovery/CV protocols score by default
    eval_pair: TypePair
    clusters: Optional[Tuple[np.ndarray, ...]] = None
    deltas: Tuple[TimedDelta, ...] = ()
    trace: Optional[QueryTrace] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        i, j = self.eval_pair
        if (i, j) not in self.network.R:
            raise ValueError(f"eval_pair {(i, j)} has no association block")
        for pair, mask in self.truth.items():
            if mask.shape != self.network.R[pair].shape:
                raise ValueError(
                    f"truth[{pair}] shape {mask.shape} != "
                    f"{self.network.R[pair].shape}"
                )

    def describe(self) -> Dict[str, Any]:
        net = self.network
        return {
            "name": self.name,
            "types": net.num_types,
            "type_names": list(net.type_names or ()),
            "sizes": list(net.sizes),
            "nodes": net.num_nodes,
            "edges": net.num_edges,
            "pairs": sorted(net.R),
            "eval_pair": tuple(self.eval_pair),
            "planted_positives": {
                str(k): int(v.sum()) for k, v in sorted(self.truth.items())
            },
            "deltas": len(self.deltas),
            "trace": None
            if self.trace is None
            else {
                "process": self.trace.process,
                "queries": len(self.trace),
                "rate_qps": self.trace.rate_qps,
                "horizon_s": self.trace.horizon_s,
            },
            **self.meta,
        }


ScenarioFn = Callable[..., ScenarioBundle]


@dataclasses.dataclass(frozen=True)
class ScenarioInfo:
    name: str
    fn: ScenarioFn
    description: str = ""
    tags: Tuple[str, ...] = ()


_REGISTRY: Dict[str, ScenarioInfo] = {}


def register_scenario(
    name: str, *, description: str = "", tags: Tuple[str, ...] = ()
) -> Callable[[ScenarioFn], ScenarioFn]:
    """Decorator: register a builder ``fn(scale, seed, **kw)`` by name."""

    def deco(fn: ScenarioFn) -> ScenarioFn:
        existing = _REGISTRY.get(name)
        if existing is not None and existing.fn is not fn:
            raise ValueError(f"scenario {name!r} already registered")
        _REGISTRY[name] = ScenarioInfo(
            name=name, fn=fn, description=description, tags=tags
        )
        return fn

    return deco


def available_scenarios() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_scenario(name: str) -> ScenarioInfo:
    if name not in _REGISTRY:
        known = ", ".join(available_scenarios()) or "<none>"
        raise KeyError(f"unknown scenario {name!r}; registered: {known}")
    return _REGISTRY[name]


# --------------------------------------------------------------------------
# Generation + disk cache
# --------------------------------------------------------------------------
# Heavyweight bundles (the 1.2M-edge powerlaw cell costs ~40 s and
# ~600 MB peak to generate, per process) are pickled once per machine
# under results/scenario_cache/ and reloaded on repeat generation.
# Small bundles are not worth the disk churn — only networks at or
# above this edge count are written.
CACHE_MIN_EDGES = 200_000
# bump when generator semantics change: stale cache entries must miss
_CACHE_SALT = "v1"


def cache_dir() -> str:
    return os.environ.get(
        "REPRO_SCENARIO_CACHE_DIR",
        os.path.join("results", "scenario_cache"),
    )


def _cache_key(name: str, scale: float, seed: int, kw: Dict[str, Any]) -> str:
    """Digest of scenario name + every builder parameter (+ salt)."""
    parts = [
        _CACHE_SALT,
        name,
        repr(float(scale)),
        repr(int(seed)),
        repr(sorted(kw.items())),
    ]
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:16]


def cache_path(name: str, scale: float, seed: int, kw: Dict[str, Any]) -> str:
    return os.path.join(cache_dir(), f"{name}-{_cache_key(name, scale, seed, kw)}.pkl")


def _cache_enabled() -> bool:
    return os.environ.get("REPRO_SCENARIO_CACHE", "1") != "0"


def generate(
    name: str,
    *,
    scale: float = 1.0,
    seed: int = 0,
    cache: Optional[bool] = None,
    **kw,
) -> ScenarioBundle:
    """Instantiate a registered scenario at ``scale``.

    ``cache=None`` applies the policy: reuse/write the per-machine disk
    cache (keyed by scenario name + params + seed) for bundles with at
    least :data:`CACHE_MIN_EDGES` edges, unless ``REPRO_SCENARIO_CACHE=0``.
    ``cache=False`` bypasses it entirely (the CLIs' ``--no-cache``);
    ``cache=True`` forces a write regardless of size.
    """
    if scale <= 0:
        raise ValueError(f"scale must be > 0, got {scale}")
    use_cache = _cache_enabled() if cache is None else cache
    path = cache_path(name, scale, seed, kw) if use_cache else None
    if path is not None and os.path.exists(path):
        try:
            with open(path, "rb") as f:
                bundle = pickle.load(f)
            if isinstance(bundle, ScenarioBundle) and bundle.name == name:
                return bundle
        except Exception:
            # a torn/stale entry must never break generation — fall through
            pass
    bundle = get_scenario(name).fn(scale=scale, seed=seed, **kw)
    if path is not None and (
        cache is True or bundle.network.num_edges >= CACHE_MIN_EDGES
    ):
        _atomic_pickle(bundle, path)
    return bundle


def _atomic_pickle(bundle: ScenarioBundle, path: str) -> None:
    """Write-then-rename so concurrent generators never read a torn file."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            pickle.dump(bundle, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)
    except Exception:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def scaled_sizes(
    base: Tuple[int, ...], scale: float, floor: int = 8
) -> Tuple[int, ...]:
    """Multiply nominal per-type sizes by ``scale`` with a sanity floor."""
    return tuple(max(floor, int(round(n * scale))) for n in base)


def list_rows() -> List[Dict[str, Any]]:
    """Registry summary rows for the CLI's ``--list``."""
    return [
        {
            "name": info.name,
            "description": info.description,
            "tags": list(info.tags),
        }
        for info in (_REGISTRY[k] for k in available_scenarios())
    ]
