"""The registered scenario library (DESIGN.md §12.2).

Five-plus scenarios spanning the diversity/scale axes the paper's
"general methods for heterogeneous networks" claim implies but its
experiments never stress:

* ``bio_tri``      — the tri-partite drug/disease/target case study
                     (adapter over the shared k-partite generator;
                     ``data/drugnet.py`` keeps its legacy API on top of
                     the same construction);
* ``kpartite5``    — a 5-type mechanism network on a non-complete pair
                     schema (drug–disease–target–gene–side-effect);
* ``kpartite_heterophilic`` — planted CROSS-cluster associations over a
                     4-type complete schema: similarity stays
                     homophilic, associations follow a fixed-point-free
                     cluster shift (Deng et al., PAPERS.md);
* ``powerlaw``     — heavy-tailed degrees from Pareto propensities with
                     a ``scale`` knob calibrated in expected edges
                     (nominal scale=1.0 ⇒ ≥1M edges, the paper's
                     Tables 5/6 territory);
* ``streaming``    — a tri-partite net whose planted edges are partly
                     held out at t=0 and re-added by a timed GraphDelta
                     stream, plus a diurnal query trace: the serve
                     layer's incremental-update workload with ground
                     truth attached.

Every builder takes ``(scale, seed, **kw)`` and returns a
:class:`~repro.scenarios.base.ScenarioBundle`; sizes floor at small
values so ``scale=0.1`` stays a valid smoke test.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.network import GraphDelta, HeteroNetwork, TypePair
from repro.scenarios.arrivals import build_trace
from repro.scenarios.base import (
    QueryTrace,
    ScenarioBundle,
    TimedDelta,
    register_scenario,
    scaled_sizes,
)
from repro.scenarios.generators import (
    KPartiteSpec,
    PlantedKPartite,
    planted_kpartite,
    sizes_for_edges,
)

# Empirical calibration of expected-vs-realized edges for the powerlaw
# construction (propensity clipping + symmetrized similarity support make
# the analytic count an underestimate); keeps nominal scale=1.0 >= 1M.
_POWERLAW_EDGE_TARGET = 1_700_000


def _bundle_from_planted(
    name: str,
    pk: PlantedKPartite,
    eval_pair: TypePair,
    *,
    deltas: Tuple[TimedDelta, ...] = (),
    trace: Optional[QueryTrace] = None,
    meta: Optional[dict] = None,
) -> ScenarioBundle:
    return ScenarioBundle(
        name=name,
        network=pk.network,
        truth=pk.truth,
        eval_pair=eval_pair,
        clusters=pk.clusters,
        deltas=deltas,
        trace=trace,
        meta={"spec_seed": pk.spec.seed, **(meta or {})},
    )


@register_scenario(
    "bio_tri",
    description="tri-partite drug/disease/target case study (paper shape)",
    tags=("bio", "homophilic"),
)
def bio_tri(scale: float = 1.0, seed: int = 0, **kw) -> ScenarioBundle:
    spec = KPartiteSpec(
        sizes=scaled_sizes((223, 150, 95), scale),
        n_clusters=12,
        type_names=("drug", "disease", "target"),
        seed=seed,
        **kw,
    )
    return _bundle_from_planted("bio_tri", planted_kpartite(spec), (0, 2))


@register_scenario(
    "bipartite",
    description="2-type net, one association pair (smallest schema)",
    tags=("bipartite", "homophilic"),
)
def bipartite(scale: float = 1.0, seed: int = 0, **kw) -> ScenarioBundle:
    """The minimal heterogeneous schema the generators support: two node
    types joined by a single association block — e.g. plain drug–target
    prediction with per-type similarity but no third information source.
    Exercises the T=2 edge of every protocol (hetero_scale = 1/(T−1) = 1,
    the strictly-literal paper update)."""
    spec = KPartiteSpec(
        sizes=scaled_sizes((160, 110), scale),
        pairs=((0, 1),),
        n_clusters=8,
        type_names=("drug", "target"),
        seed=seed,
        **kw,
    )
    return _bundle_from_planted("bipartite", planted_kpartite(spec), (0, 1))


@register_scenario(
    "kpartite5",
    description="5-type mechanism net on a non-complete pair schema",
    tags=("kpartite", "homophilic"),
)
def kpartite5(scale: float = 1.0, seed: int = 0, **kw) -> ScenarioBundle:
    spec = KPartiteSpec(
        sizes=scaled_sizes((120, 90, 80, 70, 60), scale),
        pairs=((0, 1), (0, 2), (1, 2), (2, 3), (0, 4), (3, 4)),
        n_clusters=8,
        type_names=("drug", "disease", "target", "gene", "side_effect"),
        seed=seed,
        **kw,
    )
    return _bundle_from_planted("kpartite5", planted_kpartite(spec), (2, 3))


@register_scenario(
    "kpartite_heterophilic",
    description="4-type net with planted cross-cluster associations",
    tags=("kpartite", "heterophilic"),
)
def kpartite_heterophilic(
    scale: float = 1.0, seed: int = 0, **kw
) -> ScenarioBundle:
    spec = KPartiteSpec(
        sizes=scaled_sizes((100, 80, 70, 60), scale),
        n_clusters=6,
        heterophily=True,
        type_names=("a", "b", "c", "d"),
        seed=seed,
        **kw,
    )
    return _bundle_from_planted(
        "kpartite_heterophilic", planted_kpartite(spec), (0, 2)
    )


@register_scenario(
    "powerlaw",
    description="heavy-tailed-degree net; scale=1.0 targets >=1M edges",
    tags=("powerlaw", "scale"),
)
def powerlaw(scale: float = 1.0, seed: int = 0, **kw) -> ScenarioBundle:
    target = max(2000, int(_POWERLAW_EDGE_TARGET * scale))
    base = KPartiteSpec(
        sizes=(223, 150, 95),  # ratio only; resized to the edge target
        n_clusters=12,
        degree="powerlaw",
        sim_density=0.35,
        sim_cross_frac=0.08,
        dense_sim_noise=False,
        type_names=("drug", "disease", "target"),
        seed=seed,
        **kw,
    )
    import dataclasses as _dc

    spec = _dc.replace(base, sizes=sizes_for_edges(base, target))
    pk = planted_kpartite(spec)
    return _bundle_from_planted(
        "powerlaw",
        pk,
        (0, 2),
        meta={"target_edges": target, "edges": pk.network.num_edges},
    )


def _streaming_deltas(
    rng: np.random.Generator,
    heldout: np.ndarray,
    pair: TypePair,
    horizon_s: float,
    n_batches: int,
    add_nodes_type: Optional[int],
) -> Tuple[TimedDelta, ...]:
    """Timed delta stream re-adding the held-out planted edges."""
    entries = np.argwhere(heldout)
    rng.shuffle(entries)
    batches = np.array_split(entries, max(1, n_batches))
    out = []
    # deltas land strictly inside the horizon so a trace replay sees them
    times = np.linspace(0.15, 0.85, len(batches)) * horizon_s
    for b, (t, batch) in enumerate(zip(times, batches)):
        assoc = tuple(
            (pair, int(u), int(v), 1.0) for u, v in np.asarray(batch)
        )
        add = (
            {add_nodes_type: 2}
            if (add_nodes_type is not None and b == len(batches) // 2)
            else {}
        )
        out.append(
            TimedDelta(t=float(t), delta=GraphDelta(assoc=assoc, add_nodes=add))
        )
    return tuple(out)


@register_scenario(
    "streaming",
    description="delta stream re-adds held-out edges under a diurnal trace",
    tags=("streaming", "serve"),
)
def streaming(
    scale: float = 1.0,
    seed: int = 0,
    *,
    holdout_frac: float = 0.2,
    n_deltas: int = 8,
    rate_qps: float = 40.0,
    horizon_s: float = 4.0,
    trace_process: str = "diurnal",
    **kw,
) -> ScenarioBundle:
    spec = KPartiteSpec(
        sizes=scaled_sizes((60, 45, 30), scale),
        n_clusters=6,
        type_names=("drug", "disease", "target"),
        seed=seed,
        **kw,
    )
    pk = planted_kpartite(spec)
    pair: TypePair = (0, 2)
    rng = np.random.default_rng(seed + 1)
    planted = pk.truth[pair]
    pos = np.argwhere(planted)
    n_hold = max(1, int(len(pos) * holdout_frac))
    sel = pos[rng.choice(len(pos), size=n_hold, replace=False)]
    heldout = np.zeros_like(planted)
    heldout[sel[:, 0], sel[:, 1]] = True

    # t=0 network starts WITHOUT the held-out edges; truth matches it so
    # the CV/recovery protocols stay well-posed against the initial graph
    net0 = pk.network.with_masked_fold(pair, heldout)
    truth0 = dict(pk.truth)
    truth0[pair] = planted & ~heldout
    pk0 = PlantedKPartite(
        network=net0, clusters=pk.clusters, truth=truth0, spec=spec
    )
    deltas = _streaming_deltas(
        rng, heldout, pair, horizon_s, n_deltas, add_nodes_type=0
    )
    bundle = _bundle_from_planted(
        "streaming",
        pk0,
        pair,
        deltas=deltas,
        meta={
            "heldout_edges": int(n_hold),
            "holdout_frac": holdout_frac,
            "arriving_truth": heldout,
        },
    )
    bundle.trace = build_trace(
        bundle,
        trace_process,
        rate_qps=rate_qps,
        horizon_s=horizon_s,
        seed=seed,
    )
    return bundle


@register_scenario(
    "streaming_chaos",
    description="streaming under fault drills: denser delta churn plus a "
    "suggested kill plan for the ft injectors",
    tags=("streaming", "serve", "chaos"),
)
def streaming_chaos(
    scale: float = 1.0,
    seed: int = 0,
    *,
    n_deltas: int = 12,
    rate_qps: float = 60.0,
    horizon_s: float = 3.0,
    **kw,
) -> ScenarioBundle:
    """The chaos-drill workload (DESIGN.md §16.4).

    Same planted net + held-out delta stream as ``streaming``, but with
    more delta batches (every version bump churns the serve cache a
    guarded replay must survive) and a ``fault_plan`` in ``meta`` — the
    kill points the chaos specs feed into ``ft.inject_solve_fault`` /
    ``ft.inject_serve_fault``.  Injection stays spec-driven: the scenario
    only documents where a kill exercises the most recovery machinery
    (mid-solve after the first checkpoint; a serve batch after the first
    cache snapshot).
    """
    bundle = streaming(
        scale,
        seed,
        n_deltas=n_deltas,
        rate_qps=rate_qps,
        horizon_s=horizon_s,
        **kw,
    )
    bundle.name = "streaming_chaos"
    bundle.meta = {
        **bundle.meta,
        "fault_plan": {"solve_step": 3, "serve_attempt": 2},
    }
    return bundle
