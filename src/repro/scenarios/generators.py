"""The k-partite planted-structure generator — the repo's ONE generator idiom.

Every heterogeneous network the repo synthesizes (the tri-partite
drug–disease–target case study included — ``data/drugnet.py`` is now an
adapter over this module) comes from the same construction:

* latent *mechanism* clusters shared by all T node types;
* per-type similarity = intra-cluster affinity (+ optional noise floor);
* per-pair associations = Bernoulli draws, dense where the pair's
  cluster-match relation holds and rare noise elsewhere.

Because associations are *planted*, the generator returns the exact
positive set (``truth``) alongside the network, so CV / recovery
protocols evaluate against ground truth known by construction — the
same idea as the paper's Table 2, generalized to arbitrary type counts.

Two axes beyond the homophilic tri-partite case study
(PAPERS.md: Deng et al., *LP on K-partite Graphs with Heterophily*):

* **heterophily** — the planted relation maps cluster ``c`` of type i to
  cluster ``sigma(c) != c`` of type j (a fixed-point-free shift), so
  associations are CROSS-cluster while similarities stay intra-cluster;
* **power-law degrees** — per-node Pareto propensities multiply the edge
  probabilities (similarity support included), producing hubs and a
  heavy-tailed degree distribution at controlled expected edge counts.

RNG discipline: draws happen in a fixed order (clusters per type, then
similarities per type, then associations per sorted pair) so the
tri-partite default reproduces ``data/drugnet.py``'s historical streams
bit-for-bit; optional axes only draw when enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.network import HeteroNetwork, TypePair


@dataclasses.dataclass(frozen=True)
class KPartiteSpec:
    """Parameters of one planted k-partite network.

    ``pairs=()`` means every ``i < j`` pair carries an association block
    (complete type graph); pass an explicit schema for sparser ones.
    """

    sizes: Tuple[int, ...]
    pairs: Tuple[TypePair, ...] = ()
    n_clusters: int = 12
    # probability of an association where the planted relation holds /
    # noise probability elsewhere
    p_intra: float = 0.9
    p_noise: float = 0.0005
    # similarity strengths
    sim_intra: float = 0.8
    sim_noise: float = 0.02
    # heterophily: plant associations across a cluster shift, not the
    # diagonal (similarities stay homophilic)
    heterophily: bool = False
    # degree model: "uniform" or "powerlaw" (Pareto propensities)
    degree: str = "uniform"
    powerlaw_exponent: float = 2.0
    # powerlaw mode only: keep-probability scale of intra-cluster
    # similarity support (1.0 ~ dense blocks), the cross-cluster fraction
    # of that scale (lets hub degrees escape the cluster-size ceiling —
    # the heavy tail is unbounded in n, not capped at n/k), and whether
    # the similarity noise floor is dense (the drugnet convention) or
    # planted-only
    sim_density: float = 1.0
    sim_cross_frac: float = 0.0
    dense_sim_noise: bool = True
    type_names: Optional[Tuple[str, ...]] = None
    seed: int = 0

    def __post_init__(self) -> None:
        if len(self.sizes) < 2:
            raise ValueError("need at least two node types")
        if self.degree not in ("uniform", "powerlaw"):
            raise ValueError(f"unknown degree model {self.degree!r}")
        for i, j in self.resolved_pairs():
            if not (0 <= i < len(self.sizes) and 0 <= j < len(self.sizes)):
                raise ValueError(f"pair {(i, j)} out of range")
            if i >= j:
                raise ValueError(f"pairs must be canonical i < j, got {(i, j)}")

    def resolved_pairs(self) -> Tuple[TypePair, ...]:
        if self.pairs:
            return tuple(self.pairs)
        t = len(self.sizes)
        return tuple((i, j) for i in range(t) for j in range(i + 1, t))


@dataclasses.dataclass
class PlantedKPartite:
    """Generator output: the network plus its construction ground truth."""

    network: HeteroNetwork
    clusters: Tuple[np.ndarray, ...]
    #: boolean per-pair masks of PLANTED positives (noise edges excluded)
    truth: Dict[TypePair, np.ndarray]
    spec: KPartiteSpec


def _pair_shift(spec: KPartiteSpec, pair_index: int) -> int:
    """Fixed-point-free cluster shift for heterophilic pair #``pair_index``."""
    k = spec.n_clusters
    if k < 2:
        raise ValueError("heterophily needs n_clusters >= 2")
    return 1 + pair_index % (k - 1)


def _similarity(
    rng: np.random.Generator,
    clusters: np.ndarray,
    spec: KPartiteSpec,
    theta: Optional[np.ndarray],
) -> np.ndarray:
    n = clusters.shape[0]
    same = clusters[:, None] == clusters[None, :]
    if theta is None:
        base = np.where(same, spec.sim_intra, 0.0)
        noise = rng.random((n, n)) * spec.sim_noise
        sim = base + noise
    else:
        # power-law support: a similarity slot survives with probability
        # ~ theta_u * theta_v (hubs keep more neighbors); cross-cluster
        # slots at a `sim_cross_frac` discount so hub degrees are not
        # capped at the cluster size
        scale = np.where(
            same,
            spec.sim_density,
            spec.sim_density * spec.sim_cross_frac,
        )
        keep_p = np.minimum(1.0, scale * np.outer(theta, theta))
        keep = rng.random((n, n)) < keep_p
        sim = np.where(keep, spec.sim_intra, 0.0)
        if spec.dense_sim_noise:
            sim = sim + rng.random((n, n)) * spec.sim_noise
        else:
            sim = sim + keep * (rng.random((n, n)) * spec.sim_noise)
    sim = (sim + sim.T) / 2.0
    np.fill_diagonal(sim, 1.0)
    return sim


def _association(
    rng: np.random.Generator,
    ca: np.ndarray,
    cb: np.ndarray,
    spec: KPartiteSpec,
    pair_index: int,
    theta_a: Optional[np.ndarray],
    theta_b: Optional[np.ndarray],
) -> Tuple[np.ndarray, np.ndarray]:
    """Draw one association block; returns ``(R, planted_mask)``."""
    if spec.heterophily:
        shift = _pair_shift(spec, pair_index)
        match = ((ca[:, None] + shift) % spec.n_clusters) == cb[None, :]
    else:
        match = ca[:, None] == cb[None, :]
    p = np.where(match, spec.p_intra, spec.p_noise)
    if theta_a is not None:
        p = np.minimum(1.0, p * np.outer(theta_a, theta_b))
    edges = rng.random((ca.shape[0], cb.shape[0])) < p
    return edges.astype(np.float64), edges & match


def planted_kpartite(spec: KPartiteSpec) -> PlantedKPartite:
    """Generate the network + planted truth for ``spec``.

    Draw order (clusters, similarities, associations over sorted pairs)
    is part of the contract: the tri-partite uniform default reproduces
    the historical ``make_drugnet`` streams exactly.
    """
    rng = np.random.default_rng(spec.seed)
    clusters = tuple(
        rng.integers(0, spec.n_clusters, size=n).astype(np.int32)
        for n in spec.sizes
    )
    thetas: List[Optional[np.ndarray]]
    if spec.degree == "powerlaw":
        # mean-1 Pareto propensities (drawn only on this path so the
        # uniform path's RNG stream is untouched)
        a = spec.powerlaw_exponent
        thetas = []
        for n in spec.sizes:
            t = 1.0 + rng.pareto(a, size=n)
            thetas.append(t * (a - 1.0) / a if a > 1.0 else t)
    else:
        thetas = [None] * len(spec.sizes)
    P = [_similarity(rng, c, spec, th) for c, th in zip(clusters, thetas)]
    pairs = spec.resolved_pairs()
    R: Dict[TypePair, np.ndarray] = {}
    truth: Dict[TypePair, np.ndarray] = {}
    for idx, (i, j) in enumerate(sorted(pairs)):
        R[(i, j)], truth[(i, j)] = _association(
            rng, clusters[i], clusters[j], spec, idx, thetas[i], thetas[j]
        )
    net = HeteroNetwork(P=P, R=R, type_names=spec.type_names)
    return PlantedKPartite(
        network=net, clusters=clusters, truth=truth, spec=spec
    )


def sizes_for_edges(
    spec: KPartiteSpec, target_edges: int
) -> Tuple[int, ...]:
    """Scale ``spec.sizes`` proportionally so ``num_edges`` lands near
    ``target_edges`` (the paper's Tables 5/6 scale knob, generalized).

    Uses the expected-count model: dense-noise similarity contributes
    ``n_i**2`` nonzeros per type (the drugnet convention — the noise
    floor fills the block), planted-only similarity ``sim_density *
    n_i**2 / k``, and each pair ``2 * p_intra * n_i * n_j / k``.
    """
    r = np.asarray(spec.sizes, dtype=np.float64)
    r = r / r.max()
    k = spec.n_clusters
    if spec.degree == "powerlaw" and not spec.dense_sim_noise:
        # directed keep ≈ d·(1/k + c·(1−1/k)); symmetrized union ≈ ×2
        per_slot = spec.sim_density * (
            1.0 / k + spec.sim_cross_frac * (1.0 - 1.0 / k)
        )
        a_coef = 2.0 * per_slot * float((r**2).sum())
    else:
        a_coef = float((r**2).sum())
    b_coef = (
        2.0
        * spec.p_intra
        * sum(r[i] * r[j] for i, j in spec.resolved_pairs())
        / k
    )
    n_lead = int(np.sqrt(target_edges / max(a_coef + b_coef, 1e-12)))
    return tuple(max(4, int(n_lead * ri)) for ri in r)
