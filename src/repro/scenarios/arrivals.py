"""Arrival processes for serve query traces (DESIGN.md §12.3).

Three processes span the latency-tail axes a serving stack cares about:

* ``poisson`` — memoryless baseline: exponential inter-arrival gaps at a
  constant rate (what most QPS numbers implicitly assume);
* ``bursty`` — a two-state Markov-modulated Poisson process: quiet
  periods punctuated by bursts at ``burst_factor``× the quiet rate.
  Mean rate is held at ``rate_qps``, so bursty vs poisson isolates the
  effect of arrival *correlation* on p95/p99 (queueing, batch pileup);
* ``diurnal`` — an inhomogeneous Poisson process with sinusoidal rate
  (period = the horizon by default): the daily load curve compressed
  into the trace, peak rate ``(1 + diurnal_depth) * rate_qps``.

All generators return sorted arrival offsets in seconds from trace
start; entity selection (Zipf popularity over a node block) lives in
:func:`build_trace` so the same arrival stamps can replay against any
scenario.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.scenarios.base import QueryTrace, ScenarioBundle

ARRIVAL_PROCESSES: Tuple[str, ...] = ("poisson", "bursty", "diurnal")


def poisson_arrivals(
    rate_qps: float, horizon_s: float, rng: np.random.Generator
) -> np.ndarray:
    """Homogeneous Poisson: exponential gaps at ``rate_qps``."""
    if rate_qps <= 0 or horizon_s <= 0:
        raise ValueError("rate_qps and horizon_s must be > 0")
    # draw with slack, then trim to the horizon
    n = max(8, int(rate_qps * horizon_s * 1.5) + 8)
    t = np.cumsum(rng.exponential(1.0 / rate_qps, size=n))
    while t[-1] < horizon_s:  # pragma: no cover - slack almost always enough
        t = np.concatenate(
            [t, t[-1] + np.cumsum(rng.exponential(1.0 / rate_qps, size=n))]
        )
    return t[t < horizon_s]


def bursty_arrivals(
    rate_qps: float,
    horizon_s: float,
    rng: np.random.Generator,
    *,
    burst_factor: float = 8.0,
    burst_fraction: float = 0.15,
    mean_burst_s: Optional[float] = None,
) -> np.ndarray:
    """Two-state MMPP holding the mean rate at ``rate_qps``.

    The process spends ``burst_fraction`` of the time in the burst state
    (rate = ``burst_factor`` × quiet rate); the quiet rate is solved so
    the time-averaged rate equals ``rate_qps``.  Dwell times are
    exponential with burst mean ``mean_burst_s`` (default: horizon/20).
    """
    if not 0 < burst_fraction < 1:
        raise ValueError("burst_fraction must be in (0, 1)")
    if burst_factor <= 1:
        raise ValueError("burst_factor must be > 1")
    mean_burst = mean_burst_s or horizon_s / 20.0
    mean_quiet = mean_burst * (1.0 - burst_fraction) / burst_fraction
    quiet_rate = rate_qps / (
        burst_fraction * burst_factor + (1.0 - burst_fraction)
    )
    burst_rate = burst_factor * quiet_rate
    times = []
    t = 0.0
    bursting = rng.random() < burst_fraction  # stationary start
    while t < horizon_s:
        dwell = rng.exponential(mean_burst if bursting else mean_quiet)
        end = min(t + dwell, horizon_s)
        rate = burst_rate if bursting else quiet_rate
        span = end - t
        n = rng.poisson(rate * span)
        if n:
            times.append(t + np.sort(rng.random(n)) * span)
        t = end
        bursting = not bursting
    if not times:
        return np.empty(0, dtype=np.float64)
    return np.concatenate(times)


def diurnal_arrivals(
    rate_qps: float,
    horizon_s: float,
    rng: np.random.Generator,
    *,
    depth: float = 0.8,
    period_s: Optional[float] = None,
) -> np.ndarray:
    """Inhomogeneous Poisson via thinning: λ(t) = rate·(1 + depth·sin)."""
    if not 0 <= depth <= 1:
        raise ValueError("depth must be in [0, 1]")
    period = period_s or horizon_s
    lam_max = rate_qps * (1.0 + depth)
    cand = poisson_arrivals(lam_max, horizon_s, rng)
    lam = rate_qps * (1.0 + depth * np.sin(2.0 * np.pi * cand / period))
    keep = rng.random(cand.shape[0]) < lam / lam_max
    return cand[keep]


def arrival_times(
    process: str,
    rate_qps: float,
    horizon_s: float,
    rng: np.random.Generator,
    **kw,
) -> np.ndarray:
    if process == "poisson":
        return poisson_arrivals(rate_qps, horizon_s, rng, **kw)
    if process == "bursty":
        return bursty_arrivals(rate_qps, horizon_s, rng, **kw)
    if process == "diurnal":
        return diurnal_arrivals(rate_qps, horizon_s, rng, **kw)
    raise ValueError(
        f"unknown arrival process {process!r}; known: {ARRIVAL_PROCESSES}"
    )


def zipf_entities(
    n: int,
    count: int,
    rng: np.random.Generator,
    *,
    skew: float = 1.1,
) -> np.ndarray:
    """``count`` draws from a Zipf(skew) popularity law over ``n`` items.

    Item identity is shuffled so popularity is not correlated with node
    id (block layouts put similar nodes at nearby ids).
    """
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-skew)
    w /= w.sum()
    perm = rng.permutation(n)
    return perm[rng.choice(n, size=count, p=w)].astype(np.int32)


def build_trace(
    bundle: ScenarioBundle,
    process: str = "poisson",
    *,
    rate_qps: float = 50.0,
    horizon_s: float = 4.0,
    seed: int = 0,
    zipf_skew: float = 1.1,
    source_type: Optional[int] = None,
    target_type: Optional[int] = None,
    **kw,
) -> QueryTrace:
    """Generate a serve query trace for ``bundle``.

    Queries rank ``target_type`` candidates for entities of
    ``source_type`` (defaults: the bundle's ``eval_pair``), with Zipf
    popularity over the source block and arrival stamps from
    ``process``.
    """
    net = bundle.network
    st = bundle.eval_pair[0] if source_type is None else source_type
    tt = bundle.eval_pair[1] if target_type is None else target_type
    if not 0 <= st < net.num_types or not 0 <= tt < net.num_types:
        raise ValueError(f"source/target type out of range: {(st, tt)}")
    rng = np.random.default_rng(seed)
    t = arrival_times(process, rate_qps, horizon_s, rng, **kw)
    local = zipf_entities(net.sizes[st], len(t), rng, skew=zipf_skew)
    entity = (local + net.offsets[st]).astype(np.int32)
    return QueryTrace(
        t=np.asarray(t, dtype=np.float64),
        entity=entity,
        target_type=np.full(len(t), tt, dtype=np.int32),
        process=process,
        rate_qps=rate_qps,
        horizon_s=horizon_s,
    )
