"""Workload players for the online engine (DESIGN.md §9.5 / §12.3).

Two ways to drive an :class:`~repro.serve.engine.LPServeEngine` and
report QPS + latency percentiles, shared by ``Session.serve()``, the
legacy serve CLI shim, and ``benchmarks/serve_bench.py``:

* :func:`replay_trace` — replay a scenario :class:`QueryTrace` at its
  own arrival pace (clock optionally compressed), landing the
  scenario's timed GraphDelta stream between the submissions each delta
  precedes, exactly as a live feed would interleave them;
* :func:`play_zipf` — the synthetic zipf-popularity workload the
  original standalone serve CLI played: skewed repeat queries
  over one source type, with optional random association deltas
  interleaved at even intervals.
"""

from __future__ import annotations

import collections
import time
from typing import Dict, List

import numpy as np

from repro.core.network import GraphDelta
from repro.serve.types import DEFAULT_PRIORITY, QuerySpec, percentiles


def _observe_latencies(engine, telemetry, lats) -> None:
    """Post-hoc latency recording for a telemetry-blind scheduler.

    When the engine's batcher carries its own telemetry, every latency
    was already observed live at completion time (per-window SLO
    evaluation needs that); recording here again would double-count.
    This fallback keeps the standalone pairing — engine built without
    telemetry, player called with one — reporting a full histogram.
    """
    if telemetry is None or getattr(engine.batcher, "_tel", None) is not None:
        return
    for lat in lats:
        telemetry.observe("serve.latency_s", lat)


def _sample(result) -> Dict:
    """Provenance snapshot of one query result (artifact ``sample``)."""
    return {
        "entity": int(result.spec.entity),
        "target_type": int(result.spec.target_type),
        "top_k": int(result.spec.top_k),
        "candidates": [int(c) for c in result.candidates],
        "scores": [float(s) for s in result.scores],
    }


def replay_trace(
    engine,
    trace,
    deltas,
    *,
    top_k: int,
    time_scale: float,
    priority: str = DEFAULT_PRIORITY,
    telemetry=None,
) -> Dict:
    """Submit ``trace`` through the micro-batcher at its own pace.

    ``time_scale > 1`` compresses the clock (a 4s horizon replays in
    4/scale seconds — same arrival *pattern*, proportionally higher
    offered rate).  ``priority`` stamps every replayed query with an
    admission class.  The report includes ``achieved_vs_offered`` — the
    fraction of the offered rate the tier actually sustained (1.0 means
    it kept pace; lower means the trace outran it and queueing delay
    stretched the wall clock).
    """
    deltas = sorted(deltas, key=lambda d: d.t)
    di = 0
    futs = []
    engine.start()
    t0 = time.monotonic()
    for i in range(len(trace)):
        target = float(trace.t[i]) / time_scale
        while di < len(deltas) and deltas[di].t <= float(trace.t[i]):
            wait = deltas[di].t / time_scale - (time.monotonic() - t0)
            if wait > 0:
                time.sleep(wait)
            engine.apply_delta(deltas[di].delta)
            if telemetry is not None:
                telemetry.event("serve.delta", at=float(deltas[di].t))
            di += 1
        wait = target - (time.monotonic() - t0)
        if wait > 0:
            time.sleep(wait)
        futs.append(
            engine.submit(
                QuerySpec(
                    entity=int(trace.entity[i]),
                    target_type=int(trace.target_type[i]),
                    top_k=top_k,
                    priority=priority,
                )
            )
        )
        if telemetry is not None:
            telemetry.count("serve.replay.submitted")
            telemetry.maybe_flush()  # submit loop = arrival-side pump
    results = [f.result(timeout=600) for f in futs]
    wall = time.monotonic() - t0
    engine.stop()
    lats = [r.latency_s for r in results]
    _observe_latencies(engine, telemetry, lats)
    sources = [r.source for r in results]
    offered = len(trace) / (trace.horizon_s / time_scale)
    achieved = len(results) / wall
    out = {
        "queries": len(results),
        "offered_qps": offered,
        "qps": achieved,
        "achieved_vs_offered": achieved / offered if offered else 0.0,
        "wall_s": wall,
        "deltas_applied": di,
        "mean_rounds": float(np.mean([r.rounds for r in results])),
        "sources": {s: sources.count(s) for s in set(sources)},
        "batches": engine.batcher.stats.batches,
        "mean_batch_size": engine.batcher.stats.mean_batch_size,
        "latencies": lats,
        "sample": _sample(results[0]),
    }
    out.update(percentiles(lats))
    return out


def play_zipf(
    engine,
    *,
    source_type: int,
    target_type: int,
    requests: int,
    zipf: float,
    deltas: int,
    top_k: int,
    seed: int,
    echo=None,
    telemetry=None,
) -> Dict:
    """Zipf-popular entities of ``source_type`` querying ``target_type``
    candidates, with ``deltas`` random associations landing online at
    even intervals through the workload."""
    net = engine.state.net
    rng = np.random.default_rng(seed)
    n_src = net.sizes[source_type]
    off_src = net.offsets[source_type]
    ranks = rng.permutation(n_src)
    draws = np.minimum(rng.zipf(zipf, size=requests), n_src) - 1
    entities = ranks[draws] + off_src
    delta_at = (
        set(np.linspace(0, requests, deltas + 2, dtype=int)[1:-1])
        if deltas
        else set()
    )
    pair = (
        (source_type, target_type)
        if source_type < target_type
        else (target_type, source_type)
    )

    futures = []
    events: List[Dict] = []
    engine.start()
    t0 = time.monotonic()
    for i, ent in enumerate(entities):
        if i in delta_at:
            # a fresh source→target association lands online
            u = int(rng.integers(net.sizes[source_type]))
            v = int(rng.integers(net.sizes[target_type]))
            a, b = (u, v) if source_type < target_type else (v, u)
            version = engine.apply_delta(GraphDelta(assoc=[(pair, a, b, 1.0)]))
            events.append({"at": int(i), "u": u, "v": v, "version": int(version)})
            if telemetry is not None:
                telemetry.event("serve.delta", at=int(i), version=int(version))
            if echo:
                echo(
                    f"[serve] delta @req {i}: +assoc type{source_type} {u} "
                    f"→ type{target_type} {v} (version {version})"
                )
        futures.append(
            engine.submit(
                QuerySpec(entity=int(ent), target_type=target_type, top_k=top_k)
            )
        )
        if telemetry is not None:
            telemetry.count("serve.replay.submitted")
            telemetry.maybe_flush()  # submit loop = arrival-side pump
    results = [f.result(timeout=600) for f in futures]
    wall = time.monotonic() - t0
    engine.stop()

    lats = [r.latency_s for r in results]
    _observe_latencies(engine, telemetry, lats)
    by_source = collections.Counter(r.source for r in results)
    rounds_by = collections.defaultdict(list)
    for r in results:
        rounds_by[r.source].append(r.rounds)
    bstats = engine.batcher.stats
    cstats = engine.columns.stats
    out = {
        "queries": len(results),
        "qps": len(results) / wall,
        "wall_s": wall,
        "sources": dict(by_source),
        "mean_rounds_by_source": {s: float(np.mean(v)) for s, v in rounds_by.items()},
        "deltas": events,
        "batches": bstats.batches,
        "mean_batch_size": bstats.mean_batch_size,
        "rejected": bstats.rejected,
        "cache_hit_rate": cstats.hit_rate,
        "cache_evictions": cstats.evictions,
        "cache_demoted": cstats.invalidations,
        "latencies": lats,
        "sample": _sample(results[0]),
    }
    out.update(percentiles(lats))
    return out
