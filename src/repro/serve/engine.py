"""The online LP query engine (DESIGN.md §9).

Layers the dense/sparse batched solvers behind a query interface:

* ``query``/``submit`` — rank top-k candidates of a target type for one
  entity.  Repeat queries hit the column LRU; cold queries warm-start from
  the cached column of the most-similar same-type node when one exists.
* ``apply_delta`` — incremental graph update: bump the network version,
  demote affected cached columns to warm-start hints, and let subsequent
  queries re-converge from the stale state (delta propagation) instead of
  from scratch.

The batch tick is split into two stages so the scheduler can pipeline
them (DESIGN.md §9.1):

* :meth:`_assemble_batch` — queue-side, cheap: snapshot the network
  state, probe the (sharded) column cache, build the seed/warm-start
  matrices for the misses.  Runs WITHOUT the engine lock; the cache's
  per-shard locks are its only synchronization.
* :meth:`_execute_batch` — engine-side, the long pole: one batched solve
  for the misses, cache write-back, per-request ranking.  Serialized
  against ``apply_delta`` by the engine lock.

A delta landing between the two stages is benign: the solve runs against
the *assembled* snapshot (consistent answers, correct version stamp) and
the write-back demotes to a warm-start hint instead of publishing a
column under the wrong version.

Serving always runs the solver in **fixed-seed mode**: the fixed point
``F* = β²(I − A)⁻¹Y`` is then independent of the iteration's starting
state, which is exactly the property warm-starting relies on.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.network import GraphDelta, HeteroNetwork
from repro.core.ranking import topk_exclusive
from repro.core.solver import LPConfig, SolveResult
from repro.engine import make_engine, resolve_backend
from repro.serve.cache import NetworkState, ShardedColumnCache
from repro.serve.scheduler import MicroBatcher
from repro.serve.types import QueryResult, QuerySpec


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine + scheduler + cache knobs."""

    lp: LPConfig = LPConfig(alg="dhlp2", seed_mode="fixed")
    # any `repro.engine` registry backend incl. "auto".  "sharded" serves
    # on the host's full device set (auto never selects it — running a
    # pod-backed deployment is an explicit choice); its solve AND round
    # paths both run sharded, so incremental hint refresh stays on-mesh.
    # None defers to lp.backend, then "dense"; setting BOTH this and
    # lp.backend to different keys is a conflict, not a silent precedence.
    engine: Optional[str] = None
    cache_columns: int = 4096        # column-LRU capacity
    cache_shards: int = 1            # independently-locked cache shards
    warm_start: bool = True          # neighbor/stale warm starts
    carry_untouched: bool = True     # keep untouched-type columns on delta
    # after a delta, advance demoted stale hints this many fused LP rounds
    # against the NEW operator (engine.round) so the next query's warm
    # start is already partway to the moved fixed point (dhlp2 only — the
    # round contract is the fused DHLP-2 update)
    refresh_rounds: int = 0
    max_batch: int = 64
    max_wait_s: float = 0.005
    queue_depth: int = 1024
    # batches in flight between assembly start and future resolution; 1 =
    # the synchronous tick, 2 = double-buffered (assemble next while the
    # engine solves current)
    pipeline_depth: int = 1
    # convergence-aware batch solves: per-column residual checks drop
    # converged columns from subsequent rounds (the BSP no-activity halt,
    # per column).  dhlp2 + no momentum only — the loop is built on the
    # engine.round contract.
    early_exit: bool = False

    def resolved_engine(self) -> str:
        """Backend key serving will use (before any ``auto`` resolution)."""
        return self.engine or self.lp.backend or "dense"

    def __post_init__(self):
        if (
            self.engine is not None
            and self.lp.backend is not None
            and self.engine != self.lp.backend
        ):
            raise ValueError(
                f"ServeConfig.engine={self.engine!r} conflicts with "
                f"LPConfig.backend={self.lp.backend!r}; set one (or both "
                "to the same key)"
            )
        resolved = self.resolved_engine()
        if resolved != "auto":
            from repro.engine import UnknownBackendError, get_backend_class

            try:
                resolve_backend(resolved)
            except UnknownBackendError as e:
                raise ValueError(f"unknown engine {resolved!r}: {e}") from e
            cls = get_backend_class(resolved)
            if self.lp.alg not in cls.supports_algs:
                # fail at construction, not at the first query batch —
                # a bad config inside a coalesced batch fails every
                # co-batched request
                raise ValueError(
                    f"engine {resolved!r} does not support alg "
                    f"{self.lp.alg!r} (supports {cls.supports_algs})"
                )
            if self.lp.momentum and not cls.supports_momentum:
                raise ValueError(
                    f"engine {resolved!r} has no momentum loop "
                    f"(LPConfig.momentum={self.lp.momentum})"
                )
        if self.refresh_rounds < 0:
            raise ValueError("refresh_rounds must be >= 0")
        if self.refresh_rounds and self.lp.alg != "dhlp2":
            # engine.round is the fused DHLP-2 update; advancing DHLP-1
            # hints with it would walk them toward the WRONG fixed point.
            raise ValueError(
                "refresh_rounds requires alg='dhlp2' (the round contract "
                "is the fused DHLP-2 update)"
            )
        if self.lp.resolved_seed_mode() != "fixed":
            # Warm starts and incremental re-solves need the F0-independent
            # fixed point; drift mode's answer depends on the start state.
            raise ValueError(
                "serving requires fixed-seed mode "
                "(LPConfig(seed_mode='fixed'))"
            )
        if self.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        if self.cache_shards < 1:
            raise ValueError("cache_shards must be >= 1")
        if self.cache_shards > self.cache_columns:
            raise ValueError(
                f"cache_shards={self.cache_shards} > "
                f"cache_columns={self.cache_columns}: every shard needs "
                "at least one slot"
            )
        if self.early_exit and self.lp.alg != "dhlp2":
            raise ValueError(
                "early_exit requires alg='dhlp2' (the per-column residual "
                "loop is built on the fused DHLP-2 engine.round contract)"
            )
        if self.early_exit and self.lp.momentum:
            raise ValueError(
                "early_exit and momentum are mutually exclusive — the "
                "early-exit round loop is the plain heavy-ball-free update"
            )


@dataclasses.dataclass
class _FTKit:
    """Serve-side durability state attached by :meth:`LPServeEngine.enable_ft`.

    ``attempts`` counts every entry into the guarded execute stage (so the
    injector's step key is unique per *attempt* and a retried batch gets a
    fresh key — a fault fires once, not on every replay); ``completed``
    counts successful batches and drives the checkpoint cadence.
    """

    guard: Optional[Any] = None
    straggler: Optional[Any] = None
    injector: Optional[Any] = None
    manager: Optional[Any] = None
    interval: int = 5
    attempts: int = 0
    completed: int = 0
    checkpoints: int = 0
    watermark: int = -1      # network version of the last durable snapshot
    ckpt_dir: Optional[str] = None
    closed: bool = False


@dataclasses.dataclass
class PreparedBatch:
    """Everything stage 2 needs, snapshotted by stage 1.

    ``state`` pins the network version the batch was assembled against;
    the solve and the ranking both use it, so a mid-flight delta cannot
    split one batch across two versions.
    """

    state: NetworkState
    specs: List[QuerySpec]
    cols: Dict[int, Optional[np.ndarray]]   # entity -> column (None = miss)
    sources: Dict[int, str]
    rounds: Dict[int, int]
    miss_nodes: List[int]
    Y: Optional[np.ndarray]                 # (N, misses) seed columns
    F0: Optional[np.ndarray]                # warm/seed starting state
    warm: List[bool]                        # per miss: warm-started?


class LPServeEngine:
    """Query front-end over a (mutable, versioned) heterogeneous network."""

    def __init__(
        self,
        net: HeteroNetwork,
        config: ServeConfig = ServeConfig(),
        *,
        engine=None,
        norm=None,
        telemetry=None,
    ):
        """``engine``/``norm`` let a :class:`repro.api.session.Session`
        inject its already-prepared LP engine and normalized view, so the
        serve path reuses the operator assembled for the solve stage
        instead of re-preparing per entry point (DESIGN.md §13).
        ``telemetry`` threads one :class:`repro.obs.Telemetry` into the
        batcher and column cache (DESIGN.md §14)."""
        self.config = config
        self._state = NetworkState.from_network(net, version=0, norm=norm)
        backend = resolve_backend(
            config.resolved_engine(), num_nodes=net.num_nodes,
            config=config.lp,
        )
        if engine is not None:
            if engine.name != backend:
                raise ValueError(
                    f"injected engine backend {engine.name!r} conflicts "
                    f"with ServeConfig's resolved engine {backend!r}"
                )
            if engine.config != config.lp:
                raise ValueError(
                    "injected engine's LPConfig differs from "
                    "ServeConfig.lp — serving would answer from different "
                    "math than the engine was prepared with"
                )
            self._engine = engine
        else:
            self._engine = make_engine(backend, config.lp)
        self.columns = ShardedColumnCache(
            config.cache_columns,
            shards=config.cache_shards,
            telemetry=telemetry,
        )
        self.batcher = MicroBatcher(
            self._solve_batch,
            max_batch=config.max_batch,
            max_wait_s=config.max_wait_s,
            queue_depth=config.queue_depth,
            pipeline_depth=config.pipeline_depth,
            assemble=self._assemble_batch,
            execute=self._execute_batch,
            telemetry=telemetry,
        )
        self._tel = telemetry
        # early-exit residual-threshold multiplier: the SLO watchdog's
        # second degradation rung widens it (columns leave the active set
        # sooner -> cheaper solves, coarser tails) and restores it to 1.0
        # on recovery
        self._sigma_scale = 1.0
        # one solve/update at a time: the engines' prepared-operator caches
        # are single-entry and not concurrency-safe; the sharded column
        # cache carries its own locks, so assembly stays outside this lock
        self._lock = threading.Lock()
        self._ft: Optional[_FTKit] = None

    # ------------------------------------------------------------ accessors
    @property
    def state(self) -> NetworkState:
        return self._state

    @property
    def version(self) -> int:
        return self._state.version

    @property
    def sigma_scale(self) -> float:
        return self._sigma_scale

    def set_sigma_scale(self, scale: float) -> None:
        """Runtime early-exit degradation knob (>= 1.0 widens σ).

        Only the early-exit solve path honors it; on a full-superstep
        engine the knob is recorded but inert.
        """
        if scale < 1.0:
            raise ValueError(f"sigma_scale must be >= 1.0, got {scale}")
        self._sigma_scale = float(scale)
        if self._tel is not None:
            self._tel.gauge("serve.early_exit.sigma_scale", self._sigma_scale)

    # -------------------------------------------------------------- queries
    def _validate(self, spec: QuerySpec, state: NetworkState) -> None:
        """Reject bad specs at the edge, before they join a batch.

        A bad spec inside a coalesced batch would fail every co-batched
        request; validity is stable once checked — the node-id space only
        ever grows (``GraphDelta.add_nodes``) and the type count is fixed.
        """
        if not 0 <= spec.entity < state.num_nodes:
            raise ValueError(
                f"entity {spec.entity} out of range [0,{state.num_nodes})"
            )
        if not 0 <= spec.target_type < state.net.num_types:
            raise ValueError(f"no such type {spec.target_type}")

    def submit(self, spec: QuerySpec, **kw) -> "Future[QueryResult]":
        """Enqueue for the micro-batcher (needs ``start()`` or ``drain()``)."""
        self._validate(spec, self._state)
        return self.batcher.submit(spec, **kw)

    def query(self, spec: QuerySpec) -> QueryResult:
        """Synchronous single query (a batch of one on a cache miss)."""
        return self._solve_batch([spec])[0]

    def start(self) -> None:
        self.batcher.start()

    def stop(self) -> None:
        self.batcher.stop()

    # ------------------------------------------------------ stage 1: assemble
    def _assemble_batch(self, specs: Sequence[QuerySpec]) -> PreparedBatch:
        """Cache probe + seed/warm-start assembly (no engine lock)."""
        state = self._state  # one atomic snapshot for the whole batch
        n = state.num_nodes
        for spec in specs:
            self._validate(spec, state)  # no-op for specs vetted at submit()

        # split hits from misses; dedupe miss columns within the batch
        cols: Dict[int, Optional[np.ndarray]] = {}
        sources: Dict[int, str] = {}
        rounds: Dict[int, int] = {}
        miss_nodes: List[int] = []
        for spec in specs:
            node = spec.entity
            if node in cols:
                continue
            cached = self.columns.get(state.version, node)
            if cached is not None:
                cols[node] = cached
                sources[node] = "cache"
                rounds[node] = 0
            else:
                cols[node] = None  # placeholder, solved in stage 2
                miss_nodes.append(node)

        Y = F0 = None
        warm: List[bool] = []
        if miss_nodes:
            warm_index = (
                self._cached_by_type(state) if self.config.warm_start else {}
            )
            Y = np.zeros((n, len(miss_nodes)), dtype=np.float64)
            F0 = np.zeros_like(Y)
            for c, node in enumerate(miss_nodes):
                Y[node, c] = 1.0
                hint = (
                    self._warm_hint(node, warm_index, state)
                    if self.config.warm_start
                    else None
                )
                if hint is not None:
                    F0[:, c] = hint
                    warm.append(True)
                else:
                    F0[:, c] = Y[:, c]
                    warm.append(False)
        return PreparedBatch(
            state=state, specs=list(specs), cols=cols, sources=sources,
            rounds=rounds, miss_nodes=miss_nodes, Y=Y, F0=F0, warm=warm,
        )

    # ------------------------------------------------------- stage 2: execute
    def _execute_batch(self, prepared: PreparedBatch) -> List[QueryResult]:
        """Stage-2 entry point; adds the FT envelope when enabled.

        The fault injector keys on the *attempt* index (unique per entry,
        including guarded replays of the same :class:`PreparedBatch`), the
        straggler watch times the whole execute, and every ``interval``
        completed batches the current version's cache columns go through
        the checkpoint manager.  With FT disabled this is a direct call.
        """
        ft = self._ft
        if ft is None:
            return self._execute_batch_impl(prepared)
        idx = ft.attempts
        ft.attempts += 1
        if ft.injector is not None:
            ft.injector.maybe_fail(idx)
        t0 = time.perf_counter()
        out = self._execute_batch_impl(prepared)
        if ft.straggler is not None:
            ft.straggler.observe(time.perf_counter() - t0)
        ft.completed += 1
        if (
            ft.manager is not None
            and not ft.closed
            and ft.completed % ft.interval == 0
        ):
            self._ft_checkpoint()
        return out

    def _execute_batch_impl(self, prepared: PreparedBatch) -> List[QueryResult]:
        """Batched solve + cache write-back + ranking (engine lock held)."""
        with self._lock:
            state = prepared.state
            cols, sources, rounds = (
                prepared.cols, prepared.sources, prepared.rounds,
            )
            if prepared.miss_nodes:
                result = self._run_solver(state, prepared.Y, prepared.F0)
                per_col = (
                    result.per_column_iters
                    if result.per_column_iters is not None
                    else np.full(
                        len(prepared.miss_nodes), result.outer_iters, np.int32
                    )
                )
                # a delta may have landed after assembly: publishing under
                # state.version would be a dead key, so demote to a
                # warm-start hint instead (same treatment the delta gives
                # live columns)
                stale = self._state.version != state.version
                for c, node in enumerate(prepared.miss_nodes):
                    col = result.F[:, c]
                    cols[node] = col
                    sources[node] = "warm" if prepared.warm[c] else "cold"
                    rounds[node] = int(per_col[c])
                    if stale:
                        self.columns.put_stale(node, col)
                    else:
                        self.columns.put(state.version, node, col)
            return [
                self._rank(spec, cols[spec.entity], sources[spec.entity],
                           rounds[spec.entity], state)
                for spec in prepared.specs
            ]

    # ------------------------------------------------------------- the tick
    def _solve_batch(self, specs: Sequence[QuerySpec]) -> List[QueryResult]:
        """One-stage tick: the synchronous drivers' (and tests') path."""
        return self._execute_batch(self._assemble_batch(specs))

    # ------------------------------------------------------- fault tolerance
    def enable_ft(
        self,
        *,
        guard=None,
        straggler=None,
        injector=None,
        manager=None,
        interval: int = 5,
    ) -> None:
        """Attach the durability kit (DESIGN.md §16).

        ``guard`` (a :class:`repro.ft.StepGuard`) is installed on the
        batcher so solver-thread batch execution retries transient
        failures; its ``restore_fn`` is pointed at :meth:`_ft_restore`, so
        retry exhaustion rolls the column cache back to the last durable
        snapshot and the in-flight batch replays against restored state.
        ``manager`` (a :class:`repro.checkpoint.CheckpointManager`) takes
        an immediate snapshot — the restore watermark exists before the
        first fault can.
        """
        if interval < 1:
            raise ValueError(f"interval must be >= 1, got {interval}")
        self._ft = _FTKit(
            guard=guard,
            straggler=straggler,
            injector=injector,
            manager=manager,
            interval=interval,
            ckpt_dir=getattr(manager, "root", None),
        )
        if guard is not None:
            guard.restore_fn = self._ft_restore
            self.batcher.guard = guard
        if manager is not None:
            self._ft_checkpoint()

    def _ft_checkpoint(self) -> None:
        """Snapshot the current version's cached columns durably.

        Stats-neutral read (``cache.snapshot``), saved as two leaves —
        node ids and the stacked float64 column panel — plus the network
        version in metadata: the restore's invalidation watermark.
        """
        ft = self._ft
        version = self._state.version
        snap = self.columns.snapshot(version)
        nodes = np.array([n for n, _ in snap], dtype=np.int64)
        cols = (
            np.stack([c for _, c in snap], axis=1).astype(np.float64)
            if snap
            else np.zeros((self._state.num_nodes, 0), dtype=np.float64)
        )
        ft.manager.save(
            ft.checkpoints,
            [nodes, cols],
            metadata={"version": version, "kind": "serve-cache",
                      "completed": ft.completed},
        )
        ft.checkpoints += 1
        ft.watermark = version
        if self._tel is not None:
            self._tel.count("ft.checkpoints")

    def _ft_restore(self) -> None:
        """Roll the column cache back to the last durable snapshot.

        Columns published after the snapshot's version watermark are
        dropped outright (they may carry state from the failed execution);
        snapshot columns re-enter as servable entries when the version
        still matches, else as warm-start hints.  The replayed batch then
        re-solves its misses against clean state.
        """
        ft = self._ft
        with self._lock:
            if ft is None or ft.manager is None:
                # no durable snapshot to return to: drop every cached
                # column — replays re-solve from seeds, which is safe
                self.columns.invalidate_newer(-1)
                return
            step, leaves, meta = ft.manager.restore_latest_flat()
            watermark = int(meta.get("version", -1)) if step is not None else -1
            self.columns.invalidate_newer(watermark)
            if step is None or not leaves:
                return
            nodes, cols = leaves[0], leaves[1]
            n = self._state.num_nodes
            fresh = watermark == self._state.version
            for i, node in enumerate(np.asarray(nodes, dtype=np.int64)):
                col = np.asarray(cols[:, i], dtype=np.float64)
                if fresh and col.shape[0] == n:
                    self.columns.put(watermark, int(node), col)
                elif col.shape[0] == n:
                    self.columns.put_stale(int(node), col)

    def ft_stats(self) -> Dict[str, Any]:
        """Durability roll-up for the serve artifact (empty when FT off)."""
        ft = self._ft
        if ft is None:
            return {}
        out: Dict[str, Any] = {
            "batches": ft.completed,
            "checkpoints": ft.checkpoints,
            "watermark": ft.watermark,
        }
        if ft.guard is not None:
            out["retries"] = ft.guard.retries
            out["restores"] = ft.guard.restores
        if ft.straggler is not None:
            out["straggler_flags"] = ft.straggler.slow_steps
        if ft.injector is not None:
            out["injected_faults"] = list(ft.injector.fired)
        if ft.ckpt_dir is not None:
            out["ckpt_dir"] = ft.ckpt_dir
        return out

    def close_ft(self) -> None:
        """Final snapshot + writer-thread shutdown (idempotent).

        Keeps ``ft_stats()`` readable after close — the Session reads the
        roll-up into the serve artifact after draining the trace.
        """
        ft = self._ft
        if ft is None or ft.closed:
            return
        if ft.manager is not None:
            self._ft_checkpoint()
            ft.manager.close()
        ft.closed = True

    def _run_solver(
        self, state: NetworkState, Y: np.ndarray, F0: np.ndarray
    ) -> SolveResult:
        # every registered engine caches its prepared operator on the
        # normalized network's identity, so repeat batches skip re-assembly
        if self.config.early_exit:
            return self._solve_early_exit(state, Y, F0)
        return self._engine.run(state.norm, seeds=Y, F0=F0)

    def _solve_early_exit(
        self, state: NetworkState, Y: np.ndarray, F0: np.ndarray
    ) -> SolveResult:
        """Batched solve with per-column convergence early exit.

        The BSP no-activity halt, per column: after each fused round the
        per-column residual ``max|F_{t+1} − F_t|`` is checked against σ
        and converged columns leave the active set — subsequent rounds
        run a strictly narrower matmul.  Fixed-seed mode makes this exact
        (each column's fixed point is independent of its co-batch), so
        the result matches the full-superstep solve to iteration
        tolerance; dtype is float64 end to end via ``engine.round``.

        The active width is padded up to the next power of two with zero
        columns (a zero seed + zero state is a fixed point, so the pad
        is inert) — the jitted round then compiles at most
        ``log2(max_batch)`` programs total, where per-exact-width shapes
        would recompile on nearly every narrowing.  This also bounds the
        compile set across batches: the legacy full-superstep solver
        retraces its whole while-loop program for every distinct
        miss-count a tick produces.
        """
        cfg = self.config.lp
        op = self._engine.prepare(state.norm)
        n = F0.shape[0]
        F = np.array(F0, dtype=np.float64)
        Y = np.asarray(Y, dtype=np.float64)
        k = F.shape[1]
        col_iters = np.zeros(k, dtype=np.int32)
        active = np.arange(k)
        it = 0
        while active.size and it < cfg.max_iter:
            a = int(active.size)
            width = 1 << (a - 1).bit_length()  # next power of two
            Fa = np.zeros((n, width), dtype=np.float64)
            Ya = np.zeros((n, width), dtype=np.float64)
            Fa[:, :a] = F[:, active]
            Ya[:, :a] = Y[:, active]
            # fused superstep: the engine emits the per-column residual
            # from the same launch as the round (no host-side reduction)
            Fn, delta = self._engine.round_with_residual(op, Fa, Ya)
            Fn = np.asarray(Fn, dtype=np.float64)[:, :a]
            delta = np.asarray(delta, dtype=np.float64)[:a]
            F[:, active] = Fn
            col_iters[active] += 1
            active = active[delta >= cfg.sigma * self._sigma_scale]
            it += 1
        return SolveResult(
            F=F,
            outer_iters=int(col_iters.max(initial=0)),
            inner_iters=0,
            converged=(active.size == 0),
            per_column_iters=col_iters,
        )

    def _cached_by_type(self, state: NetworkState) -> Dict[int, List[int]]:
        """Group the current version's cached nodes by type, once per tick."""
        by_type: Dict[int, List[int]] = {}
        for other in self.columns.cached_nodes(state.version):
            by_type.setdefault(int(state.type_of[other]), []).append(other)
        return by_type

    def _warm_hint(
        self,
        node: int,
        by_type: Dict[int, List[int]],
        state: NetworkState,
    ) -> Optional[np.ndarray]:
        """Warm-start column for a cold node.

        Preference order: the node's own stale column from before the last
        delta (delta propagation), else the fresh column of the
        most-similar cached node of the same type (neighbor warm start —
        one vectorized similarity-row lookup, not a per-node scan).
        """
        stale = self.columns.stale_hint(node)
        if stale is not None and stale.shape[0] == state.num_nodes:
            return stale
        t, u = state.local_id(node)
        cands = [o for o in by_type.get(t, ()) if o != node]
        if not cands:
            return None
        sims = state.net.P[t][u, np.asarray(cands) - state.offsets[t]]
        best = int(np.argmax(sims))
        if sims[best] <= 0.0:
            return None
        return self.columns.get(state.version, cands[best])

    # -------------------------------------------------------------- ranking
    def _rank(
        self,
        spec: QuerySpec,
        col: np.ndarray,
        source: str,
        rounds: int,
        state: NetworkState,
    ) -> QueryResult:
        t_ent, u = state.local_id(spec.entity)
        tt = spec.target_type
        off = state.offsets[tt]
        scores = np.asarray(col[off : off + state.sizes[tt]], dtype=np.float64)
        exclude = np.zeros(scores.shape[0], dtype=bool)
        if not spec.include_known:
            R = state.net.R
            if (t_ent, tt) in R:
                exclude |= R[(t_ent, tt)][u] > 0
            elif (tt, t_ent) in R:
                exclude |= R[(tt, t_ent)][:, u] > 0
        if t_ent == tt:
            exclude[u] = True  # an entity is not its own candidate
        cand = topk_exclusive(scores, spec.top_k, exclude)
        return QueryResult(
            spec=spec,
            candidates=cand,
            scores=scores[cand],
            target_offset=off,
            version=state.version,
            source=source,
            rounds=rounds,
        )

    # ------------------------------------------------------ incremental path
    def apply_delta(self, delta: GraphDelta) -> int:
        """Apply a graph edit; returns the new network version.

        Cached columns whose types the delta touches are demoted to
        warm-start hints; untouched-type columns are carried forward when
        ``carry_untouched`` (approximation: their values shift by at most
        the delta's propagated mass — see DESIGN.md §9.3).  When the delta
        adds nodes every column demotes (the id space changed shape) and
        stale hints are remapped into the new layout.
        """
        with self._lock:
            if delta.is_empty:
                return self._state.version
            old = self._state
            new_net = old.net.apply_delta(delta)
            new = NetworkState.from_network(new_net, old.version + 1)
            remap = None
            if delta.add_nodes:
                remap = _make_remap(old, new)
            self.columns.invalidate_for_delta(
                old.version,
                new.version,
                delta.touched_types(),
                old.type_of,
                remap=remap,
                carry_untouched=self.config.carry_untouched,
            )
            self._state = new
            self._maybe_rescale_engine()
            if self.config.refresh_rounds:
                self._refresh_stale_hints()
            return new.version

    def _maybe_rescale_engine(self) -> None:
        """Re-resolve an ``auto`` engine after the network changed size.

        Node-adding deltas can push the network across the dense/sparse
        policy boundary (§11); an ``auto`` deployment must not keep
        rebuilding an O(N²) dense operator forever.  Explicitly pinned
        engines are left alone.  Called under ``self._lock``.
        """
        if self.config.resolved_engine() != "auto":
            return
        backend = resolve_backend(
            "auto", num_nodes=self._state.num_nodes, config=self.config.lp
        )
        if backend != self._engine.name:
            self._engine = make_engine(backend, self.config.lp)

    def _refresh_stale_hints(self) -> int:
        """Advance demoted hints toward the new fixed point (§9.3).

        One batched ``engine.round`` per refresh round: the fused update
        ``β²Y + A_eff @ F`` is a contraction toward the NEW operator's
        fixed point, so k rounds leave every hint k rounds closer — the
        next query's warm start re-converges in fewer rounds without
        paying a full solve at delta time.  Called under ``self._lock``.
        """
        state = self._state
        n = state.num_nodes
        hints = {
            v: h
            for v in self.columns.stale_nodes()
            if (h := self.columns.stale_hint(v)) is not None
            and h.shape[0] == n
        }
        if not hints:
            return 0
        op = self._engine.prepare(state.norm)
        # the stale set is unbounded across deltas while queries cap work
        # at max_batch — chunk the refresh the same way (f32 slabs) so a
        # large accumulation cannot blow up memory inside the lock
        nodes = list(hints)
        width = max(1, self.config.max_batch)
        for i in range(0, len(nodes), width):
            batch = nodes[i : i + width]
            Y = np.zeros((n, len(batch)), dtype=np.float32)
            F = np.empty_like(Y)
            for c, v in enumerate(batch):
                Y[v, c] = 1.0
                F[:, c] = hints[v]
            for _ in range(self.config.refresh_rounds):
                F = self._engine.round(op, F, Y)
            for c, v in enumerate(batch):
                self.columns.put_stale(v, F[:, c])
        return len(nodes)


def _make_remap(old: NetworkState, new: NetworkState):
    """Old-layout → new-layout column scatter (types keep their prefixes)."""

    def remap(col: np.ndarray) -> np.ndarray:
        out = np.zeros(new.num_nodes, dtype=np.float64)
        for t, (o_off, o_n) in enumerate(zip(old.offsets, old.sizes)):
            out[new.offsets[t] : new.offsets[t] + o_n] = col[o_off : o_off + o_n]
        return out

    return remap
