"""The online LP query engine (DESIGN.md §9).

Layers the dense/sparse batched solvers behind a query interface:

* ``query``/``submit`` — rank top-k candidates of a target type for one
  entity.  Repeat queries hit the column LRU; cold queries warm-start from
  the cached column of the most-similar same-type node when one exists.
* ``apply_delta`` — incremental graph update: bump the network version,
  demote affected cached columns to warm-start hints, and let subsequent
  queries re-converge from the stale state (delta propagation) instead of
  from scratch.

Serving always runs the solver in **fixed-seed mode**: the fixed point
``F* = β²(I − A)⁻¹Y`` is then independent of the iteration's starting
state, which is exactly the property warm-starting relies on.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.network import GraphDelta, HeteroNetwork
from repro.core.ranking import topk_exclusive
from repro.core.solver import LPConfig
from repro.engine import make_engine, resolve_backend
from repro.serve.cache import ColumnCache, NetworkState
from repro.serve.scheduler import MicroBatcher
from repro.serve.types import QueryResult, QuerySpec


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine + scheduler + cache knobs."""

    lp: LPConfig = LPConfig(alg="dhlp2", seed_mode="fixed")
    # any `repro.engine` registry backend incl. "auto".  "sharded" serves
    # on the host's full device set (auto never selects it — running a
    # pod-backed deployment is an explicit choice); its solve AND round
    # paths both run sharded, so incremental hint refresh stays on-mesh.
    # None defers to lp.backend, then "dense"; setting BOTH this and
    # lp.backend to different keys is a conflict, not a silent precedence.
    engine: Optional[str] = None
    cache_columns: int = 4096        # column-LRU capacity
    warm_start: bool = True          # neighbor/stale warm starts
    carry_untouched: bool = True     # keep untouched-type columns on delta
    # after a delta, advance demoted stale hints this many fused LP rounds
    # against the NEW operator (engine.round) so the next query's warm
    # start is already partway to the moved fixed point (dhlp2 only — the
    # round contract is the fused DHLP-2 update)
    refresh_rounds: int = 0
    max_batch: int = 64
    max_wait_s: float = 0.005
    queue_depth: int = 1024

    def resolved_engine(self) -> str:
        """Backend key serving will use (before any ``auto`` resolution)."""
        return self.engine or self.lp.backend or "dense"

    def __post_init__(self):
        if (
            self.engine is not None
            and self.lp.backend is not None
            and self.engine != self.lp.backend
        ):
            raise ValueError(
                f"ServeConfig.engine={self.engine!r} conflicts with "
                f"LPConfig.backend={self.lp.backend!r}; set one (or both "
                "to the same key)"
            )
        resolved = self.resolved_engine()
        if resolved != "auto":
            from repro.engine import UnknownBackendError, get_backend_class

            try:
                resolve_backend(resolved)
            except UnknownBackendError as e:
                raise ValueError(f"unknown engine {resolved!r}: {e}") from e
            cls = get_backend_class(resolved)
            if self.lp.alg not in cls.supports_algs:
                # fail at construction, not at the first query batch —
                # a bad config inside a coalesced batch fails every
                # co-batched request
                raise ValueError(
                    f"engine {resolved!r} does not support alg "
                    f"{self.lp.alg!r} (supports {cls.supports_algs})"
                )
            if self.lp.momentum and not cls.supports_momentum:
                raise ValueError(
                    f"engine {resolved!r} has no momentum loop "
                    f"(LPConfig.momentum={self.lp.momentum})"
                )
        if self.refresh_rounds < 0:
            raise ValueError("refresh_rounds must be >= 0")
        if self.refresh_rounds and self.lp.alg != "dhlp2":
            # engine.round is the fused DHLP-2 update; advancing DHLP-1
            # hints with it would walk them toward the WRONG fixed point.
            raise ValueError(
                "refresh_rounds requires alg='dhlp2' (the round contract "
                "is the fused DHLP-2 update)"
            )
        if self.lp.resolved_seed_mode() != "fixed":
            # Warm starts and incremental re-solves need the F0-independent
            # fixed point; drift mode's answer depends on the start state.
            raise ValueError(
                "serving requires fixed-seed mode "
                "(LPConfig(seed_mode='fixed'))"
            )


class LPServeEngine:
    """Query front-end over a (mutable, versioned) heterogeneous network."""

    def __init__(
        self,
        net: HeteroNetwork,
        config: ServeConfig = ServeConfig(),
        *,
        engine=None,
        norm=None,
        telemetry=None,
    ):
        """``engine``/``norm`` let a :class:`repro.api.session.Session`
        inject its already-prepared LP engine and normalized view, so the
        serve path reuses the operator assembled for the solve stage
        instead of re-preparing per entry point (DESIGN.md §13).
        ``telemetry`` threads one :class:`repro.obs.Telemetry` into the
        batcher and column cache (DESIGN.md §14)."""
        self.config = config
        self._state = NetworkState.from_network(net, version=0, norm=norm)
        backend = resolve_backend(
            config.resolved_engine(), num_nodes=net.num_nodes,
            config=config.lp,
        )
        if engine is not None:
            if engine.name != backend:
                raise ValueError(
                    f"injected engine backend {engine.name!r} conflicts "
                    f"with ServeConfig's resolved engine {backend!r}"
                )
            if engine.config != config.lp:
                raise ValueError(
                    "injected engine's LPConfig differs from "
                    "ServeConfig.lp — serving would answer from different "
                    "math than the engine was prepared with"
                )
            self._engine = engine
        else:
            self._engine = make_engine(backend, config.lp)
        self.columns = ColumnCache(config.cache_columns, telemetry=telemetry)
        self.batcher = MicroBatcher(
            self._solve_batch,
            max_batch=config.max_batch,
            max_wait_s=config.max_wait_s,
            queue_depth=config.queue_depth,
            telemetry=telemetry,
        )
        # one solve/update at a time: the solvers' operator caches and the
        # column LRU are not concurrency-safe on their own
        self._lock = threading.Lock()

    # ------------------------------------------------------------ accessors
    @property
    def state(self) -> NetworkState:
        return self._state

    @property
    def version(self) -> int:
        return self._state.version

    # -------------------------------------------------------------- queries
    def _validate(self, spec: QuerySpec) -> None:
        """Reject bad specs at the edge, before they join a batch.

        A bad spec inside a coalesced batch would fail every co-batched
        request; validity is stable once checked — the node-id space only
        ever grows (``GraphDelta.add_nodes``) and the type count is fixed.
        """
        state = self._state
        if not 0 <= spec.entity < state.num_nodes:
            raise ValueError(
                f"entity {spec.entity} out of range [0,{state.num_nodes})"
            )
        if not 0 <= spec.target_type < state.net.num_types:
            raise ValueError(f"no such type {spec.target_type}")

    def submit(self, spec: QuerySpec, **kw) -> "Future[QueryResult]":
        """Enqueue for the micro-batcher (needs ``start()`` or ``drain()``)."""
        self._validate(spec)
        return self.batcher.submit(spec, **kw)

    def query(self, spec: QuerySpec) -> QueryResult:
        """Synchronous single query (a batch of one on a cache miss)."""
        return self._solve_batch([spec])[0]

    def start(self) -> None:
        self.batcher.start()

    def stop(self) -> None:
        self.batcher.stop()

    # ------------------------------------------------------------- the tick
    def _solve_batch(self, specs: Sequence[QuerySpec]) -> List[QueryResult]:
        with self._lock:
            return self._solve_batch_locked(specs)

    def _solve_batch_locked(
        self, specs: Sequence[QuerySpec]
    ) -> List[QueryResult]:
        state = self._state
        n = state.num_nodes
        for spec in specs:
            self._validate(spec)  # no-op for specs vetted at submit()

        # 1. split hits from misses; dedupe miss columns within the batch
        cols: Dict[int, np.ndarray] = {}
        sources: Dict[int, str] = {}
        rounds: Dict[int, int] = {}
        miss_nodes: List[int] = []
        for spec in specs:
            node = spec.entity
            if node in cols:
                continue
            cached = self.columns.get(state.version, node)
            if cached is not None:
                cols[node] = cached
                sources[node] = "cache"
                rounds[node] = 0
            else:
                cols[node] = None  # placeholder, solved below
                miss_nodes.append(node)

        # 2. one batched solve for every miss column
        if miss_nodes:
            warm_index = (
                self._cached_by_type() if self.config.warm_start else {}
            )
            Y = np.zeros((n, len(miss_nodes)), dtype=np.float64)
            F0 = np.zeros_like(Y)
            warm = []
            for c, node in enumerate(miss_nodes):
                Y[node, c] = 1.0
                hint = (
                    self._warm_hint(node, warm_index)
                    if self.config.warm_start
                    else None
                )
                if hint is not None:
                    F0[:, c] = hint
                    warm.append(True)
                else:
                    F0[:, c] = Y[:, c]
                    warm.append(False)
            result = self._run_solver(Y, F0)
            per_col = (
                result.per_column_iters
                if result.per_column_iters is not None
                else np.full(len(miss_nodes), result.outer_iters, np.int32)
            )
            for c, node in enumerate(miss_nodes):
                col = result.F[:, c]
                cols[node] = col
                sources[node] = "warm" if warm[c] else "cold"
                rounds[node] = int(per_col[c])
                self.columns.put(state.version, node, col)

        # 3. rank per request
        return [self._rank(spec, cols[spec.entity], sources[spec.entity],
                           rounds[spec.entity]) for spec in specs]

    def _run_solver(self, Y: np.ndarray, F0: np.ndarray):
        # every registered engine caches its prepared operator on the
        # normalized network's identity, so repeat batches skip re-assembly
        return self._engine.run(self._state.norm, seeds=Y, F0=F0)

    def _cached_by_type(self) -> Dict[int, List[int]]:
        """Group the current version's cached nodes by type, once per tick."""
        state = self._state
        by_type: Dict[int, List[int]] = {}
        for other in self.columns.cached_nodes(state.version):
            by_type.setdefault(int(state.type_of[other]), []).append(other)
        return by_type

    def _warm_hint(
        self, node: int, by_type: Dict[int, List[int]]
    ) -> Optional[np.ndarray]:
        """Warm-start column for a cold node.

        Preference order: the node's own stale column from before the last
        delta (delta propagation), else the fresh column of the
        most-similar cached node of the same type (neighbor warm start —
        one vectorized similarity-row lookup, not a per-node scan).
        """
        stale = self.columns.stale_hint(node)
        if stale is not None and stale.shape[0] == self._state.num_nodes:
            return stale
        state = self._state
        t, u = state.local_id(node)
        cands = [o for o in by_type.get(t, ()) if o != node]
        if not cands:
            return None
        sims = state.net.P[t][u, np.asarray(cands) - state.offsets[t]]
        best = int(np.argmax(sims))
        if sims[best] <= 0.0:
            return None
        return self.columns.get(state.version, cands[best])

    # -------------------------------------------------------------- ranking
    def _rank(
        self, spec: QuerySpec, col: np.ndarray, source: str, rounds: int
    ) -> QueryResult:
        state = self._state
        t_ent, u = state.local_id(spec.entity)
        tt = spec.target_type
        off = state.offsets[tt]
        scores = np.asarray(col[off : off + state.sizes[tt]], dtype=np.float64)
        exclude = np.zeros(scores.shape[0], dtype=bool)
        if not spec.include_known:
            R = state.net.R
            if (t_ent, tt) in R:
                exclude |= R[(t_ent, tt)][u] > 0
            elif (tt, t_ent) in R:
                exclude |= R[(tt, t_ent)][:, u] > 0
        if t_ent == tt:
            exclude[u] = True  # an entity is not its own candidate
        cand = topk_exclusive(scores, spec.top_k, exclude)
        return QueryResult(
            spec=spec,
            candidates=cand,
            scores=scores[cand],
            target_offset=off,
            version=state.version,
            source=source,
            rounds=rounds,
        )

    # ------------------------------------------------------ incremental path
    def apply_delta(self, delta: GraphDelta) -> int:
        """Apply a graph edit; returns the new network version.

        Cached columns whose types the delta touches are demoted to
        warm-start hints; untouched-type columns are carried forward when
        ``carry_untouched`` (approximation: their values shift by at most
        the delta's propagated mass — see DESIGN.md §9.3).  When the delta
        adds nodes every column demotes (the id space changed shape) and
        stale hints are remapped into the new layout.
        """
        with self._lock:
            if delta.is_empty:
                return self._state.version
            old = self._state
            new_net = old.net.apply_delta(delta)
            new = NetworkState.from_network(new_net, old.version + 1)
            remap = None
            if delta.add_nodes:
                remap = _make_remap(old, new)
            self.columns.invalidate_for_delta(
                old.version,
                new.version,
                delta.touched_types(),
                old.type_of,
                remap=remap,
                carry_untouched=self.config.carry_untouched,
            )
            self._state = new
            self._maybe_rescale_engine()
            if self.config.refresh_rounds:
                self._refresh_stale_hints()
            return new.version

    def _maybe_rescale_engine(self) -> None:
        """Re-resolve an ``auto`` engine after the network changed size.

        Node-adding deltas can push the network across the dense/sparse
        policy boundary (§11); an ``auto`` deployment must not keep
        rebuilding an O(N²) dense operator forever.  Explicitly pinned
        engines are left alone.  Called under ``self._lock``.
        """
        if self.config.resolved_engine() != "auto":
            return
        backend = resolve_backend(
            "auto", num_nodes=self._state.num_nodes, config=self.config.lp
        )
        if backend != self._engine.name:
            self._engine = make_engine(backend, self.config.lp)

    def _refresh_stale_hints(self) -> int:
        """Advance demoted hints toward the new fixed point (§9.3).

        One batched ``engine.round`` per refresh round: the fused update
        ``β²Y + A_eff @ F`` is a contraction toward the NEW operator's
        fixed point, so k rounds leave every hint k rounds closer — the
        next query's warm start re-converges in fewer rounds without
        paying a full solve at delta time.  Called under ``self._lock``.
        """
        state = self._state
        n = state.num_nodes
        hints = {
            v: h
            for v in self.columns.stale_nodes()
            if (h := self.columns.stale_hint(v)) is not None
            and h.shape[0] == n
        }
        if not hints:
            return 0
        op = self._engine.prepare(state.norm)
        # the stale set is unbounded across deltas while queries cap work
        # at max_batch — chunk the refresh the same way (f32 slabs) so a
        # large accumulation cannot blow up memory inside the lock
        nodes = list(hints)
        width = max(1, self.config.max_batch)
        for i in range(0, len(nodes), width):
            batch = nodes[i : i + width]
            Y = np.zeros((n, len(batch)), dtype=np.float32)
            F = np.empty_like(Y)
            for c, v in enumerate(batch):
                Y[v, c] = 1.0
                F[:, c] = hints[v]
            for _ in range(self.config.refresh_rounds):
                F = self._engine.round(op, F, Y)
            for c, v in enumerate(batch):
                self.columns.put_stale(v, F[:, c])
        return len(nodes)


def _make_remap(old: NetworkState, new: NetworkState):
    """Old-layout → new-layout column scatter (types keep their prefixes)."""

    def remap(col: np.ndarray) -> np.ndarray:
        out = np.zeros(new.num_nodes, dtype=np.float64)
        for t, (o_off, o_n) in enumerate(zip(old.offsets, old.sizes)):
            out[new.offsets[t] : new.offsets[t] + o_n] = col[o_off : o_off + o_n]
        return out

    return remap
