"""Solver-state + label-column caches (DESIGN.md §9.2).

Two levels:

* :class:`NetworkState` — one per network *version*: the raw network, its
  normalization, and the per-node type/offset tables.  The solver engines
  key their prepared device arrays on the identity of the normalized
  network, so holding one ``NetworkState`` per version means operators are
  uploaded once per version, not once per query batch.
* :class:`ColumnCache` — an LRU of solved F-columns keyed by
  ``(version, node)``.  A hit serves with zero LP rounds.  Entries evicted
  by a :class:`~repro.core.GraphDelta` are *demoted* to warm-start hints
  (``stale``): the next solve for that node starts from the stale column
  instead of the seed vector, which is the delta-propagation trick — the
  fixed point moved a little, so the stale answer is a few rounds away.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.network import HeteroNetwork, NormalizedNetwork


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    warm_hints: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclasses.dataclass
class NetworkState:
    """Immutable per-version view of the network."""

    version: int
    net: HeteroNetwork
    norm: NormalizedNetwork
    type_of: np.ndarray
    offsets: List[int]
    sizes: List[int]

    @classmethod
    def from_network(
        cls, net: HeteroNetwork, version: int, norm=None
    ) -> "NetworkState":
        """``norm`` (when the caller already normalized ``net``) keeps the
        normalized-network identity shared — engine ``prepare()`` caches
        are keyed on it (DESIGN.md §11/§13)."""
        if norm is not None and norm.num_nodes != net.num_nodes:
            raise ValueError(
                f"norm has {norm.num_nodes} nodes, network has "
                f"{net.num_nodes} — not a view of this network"
            )
        return cls(
            version=version,
            net=net,
            norm=net.normalize() if norm is None else norm,
            type_of=net.type_of_node(),
            offsets=net.offsets,
            sizes=net.sizes,
        )

    @property
    def num_nodes(self) -> int:
        return self.net.num_nodes

    def local_id(self, node: int) -> Tuple[int, int]:
        """(type, local index) for a global node id."""
        t = int(self.type_of[node])
        return t, node - self.offsets[t]


class ColumnCache:
    """LRU of solved label columns keyed by ``(version, node)``.

    Also keeps, per node, at most one *stale* column from a previous
    version — not servable, but the warm-start seed for the next solve.
    """

    def __init__(self, capacity: int = 4096, *, telemetry=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._lru: "OrderedDict[Tuple[int, int], np.ndarray]" = OrderedDict()
        self._stale: Dict[int, np.ndarray] = {}
        self.stats = CacheStats()
        # mirrors the CacheStats increments into serve.cache.* counters
        # (DESIGN.md §14.2); None = uninstrumented standalone use
        self._tel = telemetry

    def __len__(self) -> int:
        return len(self._lru)

    def get(self, version: int, node: int) -> Optional[np.ndarray]:
        key = (version, node)
        col = self._lru.get(key)
        if col is None:
            self.stats.misses += 1
            if self._tel is not None:
                self._tel.count("serve.cache.misses")
            return None
        self._lru.move_to_end(key)
        self.stats.hits += 1
        if self._tel is not None:
            self._tel.count("serve.cache.hits")
        return col

    def put(self, version: int, node: int, col: np.ndarray) -> None:
        key = (version, node)
        self._lru[key] = np.asarray(col)
        self._lru.move_to_end(key)
        self._stale.pop(node, None)  # fresh answer supersedes any hint
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)
            self.stats.evictions += 1
            if self._tel is not None:
                self._tel.count("serve.cache.evictions")

    # ---------------------------------------------------------- warm starts
    def stale_hint(self, node: int) -> Optional[np.ndarray]:
        return self._stale.get(node)

    def stale_nodes(self) -> List[int]:
        """Nodes currently holding a demoted warm-start hint."""
        return list(self._stale)

    def put_stale(self, node: int, col: np.ndarray) -> None:
        """Replace a node's hint (serve's post-delta refresh writes back)."""
        self._stale[node] = np.asarray(col)

    def cached_nodes(self, version: int) -> List[int]:
        return [n for (v, n) in self._lru if v == version]

    # --------------------------------------------------------- invalidation
    def invalidate_for_delta(
        self,
        old_version: int,
        new_version: int,
        touched_types: frozenset,
        type_of: np.ndarray,
        remap=None,
        carry_untouched: bool = True,
    ) -> int:
        """Apply a version bump.

        Columns of *touched* types are demoted to stale warm-start hints
        (optionally passed through ``remap`` when the node id space grew).
        Columns of untouched types are carried into the new version when
        ``carry_untouched`` (the freshness/latency trade documented in
        DESIGN.md §9.3) unless ``remap`` is set — a re-shaped id space means
        every cached column has the wrong length, so everything demotes.
        Returns the number of demoted columns.
        """
        demoted = 0
        old_items = [
            ((v, n), col) for (v, n), col in self._lru.items() if v == old_version
        ]
        for (v, n), col in old_items:
            del self._lru[(v, n)]
            touched = int(type_of[n]) in touched_types
            if remap is None and carry_untouched and not touched:
                self._lru[(new_version, n)] = col
                continue
            hint = col if remap is None else remap(col)
            self._stale[n] = hint
            self.stats.invalidations += 1
            demoted += 1
        self.stats.warm_hints = len(self._stale)
        if self._tel is not None and demoted:
            self._tel.count("serve.cache.invalidations", demoted)
        return demoted

    def clear(self) -> None:
        self._lru.clear()
        self._stale.clear()
