"""Solver-state + label-column caches (DESIGN.md §9.2).

Three pieces:

* :class:`NetworkState` — one per network *version*: the raw network, its
  normalization, and the per-node type/offset tables.  The solver engines
  key their prepared device arrays on the identity of the normalized
  network, so holding one ``NetworkState`` per version means operators are
  uploaded once per version, not once per query batch.
* :class:`ColumnCache` — an LRU of solved F-columns keyed by
  ``(version, node)``.  A hit serves with zero LP rounds.  Entries evicted
  by a :class:`~repro.core.GraphDelta` are *demoted* to warm-start hints
  (``stale``): the next solve for that node starts from the stale column
  instead of the seed vector, which is the delta-propagation trick — the
  fixed point moved a little, so the stale answer is a few rounds away.
* :class:`ShardedColumnCache` — N independent ``ColumnCache`` shards, each
  behind its own lock, routed by node id.  The pipelined scheduler's
  assembly and completion stages probe/write concurrently; per-shard locks
  keep eviction and warm-start lookup from serializing on one global
  mutex.  ``shards=1`` is behaviorally identical to a single
  ``ColumnCache`` (tested), so the sharding is purely a concurrency knob.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.network import HeteroNetwork, NormalizedNetwork


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    warm_hints: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclasses.dataclass
class NetworkState:
    """Immutable per-version view of the network."""

    version: int
    net: HeteroNetwork
    norm: NormalizedNetwork
    type_of: np.ndarray
    offsets: List[int]
    sizes: List[int]

    @classmethod
    def from_network(
        cls, net: HeteroNetwork, version: int, norm=None
    ) -> "NetworkState":
        """``norm`` (when the caller already normalized ``net``) keeps the
        normalized-network identity shared — engine ``prepare()`` caches
        are keyed on it (DESIGN.md §11/§13)."""
        if norm is not None and norm.num_nodes != net.num_nodes:
            raise ValueError(
                f"norm has {norm.num_nodes} nodes, network has "
                f"{net.num_nodes} — not a view of this network"
            )
        return cls(
            version=version,
            net=net,
            norm=net.normalize() if norm is None else norm,
            type_of=net.type_of_node(),
            offsets=net.offsets,
            sizes=net.sizes,
        )

    @property
    def num_nodes(self) -> int:
        return self.net.num_nodes

    def local_id(self, node: int) -> Tuple[int, int]:
        """(type, local index) for a global node id."""
        t = int(self.type_of[node])
        return t, node - self.offsets[t]


class ColumnCache:
    """LRU of solved label columns keyed by ``(version, node)``.

    Also keeps, per node, at most one *stale* column from a previous
    version — not servable, but the warm-start seed for the next solve.
    """

    def __init__(
        self,
        capacity: int = 4096,
        *,
        telemetry=None,
        shard_id: Optional[int] = None,
    ):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._lru: "OrderedDict[Tuple[int, int], np.ndarray]" = OrderedDict()
        self._stale: Dict[int, np.ndarray] = {}
        self.stats = CacheStats()
        # mirrors the CacheStats increments into serve.cache.* counters
        # (DESIGN.md §14.2); None = uninstrumented standalone use.  A
        # shard of a ShardedColumnCache additionally mirrors hit/miss
        # into serve.cache.shard<i>.* so per-shard balance is observable.
        self._tel = telemetry
        self._shard_id = shard_id

    def __len__(self) -> int:
        return len(self._lru)

    def _count(self, short: str, n: int = 1, *, per_shard: bool = False) -> None:
        if self._tel is None:
            return
        self._tel.count(f"serve.cache.{short}", n)
        if per_shard and self._shard_id is not None:
            self._tel.count(f"serve.cache.shard{self._shard_id}.{short}", n)

    def get(self, version: int, node: int) -> Optional[np.ndarray]:
        key = (version, node)
        col = self._lru.get(key)
        if col is None:
            self.stats.misses += 1
            self._count("misses", per_shard=True)
            return None
        self._lru.move_to_end(key)
        self.stats.hits += 1
        self._count("hits", per_shard=True)
        return col

    def put(self, version: int, node: int, col: np.ndarray) -> None:
        key = (version, node)
        self._lru[key] = np.asarray(col)
        self._lru.move_to_end(key)
        self._stale.pop(node, None)  # fresh answer supersedes any hint
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)
            self.stats.evictions += 1
            self._count("evictions")

    # ---------------------------------------------------------- warm starts
    def stale_hint(self, node: int) -> Optional[np.ndarray]:
        return self._stale.get(node)

    def stale_nodes(self) -> List[int]:
        """Nodes currently holding a demoted warm-start hint."""
        return list(self._stale)

    def put_stale(self, node: int, col: np.ndarray) -> None:
        """Replace a node's hint (serve's post-delta refresh writes back)."""
        self._stale[node] = np.asarray(col)

    def cached_nodes(self, version: int) -> List[int]:
        return [n for (v, n) in self._lru if v == version]

    def snapshot(self, version: int) -> List[Tuple[int, np.ndarray]]:
        """Stats-neutral read of one version's columns (FT checkpoint).

        Unlike ``get`` this neither bumps hit counters nor touches LRU
        order — a periodic checkpoint must not distort the hit-rate SLO
        or promote cold entries."""
        return [(n, col) for (v, n), col in self._lru.items() if v == version]

    # --------------------------------------------------------- invalidation
    def invalidate_for_delta(
        self,
        old_version: int,
        new_version: int,
        touched_types: frozenset,
        type_of: np.ndarray,
        remap=None,
        carry_untouched: bool = True,
    ) -> int:
        """Apply a version bump.

        Columns of *touched* types are demoted to stale warm-start hints
        (optionally passed through ``remap`` when the node id space grew).
        Columns of untouched types are carried into the new version when
        ``carry_untouched`` (the freshness/latency trade documented in
        DESIGN.md §9.3) unless ``remap`` is set — a re-shaped id space means
        every cached column has the wrong length, so everything demotes.
        Returns the number of demoted columns.
        """
        demoted = 0
        old_items = [
            ((v, n), col) for (v, n), col in self._lru.items() if v == old_version
        ]
        for (v, n), col in old_items:
            del self._lru[(v, n)]
            touched = int(type_of[n]) in touched_types
            if remap is None and carry_untouched and not touched:
                self._lru[(new_version, n)] = col
                continue
            hint = col if remap is None else remap(col)
            self._stale[n] = hint
            self.stats.invalidations += 1
            demoted += 1
        self.stats.warm_hints = len(self._stale)
        if demoted:
            self._count("invalidations", demoted)
        return demoted

    def invalidate_newer(self, version: int) -> int:
        """Drop every column published after ``version`` (FT restore).

        After a restore to a checkpoint watermark, columns computed past
        the watermark may carry state from the failed execution — they
        are dropped outright, not demoted: a tainted column must not even
        warm-start the replay.  Stale hints predating the watermark keep
        their (versionless) warm-start role.  Returns the drop count.
        """
        doomed = [(v, n) for (v, n) in self._lru if v > version]
        for key in doomed:
            del self._lru[key]
        if doomed:
            self.stats.invalidations += len(doomed)
            self._count("invalidations", len(doomed))
        return len(doomed)

    def clear(self) -> None:
        self._lru.clear()
        self._stale.clear()


class ShardedColumnCache:
    """``ColumnCache`` split into N independently-locked shards.

    Keys route by ``node % shards`` (the version is deliberately NOT in
    the routing key, so a node's fresh columns and its stale warm-start
    hint always live in the same shard).  Each shard holds
    ``ceil(capacity / shards)`` columns, so total capacity is preserved
    and, with one shard, eviction order is identical to the flat LRU.

    Exposes the same surface as :class:`ColumnCache` — the serve engine
    treats the two interchangeably — plus an aggregated ``stats`` view.
    """

    def __init__(
        self, capacity: int = 4096, *, shards: int = 1, telemetry=None
    ):
        if shards < 1:
            raise ValueError("shards must be >= 1")
        if capacity < shards:
            raise ValueError(
                f"capacity {capacity} < shards {shards}: every shard "
                "needs at least one slot"
            )
        self.capacity = capacity
        self.shards = shards
        per_shard = -(-capacity // shards)  # ceil
        self._shards: List[ColumnCache] = [
            ColumnCache(
                per_shard,
                telemetry=telemetry,
                shard_id=(i if shards > 1 else None),
            )
            for i in range(shards)
        ]
        self._locks = [threading.Lock() for _ in range(shards)]

    def _shard(self, node: int) -> Tuple[ColumnCache, threading.Lock]:
        i = node % self.shards
        return self._shards[i], self._locks[i]

    def __len__(self) -> int:
        return sum(len(s) for s in self._shards)

    @property
    def stats(self) -> CacheStats:
        """Aggregated snapshot across shards (same fields as the flat LRU)."""
        agg = CacheStats()
        for s in self._shards:
            agg.hits += s.stats.hits
            agg.misses += s.stats.misses
            agg.evictions += s.stats.evictions
            agg.invalidations += s.stats.invalidations
            agg.warm_hints += s.stats.warm_hints
        return agg

    def shard_stats(self) -> List[CacheStats]:
        return [s.stats for s in self._shards]

    def get(self, version: int, node: int) -> Optional[np.ndarray]:
        shard, lock = self._shard(node)
        with lock:
            return shard.get(version, node)

    def put(self, version: int, node: int, col: np.ndarray) -> None:
        shard, lock = self._shard(node)
        with lock:
            shard.put(version, node, col)

    def stale_hint(self, node: int) -> Optional[np.ndarray]:
        shard, lock = self._shard(node)
        with lock:
            return shard.stale_hint(node)

    def put_stale(self, node: int, col: np.ndarray) -> None:
        shard, lock = self._shard(node)
        with lock:
            shard.put_stale(node, col)

    def stale_nodes(self) -> List[int]:
        out: List[int] = []
        for shard, lock in zip(self._shards, self._locks):
            with lock:
                out.extend(shard.stale_nodes())
        return out

    def cached_nodes(self, version: int) -> List[int]:
        out: List[int] = []
        for shard, lock in zip(self._shards, self._locks):
            with lock:
                out.extend(shard.cached_nodes(version))
        return out

    def snapshot(self, version: int) -> List[Tuple[int, np.ndarray]]:
        out: List[Tuple[int, np.ndarray]] = []
        for shard, lock in zip(self._shards, self._locks):
            with lock:
                out.extend(shard.snapshot(version))
        return out

    def invalidate_for_delta(
        self,
        old_version: int,
        new_version: int,
        touched_types: frozenset,
        type_of: np.ndarray,
        remap=None,
        carry_untouched: bool = True,
    ) -> int:
        demoted = 0
        for shard, lock in zip(self._shards, self._locks):
            with lock:
                demoted += shard.invalidate_for_delta(
                    old_version,
                    new_version,
                    touched_types,
                    type_of,
                    remap=remap,
                    carry_untouched=carry_untouched,
                )
        return demoted

    def invalidate_newer(self, version: int) -> int:
        dropped = 0
        for shard, lock in zip(self._shards, self._locks):
            with lock:
                dropped += shard.invalidate_newer(version)
        return dropped

    def clear(self) -> None:
        for shard, lock in zip(self._shards, self._locks):
            with lock:
                shard.clear()
