"""Micro-batching scheduler (DESIGN.md §9.1).

Independent queries are embarrassingly batchable in LP: each is one seed
column, and the solver already iterates whole column-blocks per round.  So
the serving tick is: drain up to ``max_batch`` pending requests (waiting at
most ``max_wait_s`` for stragglers to coalesce), stack their seed columns,
run ONE batched solve, scatter results back to per-request futures.

Backpressure is the bounded queue: when ``queue_depth`` requests are
already pending, ``submit`` either blocks (default) or raises
``queue.Full`` — the caller sheds load instead of the engine dying.

With a ``telemetry`` handle attached (DESIGN.md §14) each tick records
queue depth, batch size/occupancy gauges and batch/completed/failed
counters; at trace level the tick itself becomes a ``batch`` span with
per-query events.  The batcher usually runs on its background thread, so
those spans parent to the Session's *ambient* phase span, not a stack
frame of this thread.
"""
from __future__ import annotations

import contextlib

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional, Sequence, Tuple

from repro.serve.types import QueryResult, QuerySpec

# solve_batch: List[QuerySpec] -> List[QueryResult] (same order)
SolveBatchFn = Callable[[Sequence[QuerySpec]], List[QueryResult]]


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    rejected: int = 0
    batches: int = 0

    @property
    def mean_batch_size(self) -> float:
        return self.completed / self.batches if self.batches else 0.0


class MicroBatcher:
    """Coalesce pending queries into one batched solve per tick."""

    def __init__(
        self,
        solve_batch: SolveBatchFn,
        *,
        max_batch: int = 64,
        max_wait_s: float = 0.005,
        queue_depth: int = 1024,
        telemetry=None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._solve_batch = solve_batch
        self._tel = telemetry
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._queue: "queue.Queue[Tuple[QuerySpec, Future, float]]" = (
            queue.Queue(maxsize=queue_depth)
        )
        self.stats = SchedulerStats()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ producers
    def submit(
        self,
        spec: QuerySpec,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> "Future[QueryResult]":
        """Enqueue a query; the future resolves after some later tick.

        With ``block=False`` (or on timeout) a full queue raises
        ``queue.Full`` — that is the backpressure signal.
        """
        fut: "Future[QueryResult]" = Future()
        try:
            self._queue.put((spec, fut, time.monotonic()), block, timeout)
        except queue.Full:
            self.stats.rejected += 1
            if self._tel is not None:
                self._tel.count("serve.rejected")
            raise
        self.stats.submitted += 1
        return fut

    @property
    def pending(self) -> int:
        return self._queue.qsize()

    # ------------------------------------------------------------- consumer
    def _collect(self, wait: bool) -> List[Tuple[QuerySpec, Future, float]]:
        """Drain up to ``max_batch`` requests for one tick.

        Blocks up to ``max_wait_s`` for the FIRST request (when ``wait``),
        then keeps collecting without waiting — the batch closes as soon as
        the queue momentarily empties or ``max_batch`` is reached.
        """
        batch: List[Tuple[QuerySpec, Future, float]] = []
        try:
            if wait:
                # bounded wait so the background loop can observe stop()
                batch.append(
                    self._queue.get(timeout=max(self.max_wait_s, 0.05))
                )
            else:
                batch.append(self._queue.get_nowait())
        except queue.Empty:
            return batch
        deadline = time.monotonic() + self.max_wait_s
        while len(batch) < self.max_batch:
            try:
                batch.append(self._queue.get_nowait())
            except queue.Empty:
                if time.monotonic() >= deadline:
                    break
                time.sleep(min(1e-4, self.max_wait_s / 10 or 1e-4))
        return batch

    def run_once(self, wait: bool = True) -> int:
        """One scheduler tick: coalesce → solve → resolve futures.

        Returns the number of requests served (0 when idle).
        """
        batch = self._collect(wait)
        if not batch:
            return 0
        # transition futures to RUNNING: drops already-cancelled requests
        # and, crucially, makes later cancel() impossible — set_result below
        # can then never race a concurrent cancellation into
        # InvalidStateError (which would kill the background loop)
        live = [
            (s, f, t) for (s, f, t) in batch
            if f.set_running_or_notify_cancel()
        ]
        if not live:
            return 0
        specs = [s for s, _, _ in live]
        tel = self._tel
        if tel is None:
            span = contextlib.nullcontext()
        else:
            tel.gauge("serve.queue_depth", self._queue.qsize())
            tel.gauge("serve.batch_size", len(live))
            tel.gauge("serve.batch_occupancy", len(live) / self.max_batch)
            span = tel.trace_span("batch", f"batch:{self.stats.batches}")
        with span:
            try:
                results = self._solve_batch(specs)
                if len(results) != len(specs):
                    raise RuntimeError(
                        f"solve_batch returned {len(results)} results for "
                        f"{len(specs)} specs"
                    )
            except Exception as exc:  # noqa: BLE001 — propagate to every waiter
                for _, fut, _ in live:
                    fut.set_exception(exc)
                self.stats.failed += len(live)
                self.stats.batches += 1
                if tel is not None:
                    tel.count("serve.batches")
                    tel.count("serve.failed", len(live))
                return 0
            now = time.monotonic()
            for (spec, fut, t_in), res in zip(live, results):
                res.latency_s = now - t_in
                fut.set_result(res)
                if tel is not None and tel.trace_enabled:
                    tel.event(
                        "serve.query",
                        entity=spec.entity,
                        target_type=spec.target_type,
                        source=res.source,
                        rounds=res.rounds,
                        latency_s=res.latency_s,
                    )
        self.stats.completed += len(live)
        self.stats.batches += 1
        if tel is not None:
            tel.count("serve.batches")
            tel.count("serve.completed", len(live))
        return len(live)

    def drain(self) -> int:
        """Serve until the queue is empty (synchronous drivers, tests)."""
        total = 0
        while True:
            served = self.run_once(wait=False)
            if served == 0 and self._queue.empty():
                return total
            total += served

    # ------------------------------------------------------ background loop
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self.run_once(wait=True)

        self._thread = threading.Thread(
            target=loop, name="lp-serve-batcher", daemon=True
        )
        self._thread.start()

    def stop(self, timeout: float = 5.0) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout)
        self._thread = None
        self.drain()  # don't strand late submissions
