"""Pipelined micro-batching scheduler (DESIGN.md §9.1).

Independent queries are embarrassingly batchable in LP: each is one seed
column, and the solver already iterates whole column-blocks per round.
The serving tick is: drain up to ``max_batch`` pending requests (waiting
at most ``max_wait_s`` for stragglers to coalesce), stack their seed
columns, run ONE batched solve, scatter results back to per-request
futures.

Three layers on top of that basic tick:

* **Priority classes + admission control.**  Requests carry a class
  (``interactive`` > ``refresh`` > ``bulk``).  Admission is the bounded
  queue with class-dependent thresholds: lower classes shed load earlier
  (``ADMIT_FRACTION`` of ``queue_depth``), so a bulk backfill can never
  push interactive traffic into rejection.  Draining is weighted
  round-robin (``DRAIN_WEIGHTS``): every tick reserves at least one slot
  for each non-empty class, so low-priority work is throttled, never
  starved.
* **Pipelining.**  With ``pipeline_depth > 1`` and the two-stage hooks
  (``assemble``/``execute``), ``start()`` runs a *collector* thread that
  coalesces and assembles the next batch (cache probes, seed-matrix
  construction) while a *solver* thread runs the engine on the current
  one.  The bounded in-flight queue (``pipeline_depth - 1`` assembled
  batches plus the one being solved) is the double-buffer window —
  assembly and solve overlap, memory stays bounded.
* **Backpressure.**  A full class budget makes ``submit`` block
  (default) or raise ``queue.Full`` — the caller sheds load instead of
  the engine dying.

With a ``telemetry`` handle attached (DESIGN.md §14) each tick records
queue depth (total and per class), in-flight depth per class, batch
size/occupancy gauges and batch/completed/failed counters; at trace
level the tick itself becomes a ``batch`` span with per-query events.
The batcher runs on background threads, so those spans parent to the
Session's *ambient* phase span, not a stack frame of this thread.
"""
from __future__ import annotations

import contextlib

import dataclasses
import queue
import threading
import time
from collections import deque
from typing import (
    Any,
    Callable,
    Dict,
    List,
    Optional,
    Sequence,
    Tuple,
)

from repro.serve.types import (
    DEFAULT_PRIORITY,
    PRIORITY_CLASSES,
    QueryResult,
    QuerySpec,
)

# solve_batch: List[QuerySpec] -> List[QueryResult] (same order)
SolveBatchFn = Callable[[Sequence[QuerySpec]], List[QueryResult]]

#: Admission thresholds: a class is admitted while total pending is below
#: ``ADMIT_FRACTION[cls] * queue_depth``.  Interactive may fill the whole
#: queue; refresh and bulk shed earlier, in that order.
ADMIT_FRACTION: Dict[str, float] = {
    "interactive": 1.0,
    "refresh": 0.75,
    "bulk": 0.5,
}

#: Weighted round-robin drain shares.  Each tick grants every non-empty
#: class at least one slot (anti-starvation), then splits the batch
#: roughly proportionally to these weights, then backfills by priority.
DRAIN_WEIGHTS: Dict[str, int] = {
    "interactive": 8,
    "refresh": 4,
    "bulk": 2,
}

_Entry = Tuple[QuerySpec, "queue.Future", float]  # (spec, future, t_submit)


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    rejected: int = 0
    batches: int = 0
    by_class: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=lambda: {
            c: {"submitted": 0, "completed": 0, "rejected": 0}
            for c in PRIORITY_CLASSES
        }
    )

    @property
    def mean_batch_size(self) -> float:
        return self.completed / self.batches if self.batches else 0.0


class _PipelineItem:
    """An assembled batch waiting for (or undergoing) its solve."""

    __slots__ = ("prepared", "live")

    def __init__(self, prepared: Any, live: List[_Entry]):
        self.prepared = prepared
        self.live = live


_SENTINEL = object()


class MicroBatcher:
    """Coalesce pending queries into batched solves, optionally pipelined.

    ``solve_batch`` is the one-stage callback (assemble + solve + rank in
    one call) used by the synchronous paths (``run_once``/``drain``) and
    by the legacy background loop.  Passing the two-stage hooks
    ``assemble`` (queue-side: cache probes + seed assembly, cheap) and
    ``execute`` (engine-side: the batched solve + ranking, the long pole)
    with ``pipeline_depth > 1`` makes ``start()`` run the pipelined
    collector/solver pair instead.
    """

    def __init__(
        self,
        solve_batch: SolveBatchFn,
        *,
        max_batch: int = 64,
        max_wait_s: float = 0.005,
        queue_depth: int = 1024,
        pipeline_depth: int = 1,
        assemble: Optional[Callable[[Sequence[QuerySpec]], Any]] = None,
        execute: Optional[Callable[[Any], List[QueryResult]]] = None,
        telemetry=None,
        guard=None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        if pipeline_depth > 1 and (assemble is None or execute is None):
            raise ValueError(
                "pipeline_depth > 1 needs the two-stage assemble/execute "
                "hooks (the one-stage solve_batch cannot overlap)"
            )
        self._solve_batch = solve_batch
        self._assemble = assemble
        self._execute = execute
        self._tel = telemetry
        # optional repro.ft.StepGuard: solver-side batch execution runs
        # inside it, so a transient engine fault retries (and, with a
        # restore_fn wired, restores + replays the in-flight batch)
        # instead of failing every co-batched future.  Public so the
        # serve engine's FT wiring can attach one after construction.
        self.guard = guard
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.queue_depth = queue_depth
        self.pipeline_depth = pipeline_depth
        self._classes: Dict[str, "deque[_Entry]"] = {
            c: deque() for c in PRIORITY_CLASSES
        }
        self._pending_count = 0
        self._cond = threading.Condition()
        # per-instance so the SLO degradation hook can shed a class's
        # share at runtime (set_admit_fraction) without touching the
        # module-level policy defaults
        self._admit_fraction = dict(ADMIT_FRACTION)
        self._admit_limit = {
            c: max(1, int(queue_depth * self._admit_fraction[c]))
            for c in PRIORITY_CLASSES
        }
        self.stats = SchedulerStats()
        self._stats_lock = threading.Lock()
        # assembled-but-unsolved batches; the +1 batch inside execute()
        # completes the pipeline_depth-deep in-flight window
        self._inflight: "queue.Queue" = queue.Queue(
            maxsize=max(1, pipeline_depth - 1)
        )
        self._inflight_by_class: Dict[str, int] = dict.fromkeys(
            PRIORITY_CLASSES, 0
        )
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()

    # ------------------------------------------------------------ producers
    def submit(
        self,
        spec: QuerySpec,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> "queue.Future":
        """Enqueue a query; the future resolves after some later tick.

        Admission control: the request's priority class is admitted while
        total pending sits below its share of ``queue_depth``.  Over
        budget, ``block=False`` (or a timeout) raises ``queue.Full`` —
        that is the backpressure signal, and lower classes hit it first.
        """
        from concurrent.futures import Future

        cls = getattr(spec, "priority", DEFAULT_PRIORITY)
        if cls not in PRIORITY_CLASSES:
            raise ValueError(
                f"unknown priority {cls!r}; classes: {PRIORITY_CLASSES}"
            )
        fut: "Future[QueryResult]" = Future()
        limit = self._admit_limit[cls]
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._pending_count >= limit:
                if not block:
                    self._reject(cls)
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    self._reject(cls)
                if not self._cond.wait(timeout=remaining):
                    self._reject(cls)
            self._classes[cls].append((spec, fut, time.monotonic()))
            self._pending_count += 1
            self._cond.notify_all()
        with self._stats_lock:
            self.stats.submitted += 1
            self.stats.by_class[cls]["submitted"] += 1
        return fut

    def _reject(self, cls: str) -> None:
        with self._stats_lock:
            self.stats.rejected += 1
            self.stats.by_class[cls]["rejected"] += 1
        if self._tel is not None:
            self._tel.count("serve.rejected")
            self._tel.count(f"serve.rejected.{cls}")
        raise queue.Full

    # ------------------------------------------------- admission degradation
    def admit_fraction(self, cls: str) -> float:
        """The current admission share for ``cls`` (1.0 = whole queue)."""
        if cls not in PRIORITY_CLASSES:
            raise ValueError(
                f"unknown priority {cls!r}; classes: {PRIORITY_CLASSES}"
            )
        with self._cond:
            return self._admit_fraction[cls]

    def set_admit_fraction(self, cls: str, fraction: float) -> None:
        """Runtime admission-control knob (the SLO degradation hook).

        Shrinking a class's fraction sheds its load at the admission
        edge — over-budget submits reject/block immediately; growing it
        back wakes blocked producers.  The limit floor of 1 mirrors
        ``__init__``: no class is ever fully shut off.
        """
        if cls not in PRIORITY_CLASSES:
            raise ValueError(
                f"unknown priority {cls!r}; classes: {PRIORITY_CLASSES}"
            )
        if not 0.0 < fraction <= 1.0:
            raise ValueError(
                f"admit fraction must be in (0, 1], got {fraction}"
            )
        with self._cond:
            self._admit_fraction[cls] = float(fraction)
            self._admit_limit[cls] = max(1, int(self.queue_depth * fraction))
            self._cond.notify_all()  # a raised limit unblocks waiters
        if self._tel is not None:
            self._tel.gauge(f"serve.admit_limit.{cls}", self._admit_limit[cls])

    @property
    def pending(self) -> int:
        with self._cond:
            return self._pending_count

    def pending_by_class(self) -> Dict[str, int]:
        with self._cond:
            return {c: len(q) for c, q in self._classes.items()}

    # ------------------------------------------------------------- consumer
    def _collect(self, wait: bool) -> List[_Entry]:
        """Drain up to ``max_batch`` requests for one tick.

        Blocks up to ``max(max_wait_s, 0.05)`` for the FIRST request
        (when ``wait``), then keeps the straggler window open for
        ``max_wait_s`` — the batch closes when ``max_batch`` requests are
        pending or the window expires.  Selection is weighted round-robin
        across priority classes (see :data:`DRAIN_WEIGHTS`).
        """
        with self._cond:
            if not self._pending_count:
                if not wait:
                    return []
                self._cond.wait(timeout=max(self.max_wait_s, 0.05))
                if not self._pending_count:
                    return []
            deadline = time.monotonic() + self.max_wait_s
            while self._pending_count < self.max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(timeout=remaining)
            return self._take_locked()

    def _take_locked(self) -> List[_Entry]:
        """WRR batch selection; caller holds ``self._cond``."""
        batch: List[_Entry] = []
        nonempty = [c for c in PRIORITY_CLASSES if self._classes[c]]
        total_w = sum(DRAIN_WEIGHTS[c] for c in nonempty) or 1
        # quota pass: every non-empty class gets >= 1 slot, roughly its
        # weighted share — bulk is throttled, never starved
        for c in nonempty:
            quota = max(1, (self.max_batch * DRAIN_WEIGHTS[c]) // total_w)
            q = self._classes[c]
            take = min(quota, len(q), self.max_batch - len(batch))
            for _ in range(take):
                batch.append(q.popleft())
        # fill pass: leftover room by priority order
        for c in PRIORITY_CLASSES:
            q = self._classes[c]
            while q and len(batch) < self.max_batch:
                batch.append(q.popleft())
        self._pending_count -= len(batch)
        self._cond.notify_all()
        return batch

    def _begin_batch(self, batch: List[_Entry]) -> List[_Entry]:
        """Transition futures to RUNNING, dropping cancelled requests.

        Crucially this makes later ``cancel()`` impossible — the
        ``set_result`` in completion can then never race a concurrent
        cancellation into ``InvalidStateError`` (which would kill the
        background loop).
        """
        return [
            (s, f, t) for (s, f, t) in batch
            if f.set_running_or_notify_cancel()
        ]

    def _record_tick(self, live: List[_Entry]) -> None:
        tel = self._tel
        if tel is None:
            return
        with self._cond:
            depth = self._pending_count
            per_class = {c: len(q) for c, q in self._classes.items()}
        tel.gauge("serve.queue_depth", depth)
        for c, d in per_class.items():
            tel.gauge(f"serve.queue_depth.{c}", d)
        tel.gauge("serve.batch_size", len(live))
        tel.gauge("serve.batch_occupancy", len(live) / self.max_batch)
        # the scheduler tick is the serve tier's streaming pump: one
        # attribute test when no stream is attached (DESIGN.md §14.7)
        tel.maybe_flush()

    def _track_inflight(self, live: List[_Entry], delta: int) -> None:
        tel = self._tel
        with self._stats_lock:
            for spec, _, _ in live:
                cls = getattr(spec, "priority", DEFAULT_PRIORITY)
                self._inflight_by_class[cls] += delta
            snapshot = dict(self._inflight_by_class) if tel else None
        if tel is not None:
            for c, n in snapshot.items():
                tel.gauge(f"serve.inflight.{c}", n)

    def _complete(self, live: List[_Entry], results: List[QueryResult]) -> None:
        now = time.monotonic()
        tel = self._tel
        for (spec, fut, t_in), res in zip(live, results):
            res.latency_s = now - t_in
            fut.set_result(res)
            if tel is not None:
                # recorded at completion time (not post-replay) so the
                # latency histogram fills live — per-window SLO evaluation
                # and `repro obs --follow` read it mid-run
                tel.observe("serve.latency_s", res.latency_s)
            if tel is not None and tel.trace_enabled:
                tel.event(
                    "serve.query",
                    entity=spec.entity,
                    target_type=spec.target_type,
                    source=res.source,
                    rounds=res.rounds,
                    latency_s=res.latency_s,
                )
        with self._stats_lock:
            self.stats.completed += len(live)
            self.stats.batches += 1
            for spec, _, _ in live:
                cls = getattr(spec, "priority", DEFAULT_PRIORITY)
                self.stats.by_class[cls]["completed"] += 1
        if tel is not None:
            tel.count("serve.batches")
            tel.count("serve.completed", len(live))

    def _fail(self, live: List[_Entry], exc: BaseException) -> None:
        for _, fut, _ in live:
            fut.set_exception(exc)
        with self._stats_lock:
            self.stats.failed += len(live)
            self.stats.batches += 1
        if self._tel is not None:
            self._tel.count("serve.batches")
            self._tel.count("serve.failed", len(live))

    def _run_guarded(self, fn, arg):
        """Route one batch execution through the step guard, if any."""
        if self.guard is None:
            return fn(arg)
        return self.guard.run(lambda: fn(arg))

    def run_once(self, wait: bool = True) -> int:
        """One synchronous scheduler tick: coalesce → solve → resolve.

        Returns the number of requests served (0 when idle).
        """
        batch = self._collect(wait)
        if not batch:
            return 0
        live = self._begin_batch(batch)
        if not live:
            return 0
        specs = [s for s, _, _ in live]
        tel = self._tel
        self._record_tick(live)
        if tel is None:
            span = contextlib.nullcontext()
        else:
            span = tel.trace_span("batch", f"batch:{self.stats.batches}")
        with span:
            try:
                results = self._run_guarded(self._solve_batch, specs)
                if len(results) != len(specs):
                    raise RuntimeError(
                        f"solve_batch returned {len(results)} results for "
                        f"{len(specs)} specs"
                    )
            except Exception as exc:  # noqa: BLE001 — propagate to every waiter
                self._fail(live, exc)
                return 0
            self._complete(live, results)
        return len(live)

    def drain(self) -> int:
        """Serve until the queue is empty (synchronous drivers, tests)."""
        total = 0
        while True:
            served = self.run_once(wait=False)
            if served == 0 and self.pending == 0:
                return total
            total += served

    # ------------------------------------------------------ background loops
    @property
    def pipelined(self) -> bool:
        """Whether ``start()`` runs the two-stage collector/solver pair."""
        return (
            self.pipeline_depth > 1
            and self._assemble is not None
            and self._execute is not None
        )

    def start(self) -> None:
        if self._threads:
            return
        self._stop.clear()
        if self.pipelined:
            targets = [
                (self._collector_loop, "lp-serve-collector"),
                (self._solver_loop, "lp-serve-solver"),
            ]
        else:
            targets = [(self._legacy_loop, "lp-serve-batcher")]
        for target, name in targets:
            t = threading.Thread(target=target, name=name, daemon=True)
            t.start()
            self._threads.append(t)

    def _legacy_loop(self) -> None:
        while not self._stop.is_set():
            self.run_once(wait=True)

    def _collector_loop(self) -> None:
        """Stage 1: coalesce + assemble the NEXT batch while stage 2 solves.

        The blocking put on the bounded in-flight queue is the pipeline's
        flow control: at most ``pipeline_depth`` batches exist between
        assembly start and future resolution.
        """
        while not self._stop.is_set():
            batch = self._collect(wait=True)
            if not batch:
                continue
            live = self._begin_batch(batch)
            if not live:
                continue
            specs = [s for s, _, _ in live]
            self._record_tick(live)
            try:
                prepared = self._assemble(specs)
            except Exception as exc:  # noqa: BLE001 — fail this batch only
                self._fail(live, exc)
                continue
            self._track_inflight(live, +1)
            # blocks while the solver is pipeline_depth-1 batches behind;
            # the solver keeps consuming until the sentinel, so this put
            # always completes even during shutdown
            self._inflight.put(_PipelineItem(prepared, live))
        self._inflight.put(_SENTINEL)

    def _solver_loop(self) -> None:
        """Stage 2: execute assembled batches until the sentinel."""
        tel = self._tel
        while True:
            item = self._inflight.get()
            if item is _SENTINEL:
                return
            if tel is None:
                span = contextlib.nullcontext()
            else:
                span = tel.trace_span("batch", f"batch:{self.stats.batches}")
            with span:
                try:
                    results = self._run_guarded(self._execute, item.prepared)
                    if len(results) != len(item.live):
                        raise RuntimeError(
                            f"execute returned {len(results)} results for "
                            f"{len(item.live)} specs"
                        )
                except Exception as exc:  # noqa: BLE001
                    self._fail(item.live, exc)
                else:
                    self._complete(item.live, results)
            self._track_inflight(item.live, -1)

    def stop(self, timeout: float = 5.0) -> None:
        """Clean shutdown: in-flight batches finish, late submissions drain.

        Ordering: the collector observes the stop flag, pushes its final
        assembled batch (if any) plus the sentinel; the solver executes
        everything up to the sentinel and exits; whatever was submitted
        after the collector's last tick is drained synchronously.  No
        future is ever stranded.
        """
        if not self._threads:
            return
        self._stop.set()
        with self._cond:
            self._cond.notify_all()  # wake a collector blocked in _collect
        for t in self._threads:
            t.join(timeout)
        self._threads = []
        self.drain()  # don't strand late submissions
