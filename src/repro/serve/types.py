"""Request/response dataclasses for the serving subsystem."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

#: Admission/drain classes, highest priority first (DESIGN.md §9.1).
#: ``interactive`` is user-facing traffic, ``refresh`` is post-delta
#: re-convergence work, ``bulk`` is offline backfill.
PRIORITY_CLASSES = ("interactive", "refresh", "bulk")
DEFAULT_PRIORITY = "interactive"


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One ranking query: "rank ``top_k`` ``target_type`` candidates for
    ``entity``" (the paper's step G, per-entity candidate list).

    ``entity`` is a *global* node id; ``target_type`` the type index whose
    block is ranked (e.g. targets for a drug).  ``priority`` selects the
    admission/drain class (``interactive`` > ``refresh`` > ``bulk``).
    """

    entity: int
    target_type: int
    top_k: int = 20
    # serve known-associated entities too (default: exclude them — they
    # would trivially top every repositioning list)
    include_known: bool = False
    priority: str = DEFAULT_PRIORITY


@dataclasses.dataclass
class QueryResult:
    """Ranked candidates plus serving metadata."""

    spec: QuerySpec
    candidates: np.ndarray    # (<= top_k,) local ids within the target block
    scores: np.ndarray        # matching label scores, descending
    target_offset: int        # global id = target_offset + local id
    version: int              # network version the answer was computed on
    source: str               # "cache" | "warm" | "cold"
    rounds: int               # LP rounds this column cost (0 on cache hit)
    latency_s: float = 0.0    # filled by the scheduler/driver

    @property
    def global_candidates(self) -> np.ndarray:
        return self.candidates + self.target_offset


def percentiles(
    latencies: Sequence[float], qs=(50, 95, 99)
) -> Optional[dict]:
    """{p50: ..., p95: ..., p99: ...} in seconds, or None when empty."""
    if not len(latencies):
        return None
    arr = np.asarray(latencies, dtype=np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}
