"""Online LP query-serving subsystem (DESIGN.md §9).

A one-shot solve (a RunSpec without a ``serve`` section) builds a
network, solves every seed, and exits.  This package turns the same
engines into a long-lived query service:

* :class:`~repro.serve.scheduler.MicroBatcher` — coalesces pending queries
  into one batched solve per tick (bounded queue = backpressure).
* :class:`~repro.serve.cache.ColumnCache` — LRU of solved label columns;
  repeat queries are cache hits, cold queries warm-start from cached
  nearby columns.
* :class:`~repro.serve.engine.LPServeEngine` — the front-end: ranking via
  ``core/ranking.py``, incremental :class:`~repro.core.GraphDelta` updates
  with stale-column warm restarts.
"""
from repro.serve.cache import CacheStats, ColumnCache, NetworkState
from repro.serve.engine import LPServeEngine, ServeConfig
from repro.serve.replay import play_zipf, replay_trace
from repro.serve.scheduler import MicroBatcher, SchedulerStats
from repro.serve.types import QueryResult, QuerySpec

__all__ = [
    "CacheStats",
    "ColumnCache",
    "LPServeEngine",
    "MicroBatcher",
    "NetworkState",
    "QueryResult",
    "QuerySpec",
    "SchedulerStats",
    "ServeConfig",
    "play_zipf",
    "replay_trace",
]
