"""Online LP query-serving subsystem (DESIGN.md §9).

A one-shot solve (a RunSpec without a ``serve`` section) builds a
network, solves every seed, and exits.  This package turns the same
engines into a long-lived query service:

* :class:`~repro.serve.scheduler.MicroBatcher` — coalesces pending queries
  into one batched solve per tick (bounded queue = backpressure), with
  priority-class admission control and an optional pipelined mode where
  the next batch assembles while the engine solves the current one.
* :class:`~repro.serve.cache.ColumnCache` /
  :class:`~repro.serve.cache.ShardedColumnCache` — LRU of solved label
  columns (optionally split into independently-locked shards); repeat
  queries are cache hits, cold queries warm-start from cached nearby
  columns.
* :class:`~repro.serve.engine.LPServeEngine` — the front-end: ranking via
  ``core/ranking.py``, incremental :class:`~repro.core.GraphDelta` updates
  with stale-column warm restarts, and convergence-aware early exit
  inside batch solves.
"""
from repro.serve.cache import (
    CacheStats,
    ColumnCache,
    NetworkState,
    ShardedColumnCache,
)
from repro.serve.engine import LPServeEngine, ServeConfig
from repro.serve.replay import play_zipf, replay_trace
from repro.serve.scheduler import MicroBatcher, SchedulerStats
from repro.serve.types import (
    DEFAULT_PRIORITY,
    PRIORITY_CLASSES,
    QueryResult,
    QuerySpec,
)

__all__ = [
    "CacheStats",
    "ColumnCache",
    "DEFAULT_PRIORITY",
    "LPServeEngine",
    "MicroBatcher",
    "NetworkState",
    "PRIORITY_CLASSES",
    "QueryResult",
    "QuerySpec",
    "SchedulerStats",
    "ServeConfig",
    "ShardedColumnCache",
    "play_zipf",
    "replay_trace",
]
