"""Sharded, atomic, resumable checkpointing.

Layout per step:
    <root>/step_<n>.tmp/          — written first
        leaf_00000.npy ...        — one file per pytree leaf
        manifest.json             — treedef, leaf paths, shapes, dtypes,
                                    step, wall-time, user metadata
    <root>/step_<n>/              — atomic rename after fsync

Guarantees:
  * a checkpoint directory either exists completely or not at all
    (rename is atomic; partial writes stay in ``.tmp``),
  * ``restore_latest`` skips corrupt/partial checkpoints,
  * ``keep_last`` garbage-collects old steps after a successful write,
  * async mode hands the (host-copied) arrays to a writer thread so the
    train loop is not blocked by the filesystem.

Elasticity: arrays are saved UNSHARDED (gathered to host).  On restore the
caller passes target shardings — the restore places each leaf with
``jax.device_put`` on the new mesh, so a job can come back on a different
device count (elastic re-mesh) without a resharding tool.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"
_STOP = object()  # writer-thread shutdown sentinel (see close())


def _leaf_paths(tree: PyTree) -> List[str]:
    paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(kp) for kp, _ in paths]


@dataclasses.dataclass
class CheckpointManager:
    root: str
    keep_last: int = 3
    async_write: bool = False

    def __post_init__(self) -> None:
        os.makedirs(self.root, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._writer: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._closed = False
        if self.async_write:
            self._writer = threading.Thread(
                target=self._writer_loop, daemon=True
            )
            self._writer.start()

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: PyTree,
             metadata: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot → (async) write.  Host copies happen on the caller's
        thread so the device buffers can be donated right after."""
        if self._closed:
            raise RuntimeError("CheckpointManager is closed")
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(x) for x in leaves]
        job = (step, host, str(treedef), metadata or {})
        if self.async_write:
            self._raise_pending()
            self._q.put(job)
        else:
            self._write(job)

    def wait(self) -> None:
        """Block until pending async writes are durable."""
        if self.async_write:
            self._q.join()
            self._raise_pending()

    def close(self) -> None:
        """Drain pending writes and join the writer thread.

        Without this the daemon writer dies with the interpreter and a
        queued snapshot may never hit disk.  Idempotent; ``save`` after
        close raises.  A write error queued before close is re-raised
        here, like ``wait``.
        """
        if self._closed:
            return
        self._closed = True
        if self._writer is not None:
            self._q.put(_STOP)
            self._writer.join()
            self._writer = None
        self._raise_pending()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _writer_loop(self) -> None:
        while True:
            job = self._q.get()
            if job is _STOP:
                self._q.task_done()
                return
            try:
                self._write(job)
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, job) -> None:
        step, host, treedef_str, metadata = job
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "treedef": treedef_str,
            "time": time.time(),
            "metadata": metadata,
            "leaves": [],
        }
        for i, arr in enumerate(host):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
            )
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    # -------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, name, _MANIFEST)):
                    try:
                        out.append(int(name.split("_", 1)[1]))
                    except ValueError:
                        continue
        return sorted(out)

    def manifest(self, step: int) -> Dict[str, Any]:
        """The parsed manifest of a step (leaves, treedef, metadata)."""
        with open(os.path.join(self._step_dir(step), _MANIFEST)) as f:
            return json.load(f)

    def restore(
        self,
        step: int,
        like: PyTree,
        shardings: Optional[PyTree] = None,
        *,
        cast: bool = False,
    ) -> PyTree:
        """Restore into the structure of ``like`` (treedef, shape and
        dtype all validated against the manifest).

        ``shardings`` (same structure) places each leaf on a target mesh —
        this is the elastic-reshard path: save on 512 chips, restore on 256.
        A dtype mismatch is an error — a checkpoint is a bit-exact record,
        not a conversion source; pass ``cast=True`` to opt into an
        explicit ``astype`` (e.g. restoring bf16 storage into f32).
        """
        d = self._step_dir(step)
        manifest = self.manifest(step)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        if len(manifest["leaves"]) != len(leaves_like):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target structure has {len(leaves_like)}"
            )
        saved_treedef = manifest.get("treedef")
        if saved_treedef is not None and saved_treedef != str(treedef):
            raise ValueError(
                f"checkpoint treedef {saved_treedef} does not match "
                f"target structure {treedef}"
            )
        sh_leaves = (
            jax.tree_util.tree_flatten(shardings)[0]
            if shardings is not None else [None] * len(leaves_like)
        )
        out = []
        for rec, ref, sh in zip(manifest["leaves"], leaves_like, sh_leaves):
            arr = np.load(os.path.join(d, rec["file"]))
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{rec['file']}: shape {arr.shape} != {ref.shape}"
                )
            ref_dtype = np.dtype(getattr(ref, "dtype", None) or type(ref))
            if arr.dtype != ref_dtype:
                if not cast:
                    raise ValueError(
                        f"{rec['file']}: dtype {arr.dtype} != {ref_dtype} "
                        "(pass cast=True to convert explicitly)"
                    )
                arr = arr.astype(ref_dtype)
            out.append(
                jax.device_put(arr, sh) if sh is not None else arr
            )
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(
        self,
        like: PyTree,
        shardings: Optional[PyTree] = None,
        *,
        cast: bool = False,
    ) -> Tuple[Optional[int], Optional[PyTree]]:
        for step in reversed(self.steps()):
            try:
                return step, self.restore(step, like, shardings, cast=cast)
            except Exception:  # noqa: BLE001 — corrupt ckpt: try older
                continue
        return None, None

    def restore_flat(
        self, step: int
    ) -> Tuple[List[np.ndarray], Dict[str, Any]]:
        """Restore a step as a flat leaf list + its user metadata.

        No ``like`` template: shapes/dtypes come from the manifest.  This
        is the path for snapshots whose geometry the reader cannot know up
        front (e.g. the serve tier's variable-size cache snapshot).
        """
        d = self._step_dir(step)
        manifest = self.manifest(step)
        leaves = [
            np.load(os.path.join(d, rec["file"]))
            for rec in manifest["leaves"]
        ]
        return leaves, manifest.get("metadata", {})

    def restore_latest_flat(
        self,
    ) -> Tuple[Optional[int], Optional[List[np.ndarray]], Dict[str, Any]]:
        for step in reversed(self.steps()):
            try:
                leaves, meta = self.restore_flat(step)
                return step, leaves, meta
            except Exception:  # noqa: BLE001 — corrupt ckpt: try older
                continue
        return None, None, {}

    # ------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")
