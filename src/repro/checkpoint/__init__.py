from repro.checkpoint.store import CheckpointManager

__all__ = ["CheckpointManager"]
