"""10-fold cross-validation protocol (paper §6.2.1).

Positives = the known interaction entries of one association matrix.  Each
fold hides 1/k of the positives (they are zeroed in the input network); the
solver's predicted scores for the held-out positives are compared against
all true-negative entries of that matrix.

This module is the protocol; the declarative front-end is a RunSpec
``eval`` section with ``protocol="cv"`` — ``Session.evaluate()``
(DESIGN.md §13) drives :func:`cross_validate` through
``scenarios.evaluate.scenario_cross_validate`` with one engine reused
across every fold.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.network import HeteroNetwork, TypePair
from repro.eval.metrics import evaluate_predictions


@dataclasses.dataclass
class FoldResult:
    fold: int
    metrics: Dict[str, float]


def kfold_masks(
    R: np.ndarray,
    k: int = 10,
    seed: int = 0,
    positives: Optional[np.ndarray] = None,
) -> Iterator[np.ndarray]:
    """Yield k boolean masks over R, each hiding ~1/k of the positives.

    ``positives`` overrides the positive set (default: every nonzero
    entry).  Scenario bundles pass their *planted* truth here so noise
    edges are never treated as recoverable signal.
    """
    if positives is None:
        positives = R > 0
    elif np.any(positives & (R == 0)):
        raise ValueError("positives must be present edges (R > 0)")
    pos = np.argwhere(positives)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(pos))
    folds = np.array_split(perm, k)
    for f in folds:
        mask = np.zeros_like(R, dtype=bool)
        sel = pos[f]
        mask[sel[:, 0], sel[:, 1]] = True
        yield mask


def cross_validate(
    net: HeteroNetwork,
    pair: TypePair,
    solver_fn,
    k: int = 10,
    seed: int = 0,
    positives: Optional[np.ndarray] = None,
) -> List[FoldResult]:
    """Run k-fold CV on one association matrix.

    ``solver_fn(masked_net) -> scores`` must return the predicted score
    matrix for ``pair`` (same shape as ``net.R[pair]``).  With
    ``positives`` (a boolean subset of the present edges — e.g. a
    scenario's planted truth), folds hide only those entries and the
    negative set excludes non-positive present edges (noise), so the
    protocol runs against ground truth on any T-type scenario.
    """
    i, j = min(pair), max(pair)
    R = net.R[(i, j)]
    if positives is None:
        positives = R > 0
    results: List[FoldResult] = []
    for fold, mask in enumerate(
        kfold_masks(R, k=k, seed=seed, positives=positives)
    ):
        masked = net.with_masked_fold((i, j), mask)
        scores = solver_fn(masked)
        if scores.shape != R.shape:
            raise ValueError(
                f"solver returned {scores.shape}, expected {R.shape}"
            )
        # evaluation set: held-out positives vs all true negatives.
        # positives ⊆ (R > 0) (kfold_masks enforces it), so present
        # noise edges are excluded from the negative side automatically.
        eval_mask = mask | (R == 0)
        labels = mask[eval_mask]
        s = scores[eval_mask]
        results.append(
            FoldResult(fold=fold, metrics=evaluate_predictions(s, labels))
        )
    return results


def summarize(results: List[FoldResult]) -> Dict[str, float]:
    keys = results[0].metrics.keys()
    return {k: float(np.mean([r.metrics[k] for r in results])) for k in keys}
