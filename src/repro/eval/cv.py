"""10-fold cross-validation protocol (paper §6.2.1).

Positives = the known interaction entries of one association matrix.  Each
fold hides 1/k of the positives (they are zeroed in the input network); the
solver's predicted scores for the held-out positives are compared against
all true-negative entries of that matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import numpy as np

from repro.core.network import HeteroNetwork, TypePair
from repro.eval.metrics import evaluate_predictions


@dataclasses.dataclass
class FoldResult:
    fold: int
    metrics: Dict[str, float]


def kfold_masks(
    R: np.ndarray, k: int = 10, seed: int = 0
) -> Iterator[np.ndarray]:
    """Yield k boolean masks over R, each hiding ~1/k of the positives."""
    pos = np.argwhere(R > 0)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(pos))
    folds = np.array_split(perm, k)
    for f in folds:
        mask = np.zeros_like(R, dtype=bool)
        sel = pos[f]
        mask[sel[:, 0], sel[:, 1]] = True
        yield mask


def cross_validate(
    net: HeteroNetwork,
    pair: TypePair,
    solver_fn,
    k: int = 10,
    seed: int = 0,
) -> List[FoldResult]:
    """Run k-fold CV on one association matrix.

    ``solver_fn(masked_net) -> scores`` must return the predicted score
    matrix for ``pair`` (same shape as ``net.R[pair]``).
    """
    i, j = min(pair), max(pair)
    R = net.R[(i, j)]
    results: List[FoldResult] = []
    for fold, mask in enumerate(kfold_masks(R, k=k, seed=seed)):
        masked = net.with_masked_fold((i, j), mask)
        scores = solver_fn(masked)
        if scores.shape != R.shape:
            raise ValueError(
                f"solver returned {scores.shape}, expected {R.shape}"
            )
        # evaluation set: held-out positives vs all true negatives
        eval_mask = mask | (R == 0)
        labels = mask[eval_mask]
        s = scores[eval_mask]
        results.append(
            FoldResult(fold=fold, metrics=evaluate_predictions(s, labels))
        )
    return results


def summarize(results: List[FoldResult]) -> Dict[str, float]:
    keys = results[0].metrics.keys()
    return {k: float(np.mean([r.metrics[k] for r in results])) for k in keys}
