"""Prediction-accuracy metrics (paper §6.2): AUC, AUPR, BestACC.

Numpy implementations (host-side evaluation of LP outputs), matching the
standard definitions used in the drug-repositioning literature.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _validate(scores: np.ndarray, labels: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    scores = np.asarray(scores, dtype=np.float64).ravel()
    labels = np.asarray(labels).ravel().astype(bool)
    if scores.shape != labels.shape:
        raise ValueError("scores/labels shape mismatch")
    if labels.all() or (~labels).all():
        raise ValueError("need at least one positive and one negative")
    return scores, labels


def auc_score(scores: np.ndarray, labels: np.ndarray) -> float:
    """Area under the ROC curve via the Mann–Whitney rank statistic
    (tie-aware: ties get average ranks)."""
    scores, labels = _validate(scores, labels)
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(scores)
    ranks[order] = np.arange(1, len(scores) + 1, dtype=np.float64)
    # average ranks for ties
    sorted_scores = scores[order]
    i = 0
    while i < len(sorted_scores):
        j = i
        while j + 1 < len(sorted_scores) and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        if j > i:
            avg = (i + j + 2) / 2.0
            ranks[order[i : j + 1]] = avg
        i = j + 1
    npos = int(labels.sum())
    nneg = len(labels) - npos
    rank_sum = ranks[labels].sum()
    return float((rank_sum - npos * (npos + 1) / 2.0) / (npos * nneg))


def aupr_score(scores: np.ndarray, labels: np.ndarray) -> float:
    """Area under the precision-recall curve (step-wise interpolation, i.e.
    average precision)."""
    scores, labels = _validate(scores, labels)
    order = np.argsort(-scores, kind="mergesort")
    labels = labels[order]
    tp = np.cumsum(labels)
    k = np.arange(1, len(labels) + 1)
    precision = tp / k
    npos = tp[-1]
    # AP = Σ precision@k · Δrecall@k  (Δrecall nonzero only at positives)
    return float((precision * labels).sum() / npos)


def best_accuracy(scores: np.ndarray, labels: np.ndarray) -> float:
    """Max over all decision thresholds of (TP+TN)/(P+N) — the paper's
    BestACC."""
    scores, labels = _validate(scores, labels)
    order = np.argsort(-scores, kind="mergesort")
    labels_sorted = labels[order]
    npos = int(labels.sum())
    nneg = len(labels) - npos
    # predict positive for top-k as k sweeps 0..n
    tp = np.concatenate([[0], np.cumsum(labels_sorted)])
    fp = np.arange(len(labels) + 1) - tp
    tn = nneg - fp
    acc = (tp + tn) / len(labels)
    return float(acc.max())


def evaluate_predictions(
    scores: np.ndarray, labels: np.ndarray
) -> dict:
    return {
        "auc": auc_score(scores, labels),
        "aupr": aupr_score(scores, labels),
        "best_acc": best_accuracy(scores, labels),
    }
