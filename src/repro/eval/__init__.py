from repro.eval.cv import FoldResult, cross_validate, kfold_masks, summarize
from repro.eval.metrics import (
    auc_score,
    aupr_score,
    best_accuracy,
    evaluate_predictions,
)

__all__ = [
    "FoldResult",
    "auc_score",
    "aupr_score",
    "best_accuracy",
    "cross_validate",
    "evaluate_predictions",
    "kfold_masks",
    "summarize",
]
