"""Graph dataset generators for the GNN arch pool (offline stand-ins with
the assigned shapes: cora-like, reddit-like, products-like, molecules).

Scope note: this module generates *homogeneous* node-classification /
regression datasets (EdgeList + features + labels) for the model zoo.
Heterogeneous planted-cluster networks — including the tri-partite
drug/disease/target case study — all come from the ONE k-partite
generator idiom in ``repro.scenarios.generators`` (``data/drugnet.py``
is an adapter over it); do not grow a second planted-structure
generator here."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.graph.structures import EdgeList


@dataclasses.dataclass
class NodeClassificationData:
    edges: EdgeList
    feats: np.ndarray      # (N, F)
    labels: np.ndarray     # (N,)
    train_mask: np.ndarray
    n_classes: int


def planted_partition_graph(
    n_nodes: int,
    n_edges: int,
    n_classes: int,
    d_feat: int,
    homophily: float = 0.8,
    train_frac: float = 0.1,
    seed: int = 0,
) -> NodeClassificationData:
    """Community-structured graph whose labels are recoverable from both
    features and structure (so GNN training shows real learning curves)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    # draw dst: with prob `homophily` from the same class
    same = rng.random(n_edges) < homophily
    # class buckets for same-class draws
    order = np.argsort(labels, kind="stable")
    bounds = np.searchsorted(labels[order], np.arange(n_classes + 1))
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    for c in range(n_classes):
        sel = same & (labels[src] == c)
        lo, hi = bounds[c], bounds[c + 1]
        if hi > lo:
            dst[sel] = order[rng.integers(lo, hi, int(sel.sum()))]
    edges = EdgeList(src=src, dst=dst, w=None,
                     num_nodes=n_nodes).symmetrized().with_self_loops()
    # features: class centroid + noise
    centroids = rng.normal(0, 1, (n_classes, d_feat))
    feats = (centroids[labels]
             + rng.normal(0, 1.0, (n_nodes, d_feat))).astype(np.float32)
    train_mask = (rng.random(n_nodes) < train_frac)
    return NodeClassificationData(
        edges=edges, feats=feats, labels=labels,
        train_mask=train_mask, n_classes=n_classes,
    )


def molecule_batch(
    batch: int, nodes_per: int = 30, edges_per: int = 64,
    n_species: int = 16, seed: int = 0,
) -> Dict[str, np.ndarray]:
    """Batched random 3D molecules (disjoint union) + planted energies."""
    rng = np.random.default_rng(seed)
    n = batch * nodes_per
    z = rng.integers(0, n_species, n).astype(np.int32)
    pos = rng.normal(0, 1.5, (n, 3)).astype(np.float32)
    src_l, dst_l = [], []
    for g in range(batch):
        off = g * nodes_per
        # chain + random extra bonds, symmetrized
        a = np.arange(nodes_per - 1)
        s = np.concatenate([a, a + 1])
        d = np.concatenate([a + 1, a])
        extra = edges_per - len(s)
        if extra > 0:
            es = rng.integers(0, nodes_per, extra)
            ed = rng.integers(0, nodes_per, extra)
            s = np.concatenate([s, es])
            d = np.concatenate([d, ed])
        src_l.append(s[:edges_per] + off)
        dst_l.append(d[:edges_per] + off)
    src = np.concatenate(src_l).astype(np.int32)
    dst = np.concatenate(dst_l).astype(np.int32)
    graph_ids = np.repeat(np.arange(batch, dtype=np.int32), nodes_per)
    # planted target: a smooth function of species + geometry
    per_node = np.sin(z).astype(np.float32) + 0.1 * np.linalg.norm(
        pos, axis=-1
    )
    targets = np.zeros(batch, np.float32)
    np.add.at(targets, graph_ids, per_node)
    return {
        "z": z, "pos": pos, "src": src, "dst": dst,
        "graph_ids": graph_ids, "targets": targets,
    }


def mesh_rollout_batch(
    n_nodes: int, n_edges: int, d_node: int = 8, d_edge: int = 4,
    d_out: int = 3, seed: int = 0,
) -> Dict[str, np.ndarray]:
    """MeshGraphNet-style dynamics snapshot with a learnable local rule."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    node_feat = rng.normal(0, 1, (n_nodes, d_node)).astype(np.float32)
    edge_feat = rng.normal(0, 1, (n_edges, d_edge)).astype(np.float32)
    # target = linear function of own + mean-neighbor features (learnable)
    agg = np.zeros((n_nodes, d_node), np.float32)
    np.add.at(agg, dst, node_feat[src])
    deg = np.maximum(np.bincount(dst, minlength=n_nodes), 1)[:, None]
    w1 = rng.normal(0, 0.5, (d_node, d_out))
    w2 = rng.normal(0, 0.5, (d_node, d_out))
    targets = (node_feat @ w1 + (agg / deg) @ w2).astype(np.float32)
    return {
        "node_feat": node_feat, "edge_feat": edge_feat,
        "src": src, "dst": dst, "targets": targets,
    }
