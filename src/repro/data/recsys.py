"""Synthetic CTR data with planted feature-interaction structure."""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class CTRDataConfig:
    n_sparse: int = 40
    n_dense: int = 13
    vocab_per_field: int = 100_000
    seed: int = 0


def sample_ctr_batch(cfg: CTRDataConfig, batch: int,
                     step: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(cfg.seed + step * 7919)
    # Zipf-ish categorical ids (realistic head-heavy vocab usage)
    raw = rng.zipf(1.3, size=(batch, cfg.n_sparse))
    sparse = np.minimum(raw - 1, cfg.vocab_per_field - 1).astype(np.int32)
    dense = rng.normal(0, 1, (batch, cfg.n_dense)).astype(np.float32)
    # planted CTR: per-field hash weights + a dense interaction
    field_w = np.sin(
        np.arange(cfg.n_sparse) * 2.17 + 1.0
    )
    logit = (
        (np.sin(sparse * 0.37) * field_w[None, :]).sum(axis=1) * 0.3
        + dense[:, 0] * 0.5
        - 0.7
    )
    labels = (
        rng.random(batch) < 1.0 / (1.0 + np.exp(-logit))
    ).astype(np.float32)
    return {"sparse": sparse, "dense": dense, "labels": labels}
