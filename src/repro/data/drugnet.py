"""Synthetic drug–disease–target gold-standard generator.

The paper's accuracy experiments run on the Yamanishi-08 gold standard
(four target families; GPCR: 223 drugs × 95 targets) extended with disease
associations by Heter-LP [14].  That dataset is not redistributable inside
this offline container, so we generate networks with the same *structure*:

* latent "mechanism" clusters shared by the three concept types (a drug
  binds targets of its mechanism and treats diseases of its mechanism);
* similarity matrices = noisy intra-cluster affinity (plus identity);
* association matrices = sparse Bernoulli draws, dense within matched
  clusters and (rarely, noise) across clusters.

Because interactions are *planted*, CV can verify that LP recovers held-out
edges — the same protocol as the paper's Table 2, with ground truth known by
construction.  Statistics (sizes, density) default to the GPCR scale.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core.network import HeteroNetwork


@dataclasses.dataclass(frozen=True)
class DrugNetSpec:
    n_drug: int = 223
    n_disease: int = 150
    n_target: int = 95
    n_clusters: int = 12
    # probability of an association within / across matched clusters
    p_intra: float = 0.9
    p_noise: float = 0.0005
    # similarity strengths
    sim_intra: float = 0.8
    sim_noise: float = 0.02
    seed: int = 0


@dataclasses.dataclass
class DrugNet:
    network: HeteroNetwork
    clusters: Tuple[np.ndarray, np.ndarray, np.ndarray]
    spec: DrugNetSpec

    @property
    def pair_names(self) -> Dict[Tuple[int, int], str]:
        return {
            (0, 1): "drug-disease",
            (0, 2): "drug-target",
            (1, 2): "disease-target",
        }


def _similarity(
    rng: np.random.Generator, clusters: np.ndarray, spec: DrugNetSpec
) -> np.ndarray:
    n = clusters.shape[0]
    same = clusters[:, None] == clusters[None, :]
    base = np.where(same, spec.sim_intra, 0.0)
    noise = rng.random((n, n)) * spec.sim_noise
    sim = base + noise
    sim = (sim + sim.T) / 2.0
    np.fill_diagonal(sim, 1.0)
    return sim


def _association(
    rng: np.random.Generator,
    ca: np.ndarray,
    cb: np.ndarray,
    spec: DrugNetSpec,
) -> np.ndarray:
    match = ca[:, None] == cb[None, :]
    p = np.where(match, spec.p_intra, spec.p_noise)
    return (rng.random((ca.shape[0], cb.shape[0])) < p).astype(np.float64)


def make_drugnet(spec: DrugNetSpec = DrugNetSpec()) -> DrugNet:
    rng = np.random.default_rng(spec.seed)
    sizes = (spec.n_drug, spec.n_disease, spec.n_target)
    clusters = tuple(
        rng.integers(0, spec.n_clusters, size=n).astype(np.int32)
        for n in sizes
    )
    P = [_similarity(rng, c, spec) for c in clusters]
    R = {
        (0, 1): _association(rng, clusters[0], clusters[1], spec),
        (0, 2): _association(rng, clusters[0], clusters[2], spec),
        (1, 2): _association(rng, clusters[1], clusters[2], spec),
    }
    net = HeteroNetwork(
        P=P, R=R, type_names=("drug", "disease", "target")
    )
    return DrugNet(network=net, clusters=clusters, spec=spec)


def make_scaling_network(
    num_edges: int, seed: int = 0
) -> DrugNet:
    """Network sized to hit approximately ``num_edges`` total edges —
    the knob the paper's Tables 5/6 sweep from 1M to 20M.

    Edge count is dominated by the similarity matrices (intra-cluster
    cliques): |E| ≈ Σ_types n·(n/k)·sim_density + associations.  We solve
    for n given the default density parameters.
    """
    spec0 = DrugNetSpec(seed=seed)
    # per-type intra-cluster clique edges ≈ n²/k; three types with the
    # default drug:disease:target ratio r = (223, 150, 95)/223
    r = np.array([223.0, 150.0, 95.0]) / 223.0
    k = spec0.n_clusters
    # total ≈ Σ (r_i·n)²/k  + assoc ≈ p_intra·Σ_pairs r_i r_j n²/k
    a = (r ** 2).sum() / k
    pairs = [(0, 1), (0, 2), (1, 2)]
    b = spec0.p_intra * sum(r[i] * r[j] for i, j in pairs) / k
    n_drug = int(np.sqrt(num_edges / (a + b)))
    spec = DrugNetSpec(
        n_drug=n_drug,
        n_disease=int(n_drug * r[1]),
        n_target=int(n_drug * r[2]),
        seed=seed,
    )
    return make_drugnet(spec)
