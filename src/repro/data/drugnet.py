"""Synthetic drug–disease–target gold-standard generator.

The paper's accuracy experiments run on the Yamanishi-08 gold standard
(four target families; GPCR: 223 drugs × 95 targets) extended with disease
associations by Heter-LP [14].  That dataset is not redistributable inside
this offline container, so we generate networks with the same *structure*:
latent mechanism clusters shared by the three concept types, noisy
intra-cluster similarity, and sparse planted associations.

This module is now a thin adapter over the repo's single generator idiom —
the k-partite planted-structure generator in
``repro.scenarios.generators`` — configured tri-partite (the
``bio_tri`` scenario).  The adapter preserves the historical RNG streams
bit-for-bit (the generator draws clusters, similarities, then sorted-pair
associations in the same order this module always did), so every
committed baseline and test built on ``make_drugnet`` is unchanged.

Because interactions are *planted*, CV can verify that LP recovers held-out
edges — the same protocol as the paper's Table 2, with ground truth known by
construction.  Statistics (sizes, density) default to the GPCR scale.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.network import HeteroNetwork


@dataclasses.dataclass(frozen=True)
class DrugNetSpec:
    n_drug: int = 223
    n_disease: int = 150
    n_target: int = 95
    n_clusters: int = 12
    # probability of an association within / across matched clusters
    p_intra: float = 0.9
    p_noise: float = 0.0005
    # similarity strengths
    sim_intra: float = 0.8
    sim_noise: float = 0.02
    seed: int = 0

    def to_kpartite(self):
        """The equivalent generic generator spec (``bio_tri`` shape)."""
        from repro.scenarios.generators import KPartiteSpec

        return KPartiteSpec(
            sizes=(self.n_drug, self.n_disease, self.n_target),
            n_clusters=self.n_clusters,
            p_intra=self.p_intra,
            p_noise=self.p_noise,
            sim_intra=self.sim_intra,
            sim_noise=self.sim_noise,
            type_names=("drug", "disease", "target"),
            seed=self.seed,
        )


@dataclasses.dataclass
class DrugNet:
    network: HeteroNetwork
    clusters: Tuple[np.ndarray, np.ndarray, np.ndarray]
    spec: DrugNetSpec
    #: planted positives per pair (noise edges excluded) — the scenario
    #: subsystem's ground-truth convention, carried by the adapter
    truth: Optional[Dict[Tuple[int, int], np.ndarray]] = None

    @property
    def pair_names(self) -> Dict[Tuple[int, int], str]:
        return {
            (0, 1): "drug-disease",
            (0, 2): "drug-target",
            (1, 2): "disease-target",
        }


def make_drugnet(spec: DrugNetSpec = DrugNetSpec()) -> DrugNet:
    from repro.scenarios.generators import planted_kpartite

    pk = planted_kpartite(spec.to_kpartite())
    return DrugNet(
        network=pk.network, clusters=pk.clusters, spec=spec, truth=pk.truth
    )


def make_scaling_network(
    num_edges: int, seed: int = 0
) -> DrugNet:
    """Network sized to hit approximately ``num_edges`` total edges —
    the knob the paper's Tables 5/6 sweep from 1M to 20M.

    Edge count is dominated by the similarity matrices (intra-cluster
    cliques): |E| ≈ Σ_types n·(n/k)·sim_density + associations.  We solve
    for n given the default density parameters.
    """
    spec0 = DrugNetSpec(seed=seed)
    # per-type intra-cluster clique edges ≈ n²/k; three types with the
    # default drug:disease:target ratio r = (223, 150, 95)/223
    r = np.array([223.0, 150.0, 95.0]) / 223.0
    k = spec0.n_clusters
    # total ≈ Σ (r_i·n)²/k  + assoc ≈ p_intra·Σ_pairs r_i r_j n²/k
    a = (r ** 2).sum() / k
    pairs = [(0, 1), (0, 2), (1, 2)]
    b = spec0.p_intra * sum(r[i] * r[j] for i, j in pairs) / k
    n_drug = int(np.sqrt(num_edges / (a + b)))
    spec = DrugNetSpec(
        n_drug=n_drug,
        n_disease=int(n_drug * r[1]),
        n_target=int(n_drug * r[2]),
        seed=seed,
    )
    return make_drugnet(spec)
