"""Synthetic LM token pipeline.

Deterministic, seekable token stream (a hash of the global token index) so
that (a) restarts resume mid-epoch without storing cursor state beyond the
step number, and (b) every data-parallel shard draws disjoint slices — the
standard deterministic-data-order contract of large training jobs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    # markov-ish structure so the loss has signal to minimize
    structure: int = 97


def sample_batch(cfg: LMDataConfig, step: int,
                 shard: int = 0, num_shards: int = 1) -> Dict[str, np.ndarray]:
    """Batch for (step, shard): disjoint across shards, deterministic."""
    per_shard = cfg.batch // num_shards
    base = (
        np.uint64(step) * np.uint64(cfg.batch * (cfg.seq_len + 1))
        + np.uint64(shard * per_shard * (cfg.seq_len + 1))
        + np.uint64(cfg.seed) * np.uint64(0x1000003)
    )
    idx = base + np.arange(
        per_shard * (cfg.seq_len + 1), dtype=np.uint64
    )
    raw = _splitmix64(idx).reshape(per_shard, cfg.seq_len + 1)
    # structured stream: next token correlates with previous (learnable)
    toks = (raw % np.uint64(cfg.structure)).astype(np.int64)
    toks = np.cumsum(toks, axis=1) % cfg.vocab
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }


def batch_iterator(cfg: LMDataConfig, start_step: int = 0,
                   shard: int = 0, num_shards: int = 1) -> Iterator[Dict]:
    step = start_step
    while True:
        yield sample_batch(cfg, step, shard, num_shards)
        step += 1
