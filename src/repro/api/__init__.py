"""Unified declarative API: RunSpec → Session → Artifacts (DESIGN.md §13).

One serializable job description over everything the repo can run:

>>> from repro.api import RunSpec, Session
>>> spec = RunSpec.from_file("examples/specs/quickstart_run.json")
>>> artifacts = Session(spec).run()      # solve → eval → serve → bench

The spec tree (``repro.api.spec``) is import-light and strictly
validated; the :class:`Session` resolves it against the engine/scenario
registries once, shares one prepared engine across stages, and writes
typed artifacts under ``results/<run_id>/``.  The ``python -m repro run``
driver is a thin CLI over exactly this module.
"""

from repro.api.artifacts import (
    Artifact,
    BenchArtifact,
    DryrunArtifact,
    EvalArtifact,
    ServeArtifact,
    SolveArtifact,
    TrainArtifact,
    jsonable,
)
from repro.api.session import Session
from repro.api.spec import (
    BenchSpec,
    DryrunSpec,
    EvalSpec,
    FTSpec,
    NetworkSpec,
    ObsSpec,
    RunSpec,
    ServeSpec,
    SLOSpec,
    SolveSpec,
    SpecError,
    TrainSpec,
)

__all__ = [
    "Artifact",
    "BenchArtifact",
    "BenchSpec",
    "DryrunArtifact",
    "DryrunSpec",
    "EvalArtifact",
    "EvalSpec",
    "FTSpec",
    "NetworkSpec",
    "ObsSpec",
    "RunSpec",
    "SLOSpec",
    "ServeArtifact",
    "ServeSpec",
    "Session",
    "SolveArtifact",
    "SolveSpec",
    "SpecError",
    "TrainArtifact",
    "TrainSpec",
    "jsonable",
]
