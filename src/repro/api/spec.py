"""Declarative run specification (DESIGN.md §13).

One serializable job description composing *network × algorithm ×
backend × eval × serve × bench*: a :class:`RunSpec` is a small dataclass
tree with strict validation (unknown keys and conflicting fields are
errors, not silent defaults) and a lossless JSON round-trip
(``RunSpec.from_json(spec.to_json()) == spec``).

The tree is deliberately import-light — no jax, no numpy — so specs can
be parsed, validated, and diffed without touching an accelerator
runtime.  Registry-dependent checks (is ``backend`` a registered engine
key? is ``trace`` a known arrival process?) happen when a
:class:`~repro.api.session.Session` resolves the spec.

Sections:

* :class:`NetworkSpec` — what graph: a named scenario, the drugnet case
  study, or an ``.npz`` file;
* :class:`SolveSpec`   — how to propagate: alg / backend / tolerance /
  momentum, plus the ranking the solve artifact reports;
* :class:`EvalSpec`    — optional scoring protocol (recovery or k-fold
  CV against planted truth);
* :class:`ServeSpec`   — optional online workload (trace replay or
  synthetic zipf) played against the serve stack;
* :class:`BenchSpec`   — optional registered-suite benchmark pass;
* :class:`ObsSpec`     — optional telemetry level (off / metrics / trace
  / profile, DESIGN.md §14);
* :class:`DryrunSpec`  — optional multi-pod compile sweep whose HLO
  census lands in the telemetry artifact format.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from typing import Any, Dict, Mapping, Optional, Tuple

_ALGS = ("dhlp1", "dhlp2")
_MODES = ("batched", "sequential")
_SEED_MODES = (None, "fixed", "drift")
_NETWORK_KINDS = ("scenario", "drugnet", "file")
_EVAL_PROTOCOLS = ("recovery", "cv")
_OBS_LEVELS = ("off", "metrics", "trace", "profile")
# mirrors repro.serve.types.PRIORITY_CLASSES (this module stays
# import-light; the sync is asserted by tests/test_api_spec.py)
_PRIORITY_CLASSES = ("interactive", "refresh", "bulk")
_DRYRUN_MESHES = ("single", "multi", "both")
_STORAGE_DTYPES = ("f32", "bf16")
_RUN_ID_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")


class SpecError(ValueError):
    """A spec failed validation (unknown key, bad value, conflict)."""


def _require_mapping(d: Any, path: str) -> Mapping[str, Any]:
    if not isinstance(d, Mapping):
        raise SpecError(f"{path}: expected a mapping, got {type(d).__name__}")
    return d


def _check_keys(cls, d: Mapping[str, Any], path: str) -> None:
    """Strict unknown-key rejection — a typo'd knob must not no-op."""
    allowed = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - allowed)
    if unknown:
        raise SpecError(
            f"{path}: unknown key(s) {unknown}; allowed: {sorted(allowed)}"
        )


def _as_pair(v: Any, path: str) -> Optional[Tuple[int, int]]:
    if v is None:
        return None
    if not isinstance(v, (list, tuple)) or len(v) != 2:
        raise SpecError(f"{path}: expected a [i, j] pair, got {v!r}")
    i, j = v
    if not (isinstance(i, int) and isinstance(j, int)) or i < 0 or j < 0:
        raise SpecError(f"{path}: pair entries must be ints >= 0, got {v!r}")
    return (i, j)


def _positive(value, name: str, *, strict: bool = True) -> None:
    bad = value <= 0 if strict else value < 0
    if bad:
        op = ">" if strict else ">="
        raise SpecError(f"{name} must be {op} 0, got {value}")


# --------------------------------------------------------------------------
# Sections
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """What graph the run operates on.

    ``kind="scenario"`` names a registered workload (``name`` required;
    ``scale``/``seed``/``params`` forwarded to the builder, ``cache``
    overrides the scenario disk cache).  ``kind="drugnet"`` builds the
    paper's case-study network (``params`` = ``DrugNetSpec`` overrides).
    ``kind="file"`` loads a saved network from ``path`` (no ground
    truth, so ``eval`` sections reject it).
    """

    kind: str = "scenario"
    name: Optional[str] = None
    scale: float = 1.0
    seed: int = 0
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    path: Optional[str] = None
    cache: Optional[bool] = None  # None = scenario-cache policy default

    def __post_init__(self) -> None:
        if self.kind not in _NETWORK_KINDS:
            raise SpecError(
                f"network.kind must be one of {_NETWORK_KINDS}, "
                f"got {self.kind!r}"
            )
        _positive(self.scale, "network.scale")
        if not isinstance(self.params, dict):
            raise SpecError("network.params must be a mapping")
        if self.kind == "scenario":
            if not self.name:
                raise SpecError("network.kind='scenario' requires a name")
            if self.path is not None:
                raise SpecError(
                    "network.path conflicts with kind='scenario' (path is "
                    "for kind='file')"
                )
        else:
            if self.name is not None:
                raise SpecError(
                    f"network.name={self.name!r} conflicts with "
                    f"kind={self.kind!r} (name selects a scenario)"
                )
            if self.cache is not None:
                raise SpecError("network.cache applies only to kind='scenario'")
            if self.scale != 1.0:
                raise SpecError(
                    "network.scale applies only to kind='scenario' "
                    "(size drugnet via params, files are fixed)"
                )
        if self.kind == "file":
            if not self.path:
                raise SpecError("network.kind='file' requires a path")
            if self.params:
                raise SpecError(
                    "network.params conflicts with kind='file' (the file "
                    "is self-contained)"
                )
        elif self.kind == "drugnet" and self.path is not None:
            raise SpecError("network.path is for kind='file'")

    @classmethod
    def from_dict(cls, d: Any, path: str = "network") -> "NetworkSpec":
        d = _require_mapping(d, path)
        _check_keys(cls, d, path)
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class SolveSpec:
    """How to propagate, and which ranking the solve artifact reports."""

    alg: str = "dhlp2"
    alpha: float = 0.5
    sigma: float = 1e-3
    mode: str = "batched"
    seed_mode: Optional[str] = None  # None = per-pseudocode default
    backend: Optional[str] = None  # engine-registry key; None = auto policy
    devices: Optional[int] = None  # sharded only
    momentum: float = 0.0
    max_iter: int = 1000
    # mixed precision (sparse/kernel backends): "bf16" stores operator
    # weights + the gather panel in bfloat16 (fp32 state/accumulation)
    storage_dtype: str = "f32"
    # consult the persisted blocked-CSR autotune cache (False pins the
    # layout/panel defaults unconditionally)
    autotune: bool = True
    # the ranking reported by the solve artifact (paper step G)
    top_k: int = 20
    entity: int = 0
    rank_pair: Optional[Tuple[int, int]] = None  # None = the eval pair

    def __post_init__(self) -> None:
        if self.alg not in _ALGS:
            raise SpecError(f"solve.alg must be one of {_ALGS}, got {self.alg!r}")
        if self.storage_dtype not in _STORAGE_DTYPES:
            raise SpecError(
                f"solve.storage_dtype must be one of {_STORAGE_DTYPES}, "
                f"got {self.storage_dtype!r}"
            )
        if not isinstance(self.autotune, bool):
            raise SpecError(
                f"solve.autotune must be true/false, got {self.autotune!r}"
            )
        if self.mode not in _MODES:
            raise SpecError(f"solve.mode must be one of {_MODES}, got {self.mode!r}")
        if self.seed_mode not in _SEED_MODES:
            raise SpecError(
                f"solve.seed_mode must be one of {_SEED_MODES}, "
                f"got {self.seed_mode!r}"
            )
        if not 0.0 < self.alpha < 1.0:
            raise SpecError(f"solve.alpha must be in (0, 1), got {self.alpha}")
        _positive(self.sigma, "solve.sigma")
        _positive(self.max_iter, "solve.max_iter")
        _positive(self.top_k, "solve.top_k")
        _positive(self.momentum, "solve.momentum", strict=False)
        _positive(self.entity, "solve.entity", strict=False)
        if self.devices is not None:
            _positive(self.devices, "solve.devices")
            if self.backend != "sharded":
                raise SpecError(
                    f"solve.devices={self.devices} requires "
                    f"backend='sharded' (got {self.backend!r})"
                )
        object.__setattr__(
            self, "rank_pair", _as_pair(self.rank_pair, "solve.rank_pair")
        )

    @classmethod
    def from_dict(cls, d: Any, path: str = "solve") -> "SolveSpec":
        d = _require_mapping(d, path)
        _check_keys(cls, d, path)
        return cls(**dict(d))

    def to_lp_config(self, *, seed_mode: Optional[str] = None, backend=None):
        """The equivalent :class:`~repro.core.solver.LPConfig` (lazy
        import — this module stays runtime-free)."""
        from repro.core.solver import LPConfig

        return LPConfig(
            alg=self.alg,
            alpha=self.alpha,
            sigma=self.sigma,
            mode=self.mode,
            seed_mode=seed_mode or self.seed_mode,
            momentum=self.momentum,
            max_iter=self.max_iter,
            backend=backend if backend is not None else self.backend,
            storage_dtype=self.storage_dtype,
            autotune=self.autotune,
        )


@dataclasses.dataclass(frozen=True)
class EvalSpec:
    """Scoring protocol against the network's planted ground truth."""

    protocol: str = "recovery"
    folds: int = 5  # cv
    holdout_frac: float = 0.1  # recovery
    max_entities: int = 32  # recovery
    seed: int = 0
    pair: Optional[Tuple[int, int]] = None  # None = the bundle's eval pair

    def __post_init__(self) -> None:
        if self.protocol not in _EVAL_PROTOCOLS:
            raise SpecError(
                f"eval.protocol must be one of {_EVAL_PROTOCOLS}, "
                f"got {self.protocol!r}"
            )
        if self.folds < 2:
            raise SpecError(f"eval.folds must be >= 2, got {self.folds}")
        if not 0.0 < self.holdout_frac < 1.0:
            raise SpecError(
                f"eval.holdout_frac must be in (0, 1), got {self.holdout_frac}"
            )
        _positive(self.max_entities, "eval.max_entities")
        object.__setattr__(self, "pair", _as_pair(self.pair, "eval.pair"))

    @classmethod
    def from_dict(cls, d: Any, path: str = "eval") -> "EvalSpec":
        d = _require_mapping(d, path)
        _check_keys(cls, d, path)
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Online workload played against the serve stack.

    ``trace`` names an arrival process (poisson | bursty | diurnal) for
    scenario trace replay; ``None`` plays the synthetic zipf workload
    the legacy serve CLI used.  ``engine`` is redundant with
    ``solve.backend`` — setting both to different keys is a conflict
    (the session runs ONE engine across solve → eval → serve).

    The pipelined-tier knobs default to production settings
    (``pipeline_depth=2``, ``cache_shards=4``); library users
    constructing a bare :class:`repro.serve.ServeConfig` get the
    conservative synchronous defaults instead.  ``early_exit=None``
    auto-enables per-column convergence early exit whenever the solve
    section permits it (dhlp2, no momentum).
    """

    engine: Optional[str] = None
    trace: Optional[str] = None
    # synthetic-workload knobs (trace=None); source/target default to the
    # bundle's eval pair — setting them points the zipf workload at any
    # other (source, target) type pair
    requests: int = 200
    zipf: float = 1.3
    deltas: int = 0
    source_type: Optional[int] = None
    target_type: Optional[int] = None
    # trace-replay knobs
    rate_qps: float = 40.0
    horizon_s: float = 3.0
    time_scale: float = 1.0
    apply_deltas: bool = True
    # engine knobs
    top_k: int = 20
    cache_columns: int = 4096
    warm_start: bool = True
    refresh_rounds: int = 0
    max_batch: int = 64
    max_wait_ms: float = 2.0
    queue_depth: int = 1024
    # pipelined-tier knobs (DESIGN.md §9.1)
    pipeline_depth: int = 2       # 1 = synchronous tick, 2 = double-buffered
    cache_shards: int = 4         # independently-locked column-cache shards
    early_exit: Optional[bool] = None  # None = auto (dhlp2 w/o momentum)
    priority: str = "interactive"      # admission class for replayed queries

    def __post_init__(self) -> None:
        if self.trace is not None and (
            not isinstance(self.trace, str) or not self.trace
        ):
            raise SpecError(
                f"serve.trace must be an arrival-process name, "
                f"got {self.trace!r}"
            )
        _positive(self.requests, "serve.requests")
        if self.zipf <= 1.0:
            raise SpecError(f"serve.zipf must be > 1, got {self.zipf}")
        _positive(self.deltas, "serve.deltas", strict=False)
        for knob, value in (
            ("source_type", self.source_type),
            ("target_type", self.target_type),
        ):
            if value is not None:
                if not isinstance(value, int) or isinstance(value, bool):
                    raise SpecError(
                        f"serve.{knob} must be a node-type index, got {value!r}"
                    )
                _positive(value, f"serve.{knob}", strict=False)
                if self.trace is not None:
                    raise SpecError(
                        f"serve.{knob} applies to the zipf workload only "
                        "(trace replays carry their own query targets)"
                    )
        _positive(self.rate_qps, "serve.rate_qps")
        _positive(self.horizon_s, "serve.horizon_s")
        _positive(self.time_scale, "serve.time_scale")
        _positive(self.top_k, "serve.top_k")
        _positive(self.cache_columns, "serve.cache_columns")
        _positive(self.refresh_rounds, "serve.refresh_rounds", strict=False)
        _positive(self.max_batch, "serve.max_batch")
        _positive(self.max_wait_ms, "serve.max_wait_ms", strict=False)
        _positive(self.queue_depth, "serve.queue_depth")
        _positive(self.pipeline_depth, "serve.pipeline_depth")
        _positive(self.cache_shards, "serve.cache_shards")
        if self.cache_shards > self.cache_columns:
            raise SpecError(
                f"serve.cache_shards={self.cache_shards} > "
                f"serve.cache_columns={self.cache_columns}: every shard "
                "needs at least one slot"
            )
        if self.early_exit is not None and not isinstance(
            self.early_exit, bool
        ):
            raise SpecError(
                f"serve.early_exit must be true/false/null, "
                f"got {self.early_exit!r}"
            )
        if self.priority not in _PRIORITY_CLASSES:
            raise SpecError(
                f"serve.priority must be one of {_PRIORITY_CLASSES}, "
                f"got {self.priority!r}"
            )

    @classmethod
    def from_dict(cls, d: Any, path: str = "serve") -> "ServeSpec":
        d = _require_mapping(d, path)
        _check_keys(cls, d, path)
        return cls(**dict(d))

    def resolved_early_exit(self, solve: "SolveSpec") -> bool:
        """Whether batch solves run the per-column early-exit loop.

        ``None`` auto-enables exactly when the solve section permits it:
        dhlp2 (the loop rides the fused-round contract) without momentum
        (the loop is the plain heavy-ball-free update).
        """
        if self.early_exit is not None:
            return self.early_exit
        return solve.alg == "dhlp2" and not solve.momentum


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    """A registered-suite benchmark pass through ``repro.bench``."""

    suites: Optional[Tuple[str, ...]] = None  # None = every registered suite
    fast: bool = True
    label: Optional[str] = None  # None = "ci" (fast) / "full"

    def __post_init__(self) -> None:
        if self.suites is not None:
            if not isinstance(self.suites, (list, tuple)) or not all(
                isinstance(s, str) and s for s in self.suites
            ):
                raise SpecError(
                    f"bench.suites must be suite names, got {self.suites!r}"
                )
            object.__setattr__(self, "suites", tuple(self.suites))

    @classmethod
    def from_dict(cls, d: Any, path: str = "bench") -> "BenchSpec":
        d = _require_mapping(d, path)
        _check_keys(cls, d, path)
        return cls(**dict(d))

    def resolved_label(self) -> str:
        return self.label or ("ci" if self.fast else "full")


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Service-level objectives the live watchdog evaluates per flush
    window (DESIGN.md §14.9).

    Each objective is optional but at least one must be set.
    ``latency_p95_ms`` bounds the windowed p95 of interactive serve
    latency; ``error_rate`` caps (failed + rejected) / completed-or-
    errored traffic; ``cache_hit_floor`` is the minimum column-cache hit
    ratio under lookup traffic; ``stall_windows`` flags a convergence
    stall when the solve residual stops improving for that many
    consecutive windows.  ``burn_windows`` consecutive violating windows
    raise a breach (and escalate serve degradation one rung);
    ``recovery_windows`` consecutive clean windows restore.
    """

    latency_p95_ms: Optional[float] = None
    error_rate: Optional[float] = None
    cache_hit_floor: Optional[float] = None
    stall_windows: Optional[int] = None
    burn_windows: int = 3
    recovery_windows: int = 2

    def __post_init__(self) -> None:
        objectives = (
            self.latency_p95_ms,
            self.error_rate,
            self.cache_hit_floor,
            self.stall_windows,
        )
        if all(v is None for v in objectives):
            raise SpecError(
                "obs.slo: at least one objective required "
                "(latency_p95_ms / error_rate / cache_hit_floor / stall_windows)"
            )
        if self.latency_p95_ms is not None:
            _positive(self.latency_p95_ms, "obs.slo.latency_p95_ms")
        for name in ("error_rate", "cache_hit_floor"):
            value = getattr(self, name)
            if value is not None and not 0.0 <= value <= 1.0:
                raise SpecError(
                    f"obs.slo.{name} must be in [0, 1], got {value}"
                )
        if self.stall_windows is not None:
            _positive(self.stall_windows, "obs.slo.stall_windows")
        _positive(self.burn_windows, "obs.slo.burn_windows")
        _positive(self.recovery_windows, "obs.slo.recovery_windows")

    @classmethod
    def from_dict(cls, d: Any, path: str = "obs.slo") -> "SLOSpec":
        d = _require_mapping(d, path)
        _check_keys(cls, d, path)
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Telemetry level + live-streaming knobs for the run (DESIGN.md §14).

    ``metrics`` records counters/gauges/histograms + structural spans;
    ``trace`` adds per-superstep and per-query spans; ``profile`` adds
    the ``jax.profiler`` capture and kernel timing hooks.  Writing the
    section at all defaults to ``metrics`` — an explicit ``off`` keeps
    the spec round-trippable while disabling collection.

    ``flush_interval_s`` turns on live streaming: telemetry flushes
    incrementally at that cadence while the run executes, so
    ``repro obs --follow`` can tail it.  ``export`` controls the
    OpenMetrics ``metrics.prom`` snapshot written on each flush (and the
    final one).  ``slo`` declares watchdog objectives — it requires
    streaming (``flush_interval_s``) because evaluation is per flush
    window, and a level that actually collects.
    """

    level: str = "metrics"
    flush_interval_s: Optional[float] = None
    export: bool = True
    slo: Optional[SLOSpec] = None

    def __post_init__(self) -> None:
        if self.level not in _OBS_LEVELS:
            raise SpecError(
                f"obs.level must be one of {_OBS_LEVELS}, got {self.level!r}"
            )
        if self.flush_interval_s is not None:
            _positive(self.flush_interval_s, "obs.flush_interval_s")
        if self.slo is not None:
            if self.flush_interval_s is None:
                raise SpecError(
                    "obs.slo requires obs.flush_interval_s: the watchdog "
                    "evaluates per streaming flush window"
                )
            if self.level == "off":
                raise SpecError("obs.slo requires obs.level != 'off'")

    @classmethod
    def from_dict(cls, d: Any, path: str = "obs") -> "ObsSpec":
        d = _require_mapping(d, path)
        _check_keys(cls, d, path)
        d = dict(d)
        if d.get("slo") is not None:
            d["slo"] = SLOSpec.from_dict(d["slo"], f"{path}.slo")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FTSpec:
    """Fault-tolerance & durability knobs for the run (DESIGN.md §16).

    Writing the section turns durability on: the solve stage checkpoints
    label state + its outer-iteration cursor every ``interval``
    supersteps through :class:`repro.checkpoint.CheckpointManager` (so a
    killed run resumes via ``repro run --resume <run_id>`` with
    byte-identical final rankings), and the serve tier wraps solver-batch
    execution in :class:`repro.ft.StepGuard` — transient faults retry
    with backoff, exhaustion restores from the last cache snapshot and
    replays the in-flight batch.

    ``ckpt_dir=None`` defaults to ``checkpoints/`` inside the run's
    artifact directory.  ``interval`` counts supersteps for the solve and
    solver batches for the serve tier.  The ``inject_*`` knobs arm the
    deterministic :class:`repro.ft.FailureInjector` for recovery drills:
    ``inject_solve_fault`` kills the solve at those supersteps (a fresh
    run only — a resumed run never re-fires, a real crash is not
    deterministic either), ``inject_serve_fault`` raises a transient
    fault in the solver thread at those batch indices.
    """

    ckpt_dir: Optional[str] = None
    interval: int = 5
    keep_last: int = 3
    async_write: bool = False
    max_retries: int = 3
    backoff_s: float = 0.05
    straggler_alpha: float = 0.1
    straggler_threshold: float = 2.0
    inject_solve_fault: Tuple[int, ...] = ()
    inject_serve_fault: Tuple[int, ...] = ()

    def __post_init__(self) -> None:
        if self.ckpt_dir is not None and (
            not isinstance(self.ckpt_dir, str) or not self.ckpt_dir
        ):
            raise SpecError(f"ft.ckpt_dir must be a path, got {self.ckpt_dir!r}")
        if not isinstance(self.interval, int) or isinstance(self.interval, bool):
            raise SpecError(f"ft.interval must be an int, got {self.interval!r}")
        _positive(self.interval, "ft.interval")
        _positive(self.keep_last, "ft.keep_last")
        if self.max_retries < 0:
            raise SpecError(f"ft.max_retries must be >= 0, got {self.max_retries}")
        _positive(self.backoff_s, "ft.backoff_s", strict=False)
        if not 0.0 < self.straggler_alpha <= 1.0:
            raise SpecError(
                f"ft.straggler_alpha must be in (0, 1], got {self.straggler_alpha}"
            )
        if self.straggler_threshold <= 1.0:
            raise SpecError(
                "ft.straggler_threshold must be > 1 (a straggler is slower "
                f"than the mean), got {self.straggler_threshold}"
            )
        for knob in ("inject_solve_fault", "inject_serve_fault"):
            value = getattr(self, knob)
            if not isinstance(value, (list, tuple)) or not all(
                isinstance(s, int) and not isinstance(s, bool) and s >= 0
                for s in value
            ):
                raise SpecError(
                    f"ft.{knob} must be step indices, got {value!r}"
                )
            object.__setattr__(self, knob, tuple(value))

    @classmethod
    def from_dict(cls, d: Any, path: str = "ft") -> "FTSpec":
        d = _require_mapping(d, path)
        _check_keys(cls, d, path)
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """A model-training run (lm / gnn / recsys arch families).

    Folds the ``launch/train`` driver behind the declarative API: the
    arch registry resolves ``arch`` to a family, the session runs the
    guarded training loop (periodic checkpoints, retry/restore on
    transient failures, straggler watch, optional injected faults).
    LP-family archs are rejected at session resolution — label
    propagation runs via a ``solve`` section.
    """

    arch: str = ""
    steps: int = 50
    batch: int = 8
    seq: int = 128
    full: bool = False  # full pod-scale config (default: reduced)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    ckpt_async: bool = False
    inject_fault: Tuple[int, ...] = ()  # steps that raise a transient fault
    log_every: int = 10

    def __post_init__(self) -> None:
        if not self.arch or not isinstance(self.arch, str):
            raise SpecError("train.arch is required (a registered arch name)")
        _positive(self.steps, "train.steps")
        _positive(self.batch, "train.batch")
        _positive(self.seq, "train.seq")
        _positive(self.ckpt_every, "train.ckpt_every")
        _positive(self.log_every, "train.log_every")
        if not isinstance(self.inject_fault, (list, tuple)) or not all(
            isinstance(s, int) and not isinstance(s, bool) and s >= 0
            for s in self.inject_fault
        ):
            raise SpecError(
                f"train.inject_fault must be step indices, "
                f"got {self.inject_fault!r}"
            )
        object.__setattr__(self, "inject_fault", tuple(self.inject_fault))
        if self.ckpt_async and self.ckpt_dir is None:
            raise SpecError("train.ckpt_async requires train.ckpt_dir")

    @classmethod
    def from_dict(cls, d: Any, path: str = "train") -> "TrainSpec":
        d = _require_mapping(d, path)
        _check_keys(cls, d, path)
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class DryrunSpec:
    """A multi-pod compile sweep (lower + compile every config cell).

    ``archs=None`` sweeps every assigned (arch × shape) cell; naming
    ``archs`` restricts the sweep (``shapes`` then applies to each named
    arch).  The per-cell HLO census is emitted through the telemetry
    artifact format (``telemetry/dryrun.jsonl``) that
    ``benchmarks/roofline.py`` consumes.
    """

    archs: Optional[Tuple[str, ...]] = None
    shapes: Optional[Tuple[str, ...]] = None
    mesh: str = "single"
    include_extra: bool = False

    def __post_init__(self) -> None:
        if self.mesh not in _DRYRUN_MESHES:
            raise SpecError(
                f"dryrun.mesh must be one of {_DRYRUN_MESHES}, got {self.mesh!r}"
            )
        for knob, value in (("archs", self.archs), ("shapes", self.shapes)):
            if value is not None:
                if not isinstance(value, (list, tuple)) or not all(
                    isinstance(s, str) and s for s in value
                ):
                    raise SpecError(f"dryrun.{knob} must be names, got {value!r}")
                object.__setattr__(self, knob, tuple(value))
        if self.shapes is not None and self.archs is None:
            raise SpecError("dryrun.shapes requires dryrun.archs")

    @classmethod
    def from_dict(cls, d: Any, path: str = "dryrun") -> "DryrunSpec":
        d = _require_mapping(d, path)
        _check_keys(cls, d, path)
        return cls(**dict(d))


# --------------------------------------------------------------------------
# The composed run
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One declarative job: network × solve × (eval? serve? bench? …)."""

    #: None is allowed ONLY for a train- and/or dryrun-only spec — those
    #: stages exercise model configs, not a propagation network
    network: Optional[NetworkSpec] = None
    #: None = default solve parameters; the solve STAGE runs when this
    #: section is explicitly present, or when no other stage is configured
    solve: Optional[SolveSpec] = None
    eval: Optional[EvalSpec] = None
    serve: Optional[ServeSpec] = None
    bench: Optional[BenchSpec] = None
    obs: Optional[ObsSpec] = None
    ft: Optional[FTSpec] = None
    train: Optional[TrainSpec] = None
    dryrun: Optional[DryrunSpec] = None
    run_id: Optional[str] = None  # None = deterministic content-derived id

    def __post_init__(self) -> None:
        if self.run_id is not None and not _RUN_ID_RE.match(self.run_id):
            raise SpecError(
                f"run_id {self.run_id!r} is not filesystem-safe "
                "([A-Za-z0-9._-], no leading punctuation)"
            )
        sections = self.sections()
        if self.network is None and not (
            sections and all(s in ("train", "dryrun") for s in sections)
        ):
            raise SpecError(
                "runspec: a 'network' section is required (only a "
                "train- and/or dryrun-only spec runs without one)"
            )
        solve = self.resolved_solve()
        if self.serve is not None:
            if (
                self.serve.engine is not None
                and solve.backend is not None
                and self.serve.engine != solve.backend
            ):
                raise SpecError(
                    f"serve.engine={self.serve.engine!r} conflicts with "
                    f"solve.backend={solve.backend!r}; the session "
                    "runs one engine — set one key (or both to the same)"
                )
            if solve.seed_mode == "drift":
                raise SpecError(
                    "serve requires solve.seed_mode='fixed' (warm starts "
                    "need the F0-independent fixed point, DESIGN.md §9)"
                )
            if self.serve.early_exit:
                if solve.alg != "dhlp2":
                    raise SpecError(
                        "serve.early_exit=true requires solve.alg='dhlp2' "
                        "(the per-column loop rides the fused DHLP-2 "
                        "round contract)"
                    )
                if solve.momentum:
                    raise SpecError(
                        "serve.early_exit=true conflicts with "
                        "solve.momentum — the early-exit loop is the "
                        "plain heavy-ball-free update (set early_exit "
                        "to false or null)"
                    )
        if self.eval is not None and self.network.kind == "file":
            raise SpecError(
                "eval sections need planted ground truth; "
                "network.kind='file' carries none"
            )
        if self.ft is not None:
            stages = set(self.sections())
            if not ({"solve", "serve"} & stages):
                raise SpecError(
                    "ft: nothing to protect — the section governs the "
                    "solve and serve stages"
                )
            if "solve" in stages:
                if solve.alg != "dhlp2" or solve.mode != "batched":
                    raise SpecError(
                        "ft superstep checkpointing rides the host-driven "
                        "batched DHLP-2 round contract; set "
                        "solve.alg='dhlp2' and solve.mode='batched'"
                    )
                seed_mode = solve.seed_mode or (
                    "fixed" if self.serve is not None else "drift"
                )
                if seed_mode != "fixed":
                    raise SpecError(
                        "ft requires solve.seed_mode='fixed' — a resumed "
                        "run replays from a checkpointed label panel, "
                        "which drifting seeds would invalidate"
                    )

    # ----------------------------------------------------------- round-trip
    @classmethod
    def from_dict(cls, d: Any) -> "RunSpec":
        d = _require_mapping(d, "runspec")
        _check_keys(cls, d, "runspec")
        networkless_ok = (
            d.get("dryrun") is not None or d.get("train") is not None
        ) and not any(
            d.get(k) is not None for k in ("solve", "eval", "serve", "bench")
        )
        if "network" not in d and not networkless_ok:
            raise SpecError("runspec: a 'network' section is required")
        return cls(
            network=(
                NetworkSpec.from_dict(d["network"])
                if d.get("network") is not None
                else None
            ),
            solve=(
                SolveSpec.from_dict(d["solve"])
                if d.get("solve") is not None
                else None
            ),
            eval=(EvalSpec.from_dict(d["eval"]) if d.get("eval") is not None else None),
            serve=(
                ServeSpec.from_dict(d["serve"])
                if d.get("serve") is not None
                else None
            ),
            bench=(
                BenchSpec.from_dict(d["bench"])
                if d.get("bench") is not None
                else None
            ),
            obs=(ObsSpec.from_dict(d["obs"]) if d.get("obs") is not None else None),
            ft=(FTSpec.from_dict(d["ft"]) if d.get("ft") is not None else None),
            train=(
                TrainSpec.from_dict(d["train"])
                if d.get("train") is not None
                else None
            ),
            dryrun=(
                DryrunSpec.from_dict(d["dryrun"])
                if d.get("dryrun") is not None
                else None
            ),
            run_id=d.get("run_id"),
        )

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, s: str) -> "RunSpec":
        try:
            d = json.loads(s)
        except json.JSONDecodeError as e:
            raise SpecError(f"runspec: invalid JSON ({e})") from e
        return cls.from_dict(d)

    def to_json(self, *, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_file(cls, path: str) -> "RunSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    # ------------------------------------------------------------ identity
    def content_hash(self) -> str:
        """Stable digest of the spec content (run_id excluded)."""
        d = self.to_dict()
        d.pop("run_id", None)
        blob = json.dumps(d, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:10]

    def resolved_solve(self) -> SolveSpec:
        """The solve parameters eval/serve stages run under (defaults
        when no ``solve`` section was written)."""
        return self.solve if self.solve is not None else SolveSpec()

    def resolved_run_id(self) -> str:
        """Explicit ``run_id``, else a deterministic content-derived slug
        — the same spec always lands in the same ``results/<run_id>/``."""
        if self.run_id:
            return self.run_id
        if self.network is None:
            prefix = "train" if self.dryrun is None else "dryrun"
            return f"{prefix}-{self.content_hash()}"
        solve = self.resolved_solve()
        net = self.network.name or self.network.kind
        backend = solve.backend or "auto"
        return f"{net}-{solve.alg}-{backend}-{self.content_hash()}"

    def sections(self) -> Tuple[str, ...]:
        """The configured run stages, in execution order.

        ``solve`` runs when its section is explicitly present — or when
        nothing else is, so a bare ``{"network": ...}`` spec is a solve.
        (``obs`` is cross-cutting, not a stage; ``train`` and ``dryrun``
        never imply a solve.)
        """
        out = []
        others = [self.eval, self.serve, self.bench, self.train, self.dryrun]
        if self.solve is not None or not any(s is not None for s in others):
            out.append("solve")
        if self.eval is not None:
            out.append("eval")
        if self.serve is not None:
            out.append("serve")
        if self.bench is not None:
            out.append("bench")
        if self.train is not None:
            out.append("train")
        if self.dryrun is not None:
            out.append("dryrun")
        return tuple(out)
