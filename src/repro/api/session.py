"""Session: resolve a RunSpec once, run its stages (DESIGN.md §13).

A :class:`Session` is the one place a spec meets the runtime registries:

* the network is built once (scenario generate → disk cache, drugnet
  adapter, or ``.npz`` load) and normalized once;
* ONE engine is instantiated from the resolved backend and its
  ``prepare()`` operator cache is shared across ``solve()`` and
  ``serve()`` (both run on the same normalized-network identity), so a
  combined solve→serve run assembles and uploads the operator once
  instead of once per entry point;
* stages return typed :class:`~repro.api.artifacts.Artifact` objects and
  :meth:`run` writes them under ``results/<run_id>/``.

Evaluation runs on a *sibling* engine with the same config: its folds
solve masked copies of the network, and letting those churn the main
engine's single-entry operator cache would force serve to re-prepare.
"""

from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.api.artifacts import (
    Artifact,
    BenchArtifact,
    DryrunArtifact,
    EvalArtifact,
    ServeArtifact,
    SolveArtifact,
    TrainArtifact,
    _write_json,
)
from repro.api.spec import DryrunSpec, EvalSpec, RunSpec, ServeSpec, SpecError

_UNSET = object()


class Session:
    """A resolved RunSpec: shared network, shared engine, staged runs."""

    def __init__(
        self,
        spec: RunSpec,
        *,
        results_root: str = "results",
        bundle=None,
    ):
        """``bundle`` injects an already-generated ScenarioBundle so
        multi-backend sweeps (the scenario CLI) pay generation once."""
        self.spec = spec
        self.run_id = spec.resolved_run_id()
        self.run_dir = os.path.join(results_root, self.run_id)
        self._bundle: Any = _UNSET if bundle is None else bundle
        self._network: Any = None if bundle is None else bundle.network
        self._norm: Any = None
        self._backend: Optional[str] = None
        self._engine: Any = None
        self._eval_engine: Any = None
        self._telemetry: Any = None
        self._watchdog: Any = None

    # ------------------------------------------------------------- network
    @property
    def bundle(self):
        """The ScenarioBundle behind the network (None for file loads)."""
        if self._bundle is _UNSET:
            self._resolve_network()
        return self._bundle

    @property
    def network(self):
        if self._network is None:
            self._resolve_network()
        return self._network

    @property
    def norm(self):
        """The one normalized view every stage shares (prepare-cache key)."""
        if self._norm is None:
            self._norm = self.network.normalize()
        return self._norm

    def _trace_coupled_params(self, sc) -> Dict[str, Any]:
        """Builder params, plus the serve replay's horizon/rate when the
        builder accepts them and the spec leaves them unset.

        Scenarios that schedule their own timed workload (streaming) must
        schedule it against THIS spec's replay horizon, or tail deltas
        would land past the last query and silently never apply — the
        invariant ``benchmarks/serve_bench.py`` has always kept.
        """
        ns = self.spec.network
        sv = self.spec.serve
        params = dict(ns.params)
        if sv is not None and sv.trace is not None:
            import inspect

            accepted = inspect.signature(sc.get_scenario(ns.name).fn).parameters
            for key, value in (
                ("horizon_s", sv.horizon_s),
                ("rate_qps", sv.rate_qps),
            ):
                if key in accepted and key not in params:
                    params[key] = value
        return params

    def _resolve_network(self) -> None:
        ns = self.spec.network
        if ns.kind == "scenario":
            import repro.scenarios as sc

            bundle = sc.generate(
                ns.name,
                scale=ns.scale,
                seed=ns.seed,
                cache=ns.cache,
                **self._trace_coupled_params(sc),
            )
        elif ns.kind == "drugnet":
            from repro.data.drugnet import DrugNetSpec, make_drugnet
            from repro.scenarios.base import ScenarioBundle

            try:
                dn = make_drugnet(DrugNetSpec(seed=ns.seed, **ns.params))
            except TypeError as e:
                raise SpecError(f"network.params: {e}") from e
            bundle = ScenarioBundle(
                name="drugnet",
                network=dn.network,
                truth=dn.truth or {},
                eval_pair=(0, 2),
                clusters=dn.clusters,
            )
        else:  # file
            from repro.core.network import HeteroNetwork

            net = HeteroNetwork.load_npz(ns.path)
            self._bundle, self._network = None, net
            return
        self._bundle, self._network = bundle, bundle.network

    # -------------------------------------------------------------- engine
    def lp_config(self):
        """The session-wide LPConfig.

        ``seed_mode`` left unset resolves to ``"fixed"`` when the spec
        has a serve section — the whole session must then converge to
        the F0-independent fixed point, or solve and serve would answer
        from different math.
        """
        solve = self.spec.resolved_solve()
        seed_mode = solve.seed_mode
        if seed_mode is None and self.spec.serve is not None:
            seed_mode = "fixed"
        return solve.to_lp_config(seed_mode=seed_mode, backend=self.backend)

    @property
    def backend(self) -> str:
        """The resolved engine-registry key (``auto`` resolved once)."""
        if self._backend is None:
            from repro.engine import resolve_backend

            solve = self.spec.resolved_solve()
            requested = solve.backend
            if requested is None and self.spec.serve is not None:
                requested = self.spec.serve.engine
            self._backend = resolve_backend(
                requested, num_nodes=self.network.num_nodes
            )
        return self._backend

    def _engine_kwargs(self) -> Dict[str, Any]:
        solve = self.spec.resolved_solve()
        if self.backend == "sharded" and solve.devices:
            return {"devices": solve.devices}
        return {}

    @property
    def engine(self):
        """The one prepared engine solve and serve share."""
        if self._engine is None:
            from repro.engine import make_engine

            self._engine = make_engine(
                self.backend, self.lp_config(), **self._engine_kwargs()
            )
        return self._engine

    @property
    def eval_engine(self):
        """Same config, separate operator cache (masked-fold churn)."""
        if self._eval_engine is None:
            from repro.engine import make_engine

            self._eval_engine = make_engine(
                self.backend, self.lp_config(), **self._engine_kwargs()
            )
        return self._eval_engine

    @property
    def telemetry(self):
        """The session-wide Telemetry (level from ``spec.obs``, else off).

        Always a live object: stage code records unconditionally and the
        off level suppresses at the sink (DESIGN.md §14.2's overhead
        policy), so there is exactly one instrumentation code path.
        """
        if self._telemetry is None:
            from repro.obs import Telemetry

            obs = self.spec.obs
            level = obs.level if obs is not None else "off"
            export = obs.export if obs is not None else True
            self._telemetry = Telemetry(level, run_id=self.run_id, export=export)
        return self._telemetry

    def _network_desc(self) -> Dict[str, Any]:
        net = self.network
        ns = self.spec.network
        return {
            "kind": ns.kind,
            "name": ns.name or (ns.path if ns.kind == "file" else "drugnet"),
            "scale": ns.scale,
            "seed": ns.seed,
            "types": net.num_types,
            "nodes": net.num_nodes,
            "edges": net.num_edges,
        }

    def _rank_pair(self, explicit: Optional[Tuple[int, int]]) -> Tuple[int, int]:
        if explicit is not None:
            return explicit
        if self.bundle is not None:
            return tuple(self.bundle.eval_pair)
        return (0, self.network.num_types - 1)

    # ----------------------------------------------------- fault tolerance
    def ft_ckpt_dir(self, namespace: str) -> str:
        """Checkpoint root for one stage (``solve`` / ``serve``).

        Defaults under the run directory, so re-running the same spec with
        the same ``run_id`` (``repro run --resume``) finds the durable
        steps without any extra plumbing; ``ft.ckpt_dir`` overrides for
        shared/scratch filesystems.
        """
        ft = self.spec.ft
        root = (
            ft.ckpt_dir
            if ft is not None and ft.ckpt_dir
            else os.path.join(self.run_dir, "checkpoints")
        )
        return os.path.join(root, namespace)

    def _checkpointed_solve(self):
        """The durable solve path (``spec.ft`` set): superstep barriers
        through a CheckpointManager, resume from the latest durable step."""
        from repro.checkpoint import CheckpointManager
        from repro.ft import FailureInjector, StragglerWatch
        from repro.ft.solve import checkpointed_solve, supports_checkpointed

        ft = self.spec.ft
        if not supports_checkpointed(self.engine):
            raise SpecError(
                f"ft: backend {self.backend!r} has no engine.round "
                "contract — the checkpointed superstep loop needs it"
            )
        tel = self.telemetry
        straggler = StragglerWatch(
            alpha=ft.straggler_alpha,
            threshold=ft.straggler_threshold,
            telemetry=tel,
        )
        injector = (
            FailureInjector(fail_at=ft.inject_solve_fault)
            if ft.inject_solve_fault
            else None
        )
        manager = CheckpointManager(
            self.ft_ckpt_dir("solve"),
            keep_last=ft.keep_last,
            async_write=ft.async_write,
        )
        try:
            res, stats = checkpointed_solve(
                self.engine,
                self.norm,
                manager=manager,
                interval=ft.interval,
                telemetry=tel,
                injector=injector,
                straggler=straggler,
            )
        finally:
            # an injected (or real) mid-solve crash must still drain the
            # writer queue — the durable step is what --resume restarts from
            manager.close()
        stats["straggler_flags"] = straggler.slow_steps
        if injector is not None:
            stats["injected_faults"] = list(injector.fired)
        return res, stats

    # -------------------------------------------------------------- stages
    def solve(self) -> SolveArtifact:
        from repro.core.ranking import extract_outputs

        solve = self.spec.resolved_solve()
        tel = self.telemetry
        t0 = time.perf_counter()
        ft_stats: Dict[str, Any] = {}
        if self.spec.ft is not None:
            res, ft_stats = self._checkpointed_solve()
        elif tel.enabled:
            from repro.obs.solve import observed_solve, supports_observed

            if supports_observed(self.engine):
                # host-driven round loop: per-superstep residual/active
                # series for `repro obs` (same fixed point, DESIGN.md §14.3)
                res = observed_solve(self.engine, self.norm, telemetry=tel)
            else:
                res = self.engine.run(self.norm)
                tel.count("solve.supersteps", int(res.supersteps))
        else:
            res = self.engine.run(self.norm)
        seconds = time.perf_counter() - t0
        outputs = extract_outputs(res.F, self.norm)
        pair = self._rank_pair(solve.rank_pair)
        top = outputs.ranked_candidates(pair, solve.entity, solve.top_k)
        i, j = pair
        if (i, j) in outputs.interactions:
            row = outputs.interactions[(i, j)][solve.entity]
        else:
            row = outputs.interactions[(j, i)][:, solve.entity]
        scores = np.asarray(row[top], dtype=np.float64)
        return SolveArtifact(
            run_id=self.run_id,
            seconds=seconds,
            backend=self.backend,
            alg=solve.alg,
            converged=bool(res.converged),
            outer_iters=int(res.outer_iters),
            inner_iters=int(res.inner_iters),
            supersteps=int(res.supersteps),
            network=self._network_desc(),
            ranking={
                "pair": list(pair),
                "entity": solve.entity,
                "top_k": solve.top_k,
                "candidates": [int(c) for c in top],
                "scores": [float(s) for s in scores],
            },
            ft=ft_stats,
            F=res.F,
            outputs=outputs,
        )

    def evaluate(self) -> EvalArtifact:
        import repro.scenarios as sc
        from repro.eval.cv import summarize

        ev = self.spec.eval if self.spec.eval is not None else EvalSpec()
        if self.bundle is None or not self.bundle.truth:
            raise SpecError(
                "evaluate() needs planted ground truth — "
                f"network kind {self.spec.network.kind!r} has none"
            )
        pair = ev.pair or tuple(self.bundle.eval_pair)
        t0 = time.perf_counter()
        if ev.protocol == "recovery":
            problem = sc.make_recovery_problem(
                self.bundle,
                pair,
                holdout_frac=ev.holdout_frac,
                max_entities=ev.max_entities,
                seed=ev.seed,
            )
            res = self.eval_engine.run(problem.masked_net, seeds=problem.Y)
            metrics = problem.metrics(res.F)
            metrics["outer_iters"] = float(res.outer_iters)
            F = res.F
            params = {
                "holdout_frac": ev.holdout_frac,
                "max_entities": ev.max_entities,
                "seed": ev.seed,
            }
        else:  # cv
            results = sc.scenario_cross_validate(
                self.bundle,
                pair=pair,
                backend=self.backend,
                k=ev.folds,
                seed=ev.seed,
                lp=self.lp_config(),
                engine=self.eval_engine,
            )
            metrics = summarize(results)
            F = None
            params = {"folds": ev.folds, "seed": ev.seed}
        return EvalArtifact(
            run_id=self.run_id,
            seconds=time.perf_counter() - t0,
            protocol=ev.protocol,
            backend=self.backend,
            pair=tuple(pair),
            params=params,
            metrics={k: float(v) for k, v in metrics.items()},
            F=F,
        )

    # --------------------------------------------------------------- serve
    def serve_engine(self, sv: Optional[ServeSpec] = None):
        """An LPServeEngine wired to the session's prepared engine."""
        from repro.serve import LPServeEngine, ServeConfig

        sv = sv or self.spec.serve or ServeSpec()
        cfg = ServeConfig(
            lp=self.lp_config(),
            cache_columns=sv.cache_columns,
            cache_shards=sv.cache_shards,
            warm_start=sv.warm_start,
            refresh_rounds=sv.refresh_rounds,
            max_batch=sv.max_batch,
            max_wait_s=sv.max_wait_ms / 1e3,
            queue_depth=sv.queue_depth,
            pipeline_depth=sv.pipeline_depth,
            early_exit=sv.resolved_early_exit(self.spec.resolved_solve()),
        )
        engine = LPServeEngine(
            self.network,
            cfg,
            engine=self.engine,
            norm=self.norm,
            telemetry=self.telemetry,
        )
        ft = self.spec.ft
        if ft is not None:
            from repro.checkpoint import CheckpointManager
            from repro.ft import FailureInjector, StepGuard, StragglerWatch

            engine.enable_ft(
                guard=StepGuard(
                    max_retries=ft.max_retries,
                    backoff_s=ft.backoff_s,
                    telemetry=self.telemetry,
                ),
                straggler=StragglerWatch(
                    alpha=ft.straggler_alpha,
                    threshold=ft.straggler_threshold,
                    telemetry=self.telemetry,
                ),
                injector=(
                    FailureInjector(fail_at=ft.inject_serve_fault)
                    if ft.inject_serve_fault
                    else None
                ),
                manager=CheckpointManager(
                    self.ft_ckpt_dir("serve"),
                    keep_last=ft.keep_last,
                    async_write=ft.async_write,
                ),
                interval=ft.interval,
            )
        obs = self.spec.obs
        if obs is not None and obs.slo is not None:
            from repro.obs import ServeDegradation, SLOWatchdog

            if self._watchdog is not None:
                # bench sweeps build several engines per session; only the
                # newest one's knobs should answer to the watchdog
                self._watchdog.detach()
            self._watchdog = SLOWatchdog.from_spec(
                obs.slo,
                self.telemetry,
                degradation=ServeDegradation(engine),
            ).attach()
        return engine

    def serve(self) -> ServeArtifact:
        from repro.serve.replay import play_zipf, replay_trace

        sv = self.spec.serve if self.spec.serve is not None else ServeSpec()
        engine = self.serve_engine(sv)
        t0 = time.perf_counter()
        try:
            if sv.trace is not None:
                import repro.scenarios as sc

                if self.bundle is None:
                    raise SpecError(
                        "serve.trace replay needs a scenario/drugnet network "
                        "(file networks carry no trace schema)"
                    )
                trace = sc.build_trace(
                    self.bundle,
                    sv.trace,
                    rate_qps=sv.rate_qps,
                    horizon_s=sv.horizon_s,
                    seed=self.spec.network.seed,
                )
                if len(trace) == 0:
                    raise SpecError(
                        f"serve.trace: the {sv.trace} trace came out empty "
                        f"(rate_qps={sv.rate_qps}, horizon_s={sv.horizon_s}); "
                        "raise one of them"
                    )
                report = replay_trace(
                    engine,
                    trace,
                    self.bundle.deltas if sv.apply_deltas else (),
                    top_k=sv.top_k,
                    time_scale=sv.time_scale,
                    priority=sv.priority,
                    telemetry=self.telemetry,
                )
                mode = "trace"
            else:
                pair = self._rank_pair(None)
                src = sv.source_type if sv.source_type is not None else pair[0]
                dst = sv.target_type if sv.target_type is not None else pair[1]
                for knob, t in (("source_type", src), ("target_type", dst)):
                    if t >= self.network.num_types:
                        raise SpecError(
                            f"serve.{knob}={t} out of range: the network has "
                            f"{self.network.num_types} node types"
                        )
                if src == dst:
                    raise SpecError(
                        f"serve.source_type == serve.target_type == {src}; "
                        "the zipf workload ranks a cross-type interaction"
                    )
                report = play_zipf(
                    engine,
                    source_type=src,
                    target_type=dst,
                    requests=sv.requests,
                    zipf=sv.zipf,
                    deltas=sv.deltas,
                    top_k=sv.top_k,
                    seed=self.spec.network.seed,
                    telemetry=self.telemetry,
                )
                mode = "zipf"
        finally:
            # final cache snapshot + writer-thread shutdown (no-op with
            # ft disabled); stats stay readable for the artifact below
            engine.close_ft()
        seconds = time.perf_counter() - t0
        sample = report.pop("sample", {})
        report.pop("latencies", None)  # raw samples stay in memory only
        return ServeArtifact(
            run_id=self.run_id,
            seconds=seconds,
            mode=mode,
            engine=self.backend,
            report=report,
            sample=sample,
            slo=self._watchdog.report() if self._watchdog is not None else {},
            ft=engine.ft_stats(),
        )

    # --------------------------------------------------------------- bench
    def bench(self, *, write: bool = True) -> BenchArtifact:
        from repro.bench.driver import run_bench

        bench = self.spec.bench
        if bench is None:
            from repro.api.spec import BenchSpec

            bench = BenchSpec()
        t0 = time.perf_counter()
        outcome = run_bench(
            fast=bench.fast,
            only=list(bench.suites) if bench.suites else None,
            label=bench.resolved_label(),
            write=write,
        )
        return BenchArtifact(
            run_id=self.run_id,
            seconds=time.perf_counter() - t0,
            label=bench.resolved_label(),
            suites=outcome.suites,
            records=outcome.records,
            failures=outcome.failures,
            report_paths=outcome.paths,
        )

    # --------------------------------------------------------------- train
    def train(self, *, echo=print) -> TrainArtifact:
        """Run the guarded training loop for the spec's ``train`` section.

        Training never touches the LP network/engine machinery — the
        section runs standalone (a networkless spec is valid), and
        lp-family archs are rejected in :func:`run_training` because
        they converge via the solve stage, not SGD.  ``echo`` receives
        the per-step progress lines (the launch shim points it at
        ``print``).
        """
        if self.spec.train is None:
            raise SpecError("run section 'train' needs a train section in the spec")
        from repro.launch.train import run_training

        t0 = time.perf_counter()
        stats = run_training(self.spec.train, echo=echo)
        return TrainArtifact(
            run_id=self.run_id,
            seconds=time.perf_counter() - t0,
            arch=str(stats["arch"]),
            family=str(stats["family"]),
            steps=int(stats["steps"]),
            first_loss=float(stats["first_loss"]),
            last_loss=float(stats["last_loss"]),
            retries=int(stats["retries"]),
            restores=int(stats["restores"]),
            slow_steps=int(stats["slow_steps"]),
            resumed=bool(stats["resumed"]),
        )

    # -------------------------------------------------------------- dryrun
    def dryrun(self) -> DryrunArtifact:
        """Compile-sweep the configured (arch × shape × mesh) cells.

        The census lands in the telemetry artifact format (see
        :class:`DryrunArtifact`); ``benchmarks/roofline.py`` reads it.
        """
        from repro.configs import all_cells, get_arch

        dr = self.spec.dryrun if self.spec.dryrun is not None else DryrunSpec()
        if dr.archs:
            cells = []
            for arch in dr.archs:
                shapes = dr.shapes or tuple(get_arch(arch).shapes)
                cells.extend((arch, s) for s in shapes)
        else:
            cells = all_cells(include_extra=dr.include_extra)
        meshes = ["single", "multi"] if dr.mesh == "both" else [dr.mesh]

        # imported lazily: the module pins XLA_FLAGS for the 512-device
        # host mesh, which only this stage wants
        from repro.launch.dryrun import run_cell

        tel = self.telemetry
        t0 = time.perf_counter()
        recs: List[Dict[str, Any]] = []
        offsets: List[float] = []
        for arch, shape in cells:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind)
                recs.append(rec)
                offsets.append(time.perf_counter() - t0)
                tel.event(
                    "dryrun.cell",
                    arch=arch,
                    shape=shape,
                    mesh=mesh_kind,
                    status=rec.get("status"),
                    compile_s=rec.get("compile_s"),
                )
        return DryrunArtifact(
            run_id=self.run_id,
            seconds=time.perf_counter() - t0,
            mesh=dr.mesh,
            cells=recs,
            offsets=offsets,
        )

    # ----------------------------------------------------------------- run
    def run(
        self,
        sections: Optional[List[str]] = None,
        *,
        write: bool = True,
        echo=print,
    ) -> List[Artifact]:
        """Execute the spec's configured stages in order.

        Writes ``spec.json`` + one artifact file per stage under
        ``results/<run_id>/`` unless ``write=False``.
        """
        stages = {
            "solve": self.solve,
            "eval": self.evaluate,
            "serve": self.serve,
            # bench honors the run-level write flag: --no-write must not
            # leave BENCH_<label>.json behind either
            "bench": lambda: self.bench(write=write),
            "train": lambda: self.train(echo=echo),
            "dryrun": self.dryrun,
        }
        names = list(sections) if sections else list(self.spec.sections())
        unknown = [n for n in names if n not in stages]
        if unknown:
            raise SpecError(f"unknown run section(s) {unknown}")
        if write:
            os.makedirs(self.run_dir, exist_ok=True)
            _write_json(os.path.join(self.run_dir, "spec.json"), self.spec.to_dict())

        tel = self.telemetry
        tel_dir = os.path.join(self.run_dir, "telemetry")
        obs = self.spec.obs
        if (
            write
            and tel.enabled
            and obs is not None
            and obs.flush_interval_s is not None
        ):
            # live mode: telemetry/<run_id> becomes readable mid-run and
            # the SLO watchdog (if any) gets its per-window flush ticks
            tel.attach_stream(tel_dir, interval_s=obs.flush_interval_s)
        if tel.profile_enabled:
            from repro.obs.profiler import install_kernel_hook

            install_kernel_hook(tel)
        artifacts: List[Artifact] = []
        try:
            with tel.span("run", self.run_id, sections=list(names)):
                for name in names:
                    with tel.span("phase", name):
                        if name in ("solve", "serve") and tel.profile_enabled:
                            from repro.obs.profiler import profile_phase

                            with profile_phase(tel, tel_dir, name):
                                art = stages[name]()
                        else:
                            art = stages[name]()
                    artifacts.append(art)
                    if write:
                        for path in art.write(self.run_dir):
                            echo(f"[{name}] wrote {path}")
        finally:
            if tel.profile_enabled:
                from repro.obs.profiler import uninstall_kernel_hook

                uninstall_kernel_hook()
        if write and tel.enabled:
            for path in tel.flush(tel_dir):
                echo(f"[obs] wrote {path}")
        return artifacts
