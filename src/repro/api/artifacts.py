"""Typed run artifacts written under ``results/<run_id>/`` (DESIGN.md §13).

Each :class:`~repro.api.session.Session` stage returns one artifact:
``solve()`` → :class:`SolveArtifact` (rankings + solver outputs),
``evaluate()`` → :class:`EvalArtifact` (protocol metrics), ``serve()`` →
:class:`ServeArtifact` (workload report), ``bench()`` →
:class:`BenchArtifact` (BENCH record summary), ``train()`` →
:class:`TrainArtifact` (guarded training-loop stats), ``dryrun()`` →
:class:`DryrunArtifact` (per-cell compile census, emitted in the
telemetry event format so ``benchmarks/roofline.py`` and ``repro obs``
read the same artifact).  Artifacts carry their heavy payloads (score
matrices, LPOutputs) in memory and write a JSON summary plus ``.npz``
arrays via :meth:`write`.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, ClassVar, Dict, List, Optional, Tuple

import numpy as np


def jsonable(obj: Any) -> Any:
    """Recursively coerce numpy scalars/arrays into JSON-native values."""
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return jsonable(obj.tolist())
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    return obj


def _write_json(path: str, payload: Dict[str, Any]) -> str:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(jsonable(payload), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


@dataclasses.dataclass
class Artifact:
    """Base: provenance (run id + sections) and wall time."""

    kind: ClassVar[str] = "?"
    run_id: str
    seconds: float

    def summary(self) -> Dict[str, Any]:
        """The JSON-able report body (subclasses extend)."""
        return {
            "kind": self.kind,
            "run_id": self.run_id,
            "seconds": round(self.seconds, 4),
        }

    def write(self, run_dir: str) -> List[str]:
        """Write the artifact under ``run_dir``; returns written paths."""
        return [_write_json(os.path.join(run_dir, f"{self.kind}.json"), self.summary())]


@dataclasses.dataclass
class SolveArtifact(Artifact):
    """A converged propagation plus the paper's step-G ranking."""

    kind: ClassVar[str] = "solve"
    backend: str = "?"
    alg: str = "dhlp2"
    converged: bool = False
    outer_iters: int = 0
    inner_iters: int = 0
    supersteps: int = 0
    network: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: the reported ranking: pair, entity, top-k candidate ids + scores
    ranking: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: durability roll-up (empty when the spec declared no ft block):
    #: checkpoints written, resume cursor, checkpoint root
    ft: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: in-memory payloads (not serialized into the JSON summary)
    F: Optional[np.ndarray] = None
    outputs: Optional[object] = None  # repro.core.ranking.LPOutputs

    def summary(self) -> Dict[str, Any]:
        out = super().summary()
        out.update(
            {
                "backend": self.backend,
                "alg": self.alg,
                "converged": self.converged,
                "outer_iters": self.outer_iters,
                "inner_iters": self.inner_iters,
                "supersteps": self.supersteps,
                "network": self.network,
                "ranking": self.ranking,
            }
        )
        if self.ft:
            out["ft"] = self.ft
        return out

    def write(self, run_dir: str) -> List[str]:
        paths = super().write(run_dir)
        if self.outputs is not None:
            arrays: Dict[str, np.ndarray] = {}
            for (i, j), m in self.outputs.interactions.items():
                arrays[f"R_{i}_{j}"] = np.asarray(m)
            for t, s in enumerate(self.outputs.similarities):
                arrays[f"P_{t}"] = np.asarray(s)
            npz = os.path.join(run_dir, "solve_outputs.npz")
            np.savez_compressed(npz, **arrays)
            paths.append(npz)
        return paths


@dataclasses.dataclass
class EvalArtifact(Artifact):
    """Recovery / k-fold CV metrics against planted ground truth."""

    kind: ClassVar[str] = "eval"
    protocol: str = "recovery"
    backend: str = "?"
    pair: Tuple[int, int] = (0, 0)
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: recovery protocol's converged labels (in memory only — the
    #: scenario CLI's cross-backend agreement check reads it)
    F: Optional[np.ndarray] = None

    def summary(self) -> Dict[str, Any]:
        out = super().summary()
        out.update(
            {
                "protocol": self.protocol,
                "backend": self.backend,
                "pair": list(self.pair),
                "params": self.params,
                "metrics": self.metrics,
            }
        )
        return out


@dataclasses.dataclass
class ServeArtifact(Artifact):
    """An online-workload report (trace replay or synthetic zipf)."""

    kind: ClassVar[str] = "serve"
    mode: str = "zipf"  # "zipf" | "trace"
    engine: str = "?"
    report: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: one representative query result for provenance checks
    sample: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: the SLO watchdog roll-up (empty when the spec declared no slo block)
    slo: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: durability roll-up (empty when the spec declared no ft block):
    #: guarded-batch retries/restores, checkpoint cadence, watermark
    ft: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def summary(self) -> Dict[str, Any]:
        out = super().summary()
        out.update(
            {
                "mode": self.mode,
                "engine": self.engine,
                "report": self.report,
                "sample": self.sample,
            }
        )
        if self.slo:
            out["slo"] = self.slo
        if self.ft:
            out["ft"] = self.ft
        return out


@dataclasses.dataclass
class DryrunArtifact(Artifact):
    """A compile-sweep census: one record per (arch × shape × mesh) cell.

    ``write`` emits ``dryrun.json`` (status roll-up) plus
    ``telemetry/dryrun.jsonl`` — the cells as ``repro.obs`` event lines
    (meta line first) so roofline analysis and ``repro obs --validate``
    consume the census through one schema.  The JSONL is written whether
    or not the run's telemetry level is on: the census IS the stage's
    product, not an observation of it.
    """

    kind: ClassVar[str] = "dryrun"
    mesh: str = "single"
    #: raw ``run_cell`` records, in sweep order
    cells: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    #: seconds-from-stage-start offset per cell (parallel to ``cells``)
    offsets: List[float] = dataclasses.field(default_factory=list)

    def summary(self) -> Dict[str, Any]:
        statuses: Dict[str, int] = {}
        for rec in self.cells:
            status = rec.get("status", "?")
            statuses[status] = statuses.get(status, 0) + 1
        out = super().summary()
        out.update(
            {
                "mesh": self.mesh,
                "cells": len(self.cells),
                "statuses": statuses,
                "failures": [
                    {
                        "arch": rec.get("arch"),
                        "shape": rec.get("shape"),
                        "mesh": rec.get("mesh"),
                        "error": rec.get("error"),
                    }
                    for rec in self.cells
                    if rec.get("status") == "error"
                ],
            }
        )
        return out

    def write(self, run_dir: str) -> List[str]:
        from repro.obs.telemetry import SCHEMA

        paths = super().write(run_dir)
        tel_dir = os.path.join(run_dir, "telemetry")
        os.makedirs(tel_dir, exist_ok=True)
        path = os.path.join(tel_dir, "dryrun.jsonl")
        with open(path, "w") as f:
            meta = {"kind": "meta", "schema": SCHEMA, "run_id": self.run_id}
            f.write(json.dumps(jsonable(meta), sort_keys=True) + "\n")
            for i, rec in enumerate(self.cells):
                t = self.offsets[i] if i < len(self.offsets) else float(i)
                line = {
                    "kind": "event",
                    "id": i,
                    "parent": None,
                    "name": "dryrun.cell",
                    "t": t,
                    "attrs": rec,
                }
                f.write(json.dumps(jsonable(line), sort_keys=True) + "\n")
        paths.append(path)
        return paths


@dataclasses.dataclass
class TrainArtifact(Artifact):
    """A guarded training run (lm / gnn / recsys arch families)."""

    kind: ClassVar[str] = "train"
    arch: str = "?"
    family: str = "?"
    steps: int = 0
    first_loss: float = float("nan")
    last_loss: float = float("nan")
    retries: int = 0
    restores: int = 0
    slow_steps: int = 0
    resumed: bool = False

    def summary(self) -> Dict[str, Any]:
        out = super().summary()
        out.update(
            {
                "arch": self.arch,
                "family": self.family,
                "steps": self.steps,
                "first_loss": self.first_loss,
                "last_loss": self.last_loss,
                "retries": self.retries,
                "restores": self.restores,
                "slow_steps": self.slow_steps,
                "resumed": self.resumed,
            }
        )
        return out


@dataclasses.dataclass
class BenchArtifact(Artifact):
    """Summary of a registered-suite benchmark pass (``repro.bench``)."""

    kind: ClassVar[str] = "bench"
    label: str = "ci"
    suites: List[str] = dataclasses.field(default_factory=list)
    records: int = 0
    failures: int = 0
    report_paths: List[str] = dataclasses.field(default_factory=list)

    def summary(self) -> Dict[str, Any]:
        out = super().summary()
        out.update(
            {
                "label": self.label,
                "suites": self.suites,
                "records": self.records,
                "failures": self.failures,
                "report_paths": self.report_paths,
            }
        )
        return out
