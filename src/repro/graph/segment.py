"""Segment-reduce message passing primitives.

JAX has no native EmbeddingBag or CSR/CSC sparse — message passing is
implemented via ``jax.ops.segment_sum``-style reductions over an edge-index
scatter.  These wrappers are the single place the rest of the system (LP
sparse engine, GNN models, recsys embedding bag) gets them from, so the
Pallas kernel in ``repro/kernels/segment_reduce`` can be swapped in behind
the same API.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def segment_sum(
    data: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    indices_are_sorted: bool = False,
) -> jax.Array:
    return jax.ops.segment_sum(
        data,
        segment_ids,
        num_segments=num_segments,
        indices_are_sorted=indices_are_sorted,
    )


def segment_mean(
    data: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    indices_are_sorted: bool = False,
) -> jax.Array:
    total = segment_sum(
        data, segment_ids, num_segments, indices_are_sorted=indices_are_sorted
    )
    count = segment_sum(
        jnp.ones(data.shape[:1], dtype=data.dtype),
        segment_ids,
        num_segments,
        indices_are_sorted=indices_are_sorted,
    )
    return total / jnp.maximum(count, 1.0)[(...,) + (None,) * (data.ndim - 1)]


def segment_max(
    data: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    indices_are_sorted: bool = False,
) -> jax.Array:
    return jax.ops.segment_max(
        data,
        segment_ids,
        num_segments=num_segments,
        indices_are_sorted=indices_are_sorted,
    )


def segment_min(
    data: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
) -> jax.Array:
    return jax.ops.segment_min(data, segment_ids, num_segments=num_segments)


def segment_softmax(
    scores: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
) -> jax.Array:
    """Numerically-stable softmax within each segment (GAT edge softmax)."""
    seg_max = jax.ops.segment_max(
        scores, segment_ids, num_segments=num_segments
    )
    # empty segments produce -inf max; gather is safe because those segments
    # have no edges to read it back.
    shifted = scores - seg_max[segment_ids]
    expd = jnp.exp(shifted)
    denom = segment_sum(expd, segment_ids, num_segments)
    return expd / jnp.maximum(denom[segment_ids], 1e-38)


def scatter_spmm(
    src: jax.Array,        # (E,) int — message source node per edge
    dst: jax.Array,        # (E,) int — message destination node per edge
    w: jax.Array,          # (E,) float — edge weight
    F: jax.Array,          # (N, D) node features / labels
    num_nodes: int,
    *,
    indices_are_sorted: bool = False,
    accum_dtype: Optional[jnp.dtype] = jnp.float32,
) -> jax.Array:
    """(W @ F) for a COO operator W: out[v] = Σ_{e: dst=v} w_e · F[src_e].

    This IS one Giraph superstep: gather = messages leaving src, segment_sum
    = the destination vertex folding its mailbox (combiner semantics).
    """
    msgs = w[:, None].astype(accum_dtype) * F[src].astype(accum_dtype)
    out = segment_sum(
        msgs, dst, num_nodes, indices_are_sorted=indices_are_sorted
    )
    return out.astype(F.dtype)


def degree(
    dst: jax.Array, num_nodes: int, dtype=jnp.float32
) -> jax.Array:
    return segment_sum(jnp.ones_like(dst, dtype=dtype), dst, num_nodes)
