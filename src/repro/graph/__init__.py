"""Graph substrate: structures, segment ops, sampling, partitioning."""
from repro.graph.partition import (
    EdgeShards,
    NodeBands,
    balance_report,
    edge_partition,
    node_partition,
)
from repro.graph.sampler import (
    CSRAdjacency,
    NeighborSampler,
    SampledBlock,
    SampledSubgraph,
    relabel_to_local,
)
from repro.graph.segment import (
    degree,
    scatter_spmm,
    segment_max,
    segment_mean,
    segment_min,
    segment_softmax,
    segment_sum,
)
from repro.graph.structures import (
    EdgeList,
    PaddedCSR,
    erdos_renyi,
    powerlaw_graph,
)

__all__ = [
    "CSRAdjacency",
    "EdgeList",
    "EdgeShards",
    "NeighborSampler",
    "NodeBands",
    "PaddedCSR",
    "SampledBlock",
    "SampledSubgraph",
    "balance_report",
    "degree",
    "edge_partition",
    "erdos_renyi",
    "node_partition",
    "powerlaw_graph",
    "relabel_to_local",
    "scatter_spmm",
    "segment_max",
    "segment_mean",
    "segment_min",
    "segment_softmax",
    "segment_sum",
]
