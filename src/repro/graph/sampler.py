"""Fanout neighbor sampling (GraphSAGE-style) for minibatch GNN training.

``minibatch_lg`` (232,965 nodes / 114.6M edges, batch 1024, fanout 15-10)
needs a *real* sampler: we build a CSR adjacency once, then per batch draw a
uniform sample of up to ``fanout[k]`` in-neighbors per frontier node at hop
k.  The sampled block is emitted as padded rectangles so the downstream
JAX program has static shapes.

The sampler is host-side numpy (it is the data pipeline, like any indices
pipeline feeding a TPU job), deliberately without jax deps so it can run in
input-worker processes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.graph.structures import EdgeList


@dataclasses.dataclass
class CSRAdjacency:
    """Compressed in-neighbor lists: neighbors of v are
    ``cols[indptr[v]:indptr[v+1]]``."""

    indptr: np.ndarray  # (N+1,) int64
    cols: np.ndarray    # (E,) int32
    num_nodes: int

    @classmethod
    def from_edgelist(cls, edges: EdgeList) -> "CSRAdjacency":
        e = edges.sorted_by_dst()
        deg = e.in_degrees().astype(np.int64)
        indptr = np.zeros(edges.num_nodes + 1, dtype=np.int64)
        np.cumsum(deg, out=indptr[1:])
        return cls(indptr=indptr, cols=e.src.copy(), num_nodes=e.num_nodes)

    def degree(self, nodes: np.ndarray) -> np.ndarray:
        return (self.indptr[nodes + 1] - self.indptr[nodes]).astype(np.int64)


@dataclasses.dataclass
class SampledBlock:
    """One hop of a sampled computation graph (padded).

    ``nbr[i, k]`` is the k-th sampled in-neighbor of frontier node i;
    ``mask[i, k]`` marks real (non-pad) entries.  ``nodes`` are the frontier
    ids this hop expands; the next hop's frontier is ``unique_nbrs``.
    """

    nodes: np.ndarray         # (B,) int32 frontier
    nbr: np.ndarray           # (B, fanout) int32 global ids (pad: 0)
    mask: np.ndarray          # (B, fanout) bool
    unique_nbrs: np.ndarray   # (U,) int32 next frontier


@dataclasses.dataclass
class SampledSubgraph:
    """Multi-hop sample: blocks[0] expands the seed batch, blocks[k] the
    k-th frontier.  ``all_nodes`` is the union (seeds first) — the set whose
    features get gathered for the device step."""

    seeds: np.ndarray
    blocks: List[SampledBlock]
    all_nodes: np.ndarray


class NeighborSampler:
    def __init__(self, adj: CSRAdjacency, fanouts: Sequence[int], seed: int = 0):
        self.adj = adj
        self.fanouts = list(fanouts)
        self._rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> SampledSubgraph:
        seeds = np.asarray(seeds, dtype=np.int32)
        frontier = seeds
        blocks: List[SampledBlock] = []
        seen = [seeds]
        for fanout in self.fanouts:
            nbr, mask = self._sample_hop(frontier, fanout)
            uniq = np.unique(nbr[mask])
            blocks.append(
                SampledBlock(
                    nodes=frontier, nbr=nbr, mask=mask,
                    unique_nbrs=uniq.astype(np.int32),
                )
            )
            frontier = uniq.astype(np.int32)
            seen.append(frontier)
        all_nodes = np.unique(np.concatenate(seen)).astype(np.int32)
        return SampledSubgraph(seeds=seeds, blocks=blocks, all_nodes=all_nodes)

    def _sample_hop(
        self, frontier: np.ndarray, fanout: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        b = frontier.shape[0]
        deg = self.adj.degree(frontier)                       # (B,)
        # uniform with replacement when deg > 0; replacement keeps the
        # sampler O(B·fanout) with static shapes (standard GraphSAGE trick)
        draw = self._rng.integers(0, 1 << 62, size=(b, fanout))
        safe_deg = np.maximum(deg, 1)[:, None]
        offsets = (draw % safe_deg).astype(np.int64)
        starts = self.adj.indptr[frontier][:, None]
        nbr = self.adj.cols[starts + offsets].astype(np.int32)
        mask = np.broadcast_to((deg > 0)[:, None], (b, fanout)).copy()
        nbr = np.where(mask, nbr, 0)
        return nbr, mask


def relabel_to_local(
    subg: SampledSubgraph,
) -> Tuple[np.ndarray, List[Tuple[np.ndarray, np.ndarray, np.ndarray]]]:
    """Map global node ids to positions in ``subg.all_nodes``.

    Returns ``(all_nodes, hops)`` where each hop is
    ``(local_frontier, local_nbr, mask)`` ready for gather/segment ops over
    the gathered feature block.
    """
    lookup = np.full(int(subg.all_nodes.max(initial=0)) + 1, -1, np.int64)
    lookup[subg.all_nodes] = np.arange(subg.all_nodes.shape[0])
    hops = []
    for blk in subg.blocks:
        hops.append(
            (
                lookup[blk.nodes].astype(np.int32),
                lookup[np.where(blk.mask, blk.nbr, subg.all_nodes[0])].astype(
                    np.int32
                ),
                blk.mask,
            )
        )
    return subg.all_nodes, hops
