"""Graph partitioning for the distributed engines.

Giraph assigns vertex partitions to workers; our equivalents:

* ``edge_partition`` — split the COO edge list into ``k`` equal shards
  (destination-contiguous so each shard's segment-sum output is a narrow
  row band).  Used by the shard_map LP engine: every shard computes a
  partial (N, s) aggregate, combined with ``psum``/``reduce_scatter``.
* ``node_partition`` — contiguous row bands of nodes per shard (1D row
  decomposition); remote rows needed by local edges form the halo.

Both return padded, equal-size shards — XLA needs static per-shard shapes,
the exact analogue of Giraph's hash-partitioner producing balanced splits.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.graph.structures import EdgeList


@dataclasses.dataclass
class EdgeShards:
    """(k, E/k) stacked shards; pads are zero-weight self-loops on node 0."""

    src: np.ndarray   # (k, Ep) int32
    dst: np.ndarray   # (k, Ep) int32
    w: np.ndarray     # (k, Ep) float32
    num_nodes: int

    @property
    def num_shards(self) -> int:
        return int(self.src.shape[0])

    @property
    def edges_per_shard(self) -> int:
        return int(self.src.shape[1])


def edge_partition(edges: EdgeList, k: int) -> EdgeShards:
    e = edges.sorted_by_dst()
    per = (e.num_edges + k - 1) // k
    per = max(per, 1)
    total = per * k
    pad = total - e.num_edges
    src = np.concatenate([e.src, np.zeros(pad, np.int32)])
    dst = np.concatenate([e.dst, np.zeros(pad, np.int32)])
    w = np.concatenate([e.weights(), np.zeros(pad, np.float32)])
    return EdgeShards(
        src=src.reshape(k, per),
        dst=dst.reshape(k, per),
        w=w.reshape(k, per),
        num_nodes=e.num_nodes,
    )


@dataclasses.dataclass
class NodeBands:
    """Contiguous row bands: shard i owns rows [bounds[i], bounds[i+1])."""

    bounds: np.ndarray  # (k+1,) int64
    num_nodes: int

    def owner_of(self, nodes: np.ndarray) -> np.ndarray:
        return (
            np.searchsorted(self.bounds, nodes, side="right") - 1
        ).astype(np.int32)


def node_partition(num_nodes: int, k: int) -> NodeBands:
    per = (num_nodes + k - 1) // k
    bounds = np.minimum(np.arange(k + 1, dtype=np.int64) * per, num_nodes)
    return NodeBands(bounds=bounds, num_nodes=num_nodes)


def balance_report(edges: EdgeList, k: int) -> Tuple[float, List[int]]:
    """Edge balance of a node partition (straggler predictor): returns the
    max/mean load ratio and per-shard edge counts."""
    bands = node_partition(edges.num_nodes, k)
    owner = bands.owner_of(edges.dst)
    counts = np.bincount(owner, minlength=k).tolist()
    mean = max(1.0, edges.num_edges / k)
    return max(counts) / mean, counts
