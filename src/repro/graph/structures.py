"""Graph containers: COO edge lists and padded CSR.

Design notes
------------
* ``EdgeList`` (COO) is the canonical exchange format — the Giraph "vertex
  input format" analogue.  Stored destination-major so segment reductions
  see sorted ids.
* ``PaddedCSR`` re-packs neighbors into an ``(N, max_deg)`` rectangle for
  kernels that want regular tiles (Pallas); the pad entries point at node 0
  with weight 0 so every op treats them as no-ops.
* All index arrays are int32: 2B+ nodes are out of scope per shard — a shard
  of a 1000-node cluster holds ≪ 2³¹ local nodes after partitioning.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class EdgeList:
    """COO graph: edge e carries ``src[e] -> dst[e]`` with weight ``w[e]``."""

    src: np.ndarray            # (E,) int32
    dst: np.ndarray            # (E,) int32
    w: Optional[np.ndarray]    # (E,) float32 or None (unweighted)
    num_nodes: int

    def __post_init__(self) -> None:
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        if self.w is not None:
            self.w = np.asarray(self.w, dtype=np.float32)
            if self.w.shape != self.src.shape:
                raise ValueError("w shape mismatch")
        if self.src.shape != self.dst.shape:
            raise ValueError("src/dst shape mismatch")

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    def weights(self) -> np.ndarray:
        if self.w is None:
            return np.ones_like(self.src, dtype=np.float32)
        return self.w

    # ----------------------------------------------------------- transforms
    def sorted_by_dst(self) -> "EdgeList":
        order = np.argsort(self.dst, kind="stable")
        return EdgeList(
            src=self.src[order],
            dst=self.dst[order],
            w=None if self.w is None else self.w[order],
            num_nodes=self.num_nodes,
        )

    def symmetrized(self) -> "EdgeList":
        """Add reverse edges (deduplicated)."""
        pairs = np.stack(
            [
                np.concatenate([self.src, self.dst]),
                np.concatenate([self.dst, self.src]),
            ],
            axis=1,
        )
        w = np.concatenate([self.weights(), self.weights()])
        key = pairs[:, 0].astype(np.int64) * self.num_nodes + pairs[:, 1]
        _, idx = np.unique(key, return_index=True)
        return EdgeList(
            src=pairs[idx, 0], dst=pairs[idx, 1], w=w[idx],
            num_nodes=self.num_nodes,
        )

    def with_self_loops(self) -> "EdgeList":
        loops = np.arange(self.num_nodes, dtype=np.int32)
        return EdgeList(
            src=np.concatenate([self.src, loops]),
            dst=np.concatenate([self.dst, loops]),
            w=np.concatenate(
                [self.weights(), np.ones(self.num_nodes, np.float32)]
            ),
            num_nodes=self.num_nodes,
        )

    def pad_to_multiple(self, mult: int) -> "EdgeList":
        """Pad with zero-weight self-loops on node 0 (shard-friendly shapes)."""
        e = self.num_edges
        target = ((e + mult - 1) // mult) * mult if e else mult
        pad = target - e
        if pad == 0 and self.w is not None:
            return self
        return EdgeList(
            src=np.concatenate([self.src, np.zeros(pad, np.int32)]),
            dst=np.concatenate([self.dst, np.zeros(pad, np.int32)]),
            w=np.concatenate([self.weights(), np.zeros(pad, np.float32)]),
            num_nodes=self.num_nodes,
        )

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.num_nodes)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.num_nodes)

    # ------------------------------------------------------------- builders
    @classmethod
    def from_dense(cls, A: np.ndarray) -> "EdgeList":
        dst, src = np.nonzero(A)
        return cls(
            src=src.astype(np.int32),
            dst=dst.astype(np.int32),
            w=A[dst, src].astype(np.float32),
            num_nodes=A.shape[0],
        ).sorted_by_dst()

    def to_dense(self) -> np.ndarray:
        A = np.zeros((self.num_nodes, self.num_nodes), dtype=np.float32)
        np.add.at(A, (self.dst, self.src), self.weights())
        return A

    def to_padded_csr(self, max_deg: Optional[int] = None) -> "PaddedCSR":
        return PaddedCSR.from_edgelist(self, max_deg=max_deg)


@dataclasses.dataclass
class PaddedCSR:
    """Destination-indexed padded neighbor table.

    ``nbr[v, k]`` is the k-th in-neighbor of v (source node of an incoming
    edge), ``wgt[v, k]`` its weight; pads are (0, 0.0).  The rectangle is the
    Pallas-friendly layout: one VMEM tile per (node-block, neighbor-block).
    """

    nbr: np.ndarray   # (N, max_deg) int32
    wgt: np.ndarray   # (N, max_deg) float32
    deg: np.ndarray   # (N,) int32 true in-degree (may exceed max_deg if truncated)
    num_nodes: int

    @property
    def max_deg(self) -> int:
        return int(self.nbr.shape[1])

    @classmethod
    def from_edgelist(
        cls, edges: EdgeList, max_deg: Optional[int] = None
    ) -> "PaddedCSR":
        n = edges.num_nodes
        e = edges.sorted_by_dst()
        deg = e.in_degrees().astype(np.int64)
        cap = int(deg.max(initial=1)) if max_deg is None else int(max_deg)
        cap = max(cap, 1)
        nbr = np.zeros((n, cap), dtype=np.int32)
        wgt = np.zeros((n, cap), dtype=np.float32)
        # slot of each edge within its destination's neighbor row
        starts = np.zeros(n, dtype=np.int64)
        np.cumsum(deg[:-1], out=starts[1:])
        slot = np.arange(e.num_edges, dtype=np.int64) - starts[e.dst]
        keep = slot < cap
        nbr[e.dst[keep], slot[keep]] = e.src[keep]
        wgt[e.dst[keep], slot[keep]] = e.weights()[keep]
        return cls(nbr=nbr, wgt=wgt, deg=deg.astype(np.int32), num_nodes=n)


def erdos_renyi(
    num_nodes: int,
    num_edges: int,
    seed: int = 0,
    weighted: bool = True,
) -> EdgeList:
    """Random graph with exactly ``num_edges`` directed edges (with repeats
    collapsed by weight accumulation in to_dense; kept raw here)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_nodes, size=num_edges, dtype=np.int32)
    dst = rng.integers(0, num_nodes, size=num_edges, dtype=np.int32)
    w = rng.random(num_edges).astype(np.float32) if weighted else None
    return EdgeList(src=src, dst=dst, w=w, num_nodes=num_nodes).sorted_by_dst()


def powerlaw_graph(
    num_nodes: int,
    num_edges: int,
    exponent: float = 2.1,
    seed: int = 0,
) -> EdgeList:
    """Degree-skewed graph (realistic for biological/social networks)."""
    rng = np.random.default_rng(seed)
    # sample endpoints from a Zipf-ish distribution over node ids
    ranks = np.arange(1, num_nodes + 1, dtype=np.float64)
    probs = ranks ** (-exponent / 2.0)
    probs /= probs.sum()
    src = rng.choice(num_nodes, size=num_edges, p=probs).astype(np.int32)
    dst = rng.choice(num_nodes, size=num_edges, p=probs).astype(np.int32)
    return EdgeList(src=src, dst=dst, w=None, num_nodes=num_nodes).sorted_by_dst()
