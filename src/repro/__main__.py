"""``python -m repro`` — the unified driver (DESIGN.md §13).

    python -m repro run examples/specs/quickstart_run.json
    python -m repro run --network scenario:powerlaw --scale 0.02 --eval recovery
    python -m repro run --bench              # registered-suite fast pass
    python -m repro solve|serve|scenario|bench ...   # deprecation shims

The sharded backend and the bench matrix's sharded cells need multiple
devices; on CPU hosts they are fabricated via XLA_FLAGS, which must be
set before ANY jax import (the device count locks at jax init).  argv is
peeked here because argparse runs after import, inside main().
"""

import os
import sys

_DEVICES = 8 if "--full" in sys.argv else 4
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={_DEVICES}"
)

from repro.launch.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
