"""Public wrapper: flash attention with GQA head-group handling."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def gqa_attention_op(
    q: jax.Array,    # (B, Hq, Lq, D)
    k: jax.Array,    # (B, Hkv, Lk, D)
    v: jax.Array,    # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    use_kernel: bool | None = None,
    bq: int = 256,
    bk: int = 512,
) -> jax.Array:
    """Grouped-query attention: repeats KV heads to match Q heads, then
    dispatches to the Pallas kernel (serving) or the jnp reference
    (training / tiny shapes)."""
    hq, hkv = q.shape[1], k.shape[1]
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    if use_kernel is None:
        use_kernel = q.shape[2] * k.shape[2] >= 128 * 128
    if not use_kernel:
        return attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    return flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, interpret=default_interpret(),
    )
