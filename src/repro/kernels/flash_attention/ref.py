"""Pure-jnp oracle: masked softmax attention (causal / sliding window)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,        # (B, H, Lq, D)
    k: jnp.ndarray,        # (B, H, Lk, D)
    v: jnp.ndarray,        # (B, H, Lk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,   # sliding window size (None = full)
    q_offset: int = 0,              # absolute position of q[0] (decode)
) -> jnp.ndarray:
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    lq, lk = q.shape[2], k.shape[2]
    q_pos = jnp.arange(lq) + q_offset
    k_pos = jnp.arange(lk)
    mask = jnp.ones((lq, lk), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-38)
    out = jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(jnp.float32), v.astype(jnp.float32)
    )
    return out.astype(q.dtype)
