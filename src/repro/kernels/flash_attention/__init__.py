from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import gqa_attention_op
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["attention_ref", "flash_attention", "gqa_attention_op"]
