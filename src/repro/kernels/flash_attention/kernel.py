"""Pallas TPU kernel: blocked online-softmax attention (forward).

FlashAttention adapted to TPU tiling: grid = (B·H, Lq/bq, Lk/bk) with the
key axis innermost ("arbitrary" semantics); the (m, l, acc) online-softmax
state lives in VMEM scratch across key steps, so each output tile makes one
HBM round-trip regardless of sequence length.  Causal and sliding-window
masks are applied from absolute positions; ``q_offset`` supports decode
(query positions start at the cache length).

Serving-path kernel (prefill/decode are jit'd forward passes); the training
path uses the jnp reference (XLA's fused attention is adequate there and
keeps the backward pass free).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, default_interpret, tpu_compiler_params

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, out_ref, m_scr, l_scr, acc_scr,
    *, scale, bq, bk, k_steps, causal, window, q_offset, lk_valid,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                       # (bq, d)
    k = k_ref[0]                       # (bk, d)
    v = v_ref[0]                       # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                          # (bq, bk)

    q_pos = q_offset + pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0
    )
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < lk_valid          # padded keys are never attended
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # guard rows that have seen nothing yet (all -inf): exp(-inf - -inf)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(j == k_steps - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-38)
        out_ref[0] = (acc_scr[...] / denom[:, None]).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "bq", "bk", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,   # (B, H, Lq, D)
    k: jax.Array,   # (B, H, Lk, D)
    v: jax.Array,   # (B, H, Lk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    bq: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    b, h, lq, d = q.shape
    _, _, lk, _ = k.shape
    bq = min(bq, lq)
    bk = min(bk, lk)
    lq_pad = cdiv(lq, bq) * bq
    lk_pad = cdiv(lk, bk) * bk
    if lq_pad != lq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, lq_pad - lq), (0, 0)))
    if lk_pad != lk:
        # padded keys are masked inside the kernel via lk_valid
        k = jnp.pad(k, ((0, 0), (0, 0), (0, lk_pad - lk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, lk_pad - lk), (0, 0)))
    qf = q.reshape(b * h, lq_pad, d)
    kf = k.reshape(b * h, lk_pad, d)
    vf = v.reshape(b * h, lk_pad, d)
    grid = (b * h, lq_pad // bq, lk_pad // bk)
    if interpret is None:
        interpret = default_interpret()
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, bq=bq, bk=bk, k_steps=grid[2],
        causal=causal, window=window, q_offset=q_offset, lk_valid=lk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, bk, d), lambda n, i, j: (n, j, 0)),
            pl.BlockSpec((1, bk, d), lambda n, i, j: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda n, i, j: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, lq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, h, lq_pad, d)
    if lq_pad != lq:
        out = out[:, :, :lq]
    return out
