"""Shared kernel plumbing.

TPU is the compile target; this container is CPU-only, so every kernel runs
under ``interpret=True`` here (the Pallas interpreter executes the kernel
body in Python with the same blocking semantics).  On a real TPU backend
``interpret`` resolves to False and the same code lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def tpu_compiler_params(**kwargs):
    """Build Mosaic compiler params across the Pallas rename.

    Newer Pallas exposes ``pltpu.CompilerParams``; older releases call the
    same dataclass ``pltpu.TPUCompilerParams``.  Resolve whichever exists.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
    return cls(**kwargs)


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pick_block(dim: int, preferred: int, align: int = 8) -> int:
    """Largest hardware-friendly block ≤ preferred that keeps the grid
    covering ``dim`` without a ragged tail when possible."""
    if dim <= preferred:
        return round_up(dim, align) if dim % align else dim
    b = preferred
    while b > align and dim % b:
        b -= align
    return b if dim % b == 0 else preferred
