from repro.kernels.lp_blockspmm.kernel import lp_round
from repro.kernels.lp_blockspmm.ops import lp_round_op
from repro.kernels.lp_blockspmm.ref import lp_round_ref

__all__ = ["lp_round", "lp_round_op", "lp_round_ref"]
