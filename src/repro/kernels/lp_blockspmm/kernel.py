"""Pallas TPU kernel: one fused DHLP-2 round, ``out = c·base + A @ F``.

The LP hot loop is a repeated (N,N)×(N,S) matmul with an axpy epilogue.
Unfused, XLA emits matmul → HBM round-trip → elementwise; fusing the
epilogue into the matmul's final k-step keeps the (bm, bs) tile in VMEM
until it is complete — one HBM write per output tile per round instead of
write+read+write.

Blocking: grid = (N/bm, S/bs, N/bk), k innermost (``arbitrary`` semantics so
the fp32 VMEM accumulator survives across k-steps).  MXU alignment: all
block dims multiples of 128 where the problem allows; accumulation always
fp32 regardless of the storage dtype (bf16 storage mode of the LP engine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, default_interpret, tpu_compiler_params


def _lp_round_kernel(base_ref, a_ref, f_ref, out_ref, acc_ref, *, c, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c * base_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(
        a_ref[...],
        f_ref[...],
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("c", "bm", "bs", "bk", "interpret"),
)
def lp_round(
    A: jax.Array,        # (N, N)
    F: jax.Array,        # (N, S)
    base: jax.Array,     # (N, S)
    *,
    c: float,
    bm: int = 256,
    bs: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    n, s = F.shape
    if A.shape != (n, n) or base.shape != (n, s):
        raise ValueError(f"shape mismatch A={A.shape} F={F.shape} base={base.shape}")
    bm = min(bm, n)
    bs = min(bs, s)
    bk = min(bk, n)
    # Ragged trailing blocks read out-of-bounds garbage on TPU (and NaN in
    # the interpreter); zero-pad to block multiples — exact for this op —
    # and slice the result back.
    n_m = cdiv(n, bm) * bm
    n_k = cdiv(n, bk) * bk
    n_pad = max(n_m, n_k)
    s_pad = cdiv(s, bs) * bs
    if n_pad != n or s_pad != s:
        A = jnp.pad(A, ((0, n_pad - n), (0, n_pad - n)))
        F = jnp.pad(F, ((0, n_pad - n), (0, s_pad - s)))
        base = jnp.pad(base, ((0, n_pad - n), (0, s_pad - s)))
    grid = (cdiv(n_pad, bm), cdiv(s_pad, bs), cdiv(n_pad, bk))
    if interpret is None:
        interpret = default_interpret()
    kernel = functools.partial(_lp_round_kernel, c=c, k_steps=grid[2])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),   # base tile
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # A tile
            pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),   # F tile
        ],
        out_specs=pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, s_pad), F.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bs), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(base, A, F)
    if n_pad != n or s_pad != s:
        out = out[:n, :s]
    return out
