"""Public jit'd wrapper for the fused LP round kernel.

Chooses the kernel on TPU and falls back to the jnp reference when shapes
are too small to justify tiling overhead (or on platforms without Mosaic).
"""
from __future__ import annotations

import jax

from repro.kernels.common import default_interpret
from repro.kernels.lp_blockspmm.kernel import lp_round
from repro.kernels.lp_blockspmm.ref import lp_round_ref
from repro.obs.profiler import kernel_clock, kernel_time

_MIN_DIM_FOR_KERNEL = 128


def lp_round_op(
    A: jax.Array,
    F: jax.Array,
    base: jax.Array,
    *,
    c: float,
    bm: int = 256,
    bs: int = 256,
    bk: int = 512,
    use_kernel: bool | None = None,
) -> jax.Array:
    n, s = F.shape
    if use_kernel is None:
        use_kernel = n >= _MIN_DIM_FOR_KERNEL and s >= _MIN_DIM_FOR_KERNEL
    t0 = kernel_clock()
    if not use_kernel:
        return kernel_time("lp_round.ref", t0, lp_round_ref(A, F, base, c))
    out = lp_round(
        A, F, base, c=c, bm=bm, bs=bs, bk=bk, interpret=default_interpret()
    )
    return kernel_time("lp_round.kernel", t0, out)
