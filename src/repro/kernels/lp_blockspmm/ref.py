"""Pure-jnp oracle for the fused LP round: ``out = c·base + A @ F``."""
from __future__ import annotations

import jax.numpy as jnp


def lp_round_ref(
    A: jnp.ndarray,      # (N, N) fused operator (αβ·scale·H + α·M)
    F: jnp.ndarray,      # (N, S) current labels
    base: jnp.ndarray,   # (N, S) Y (fixed) or F (drift)
    c: float,            # β²
) -> jnp.ndarray:
    return c * base + jnp.matmul(
        A, F, preferred_element_type=jnp.float32
    ).astype(F.dtype)
