"""Pure-jnp oracle for padded-CSR neighbor aggregation.

``out[v] = Σ_k wgt[v, k] · F[nbr[v, k]]`` — zero-weight pads are no-ops.
This is the message-passing primitive (GNN aggregate / sparse LP superstep)
in the regular layout.
"""
from __future__ import annotations

import jax.numpy as jnp


def csr_aggregate_ref(
    nbr: jnp.ndarray,   # (M, D) int32 neighbor ids
    wgt: jnp.ndarray,   # (M, D) float weights (0 = pad)
    F: jnp.ndarray,     # (N, S) features/labels
) -> jnp.ndarray:
    gathered = F[nbr]                       # (M, D, S)
    acc = jnp.einsum(
        "nd,nds->ns",
        wgt.astype(jnp.float32),
        gathered.astype(jnp.float32),
    )
    return acc.astype(F.dtype)


def csr_round_ref(
    nbr: jnp.ndarray,   # (M, D) int32 neighbor ids
    wgt: jnp.ndarray,   # (M, D) float weights (0 = pad)
    F: jnp.ndarray,     # (N, S) features/labels
    base: jnp.ndarray,  # (M, S) seed/base panel for the fused epilogue
    c: float,
) -> jnp.ndarray:
    """Fused LP round oracle: ``c·base + Σ_k wgt[·,k] · F[nbr[·,k]]``."""
    acc = csr_aggregate_ref(nbr, wgt, F).astype(jnp.float32)
    return (c * base.astype(jnp.float32) + acc).astype(F.dtype)


def csr_round_residual_ref(
    nbr: jnp.ndarray,   # (M, D) int32 neighbor ids
    wgt: jnp.ndarray,   # (M, D) float weights (0 = pad)
    F: jnp.ndarray,     # (N, S) features/labels (gather panel)
    base: jnp.ndarray,  # (M, S) seed/base panel
    prev: jnp.ndarray,  # (M, S) pre-round state for this bucket's rows
    c: float,
) -> tuple:
    """Fused superstep oracle: the round plus its convergence residual.

    Returns ``(out, delta)`` with ``out`` in ``base.dtype`` and ``delta``
    shaped ``(1, S)`` — the max over this bucket's rows of ``|out − prev|``
    computed in fp32, matching the kernel's per-row-block partial layout.
    """
    acc = csr_aggregate_ref(nbr, wgt, F).astype(jnp.float32)
    out = c * base.astype(jnp.float32) + acc
    delta = jnp.max(
        jnp.abs(out - prev.astype(jnp.float32)), axis=0, keepdims=True
    )
    return out.astype(base.dtype), delta
