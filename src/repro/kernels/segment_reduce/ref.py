"""Pure-jnp oracle for padded-CSR neighbor aggregation.

``out[v] = Σ_k wgt[v, k] · F[nbr[v, k]]`` — zero-weight pads are no-ops.
This is the message-passing primitive (GNN aggregate / sparse LP superstep)
in the regular layout.
"""
from __future__ import annotations

import jax.numpy as jnp


def csr_aggregate_ref(
    nbr: jnp.ndarray,   # (N, D) int32 neighbor ids
    wgt: jnp.ndarray,   # (N, D) float weights (0 = pad)
    F: jnp.ndarray,     # (N, S) features/labels
) -> jnp.ndarray:
    gathered = F[nbr]                       # (N, D, S)
    acc = jnp.einsum(
        "nd,nds->ns",
        wgt.astype(jnp.float32),
        gathered.astype(jnp.float32),
    )
    return acc.astype(F.dtype)
