"""Public wrappers: padded-CSR aggregation with fallback to the oracle."""
from __future__ import annotations

import jax

from repro.kernels.common import default_interpret
from repro.kernels.segment_reduce.kernel import (
    csr_aggregate,
    csr_round,
    csr_round_residual,
)
from repro.kernels.segment_reduce.ref import (
    csr_aggregate_ref,
    csr_round_ref,
    csr_round_residual_ref,
)
from repro.obs.profiler import kernel_clock, kernel_time

# The resident F panel must fit VMEM alongside tiles: N·bs·4B ≲ 8MB.
_MAX_RESIDENT_NODES = 16384


def csr_aggregate_op(
    nbr: jax.Array,
    wgt: jax.Array,
    F: jax.Array,
    *,
    bn: int = 256,
    bs: int = 128,
    bd: int = 16,
    use_kernel: bool | None = None,
) -> jax.Array:
    n = F.shape[0]
    if use_kernel is None:
        use_kernel = 128 <= n <= _MAX_RESIDENT_NODES
    t0 = kernel_clock()
    if not use_kernel:
        return kernel_time("csr_aggregate.ref", t0, csr_aggregate_ref(nbr, wgt, F))
    out = csr_aggregate(
        nbr, wgt, F, bn=bn, bs=bs, bd=bd, interpret=default_interpret()
    )
    return kernel_time("csr_aggregate.kernel", t0, out)


def csr_round_op(
    nbr: jax.Array,
    wgt: jax.Array,
    F: jax.Array,
    base: jax.Array,
    *,
    c: float,
    bn: int = 256,
    bs: int = 128,
    bd: int = 16,
    use_kernel: bool | None = None,
) -> jax.Array:
    """Fused ``c·base + A_bucket @ F`` round for one blocked-CSR bucket.

    Same size heuristic as :func:`csr_aggregate_op`; the engine registry's
    ``kernel`` backend passes ``use_kernel=True`` so an opted-in config
    never silently falls back to the oracle.
    """
    n = F.shape[0]
    if use_kernel is None:
        use_kernel = 128 <= n <= _MAX_RESIDENT_NODES
    t0 = kernel_clock()
    if not use_kernel:
        return kernel_time("csr_round.ref", t0, csr_round_ref(nbr, wgt, F, base, c))
    out = csr_round(
        nbr, wgt, F, base, c=c, bn=bn, bs=bs, bd=bd,
        interpret=default_interpret(),
    )
    return kernel_time("csr_round.kernel", t0, out)


def csr_round_residual_op(
    nbr: jax.Array,
    wgt: jax.Array,
    F: jax.Array,
    base: jax.Array,
    prev: jax.Array,
    *,
    c: float,
    bn: int = 256,
    bs: int = 128,
    bd: int = 16,
    use_kernel: bool | None = None,
) -> tuple:
    """Fused superstep for one bucket: round plus max-|out − prev| partial.

    Returns ``(out, delta)``; ``delta`` has one max-partial row per row
    block (``(grid_m, S)`` from the kernel, ``(1, S)`` from the oracle) —
    callers reduce with ``jnp.max(delta, axis=0)`` after concatenating
    buckets. Same size heuristic as :func:`csr_aggregate_op`.
    """
    n = F.shape[0]
    if use_kernel is None:
        use_kernel = 128 <= n <= _MAX_RESIDENT_NODES
    t0 = kernel_clock()
    if not use_kernel:
        out = csr_round_residual_ref(nbr, wgt, F, base, prev, c)
        return kernel_time("csr_round_residual.ref", t0, out)
    out = csr_round_residual(
        nbr, wgt, F, base, prev, c=c, bn=bn, bs=bs, bd=bd,
        interpret=default_interpret(),
    )
    return kernel_time("csr_round_residual.kernel", t0, out)
