from repro.kernels.segment_reduce.kernel import (
    csr_aggregate,
    csr_round,
    csr_round_residual,
)
from repro.kernels.segment_reduce.ops import (
    csr_aggregate_op,
    csr_round_op,
    csr_round_residual_op,
)
from repro.kernels.segment_reduce.ref import (
    csr_aggregate_ref,
    csr_round_ref,
    csr_round_residual_ref,
)

__all__ = [
    "csr_aggregate",
    "csr_aggregate_op",
    "csr_aggregate_ref",
    "csr_round",
    "csr_round_op",
    "csr_round_ref",
    "csr_round_residual",
    "csr_round_residual_op",
    "csr_round_residual_ref",
]
