from repro.kernels.segment_reduce.kernel import csr_aggregate
from repro.kernels.segment_reduce.ops import csr_aggregate_op
from repro.kernels.segment_reduce.ref import csr_aggregate_ref

__all__ = ["csr_aggregate", "csr_aggregate_op", "csr_aggregate_ref"]
