"""Pallas TPU kernel: padded-CSR neighbor aggregation (gather + reduce).

TPU adaptation of the Giraph message loop: instead of scattering messages
edge-by-edge (GPU-style atomics have no TPU analogue), neighbors are packed
into an (N, max_deg) rectangle (``PaddedCSR``) so each output row *gathers*
its inputs — a pull model with fully regular tiles:

  grid = (N/bn, S/bs, D/bd); for each (node-block, seat-block, deg-block):
      out[bn, bs] += Σ_{k<bd} wgt[bn, k] · F[nbr[bn, k], bs]

F's seed/feature column panel (N, bs) stays resident in VMEM across the
node-block sweep (BlockSpec index ignores i), so the gather is VMEM-local —
the HBM traffic is one read of F per column panel plus the nbr/wgt tiles.
VMEM budget: N·bs·4 bytes for the panel (N ≤ ~16k at bs=128 fits the 16MB
+ tiles).  For larger N the caller shards nodes first (the distributed
engine's node bands keep per-shard N bounded).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, default_interpret, tpu_compiler_params


def _csr_agg_kernel(nbr_ref, wgt_ref, f_ref, out_ref, acc_ref, *, d_steps, bd):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    nbr = nbr_ref[...]            # (bn, bd)
    wgt = wgt_ref[...].astype(jnp.float32)
    f = f_ref[...]                # (N, bs) resident panel
    # unrolled gather-accumulate over the neighbor-slot axis: each step is a
    # (bn,)-row gather from the VMEM panel + an axpy. bd is kept small (8-32)
    # so the unroll stays reasonable.
    for k in range(bd):
        rows = f[nbr[:, k], :].astype(jnp.float32)   # (bn, bs) gather
        acc_ref[...] += wgt[:, k][:, None] * rows

    @pl.when(d == d_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bn", "bs", "bd", "interpret")
)
def csr_aggregate(
    nbr: jax.Array,   # (N, D) int32
    wgt: jax.Array,   # (N, D)
    F: jax.Array,     # (N, S)
    *,
    bn: int = 256,
    bs: int = 128,
    bd: int = 16,
    interpret: bool | None = None,
) -> jax.Array:
    n, dmax = nbr.shape
    _, s = F.shape
    bn = min(bn, n)
    bs = min(bs, s)
    bd = min(bd, dmax)
    n_pad = cdiv(n, bn) * bn
    s_pad = cdiv(s, bs) * bs
    d_pad = cdiv(dmax, bd) * bd
    if n_pad != n or d_pad != dmax:
        nbr = jnp.pad(nbr, ((0, n_pad - n), (0, d_pad - dmax)))
        wgt = jnp.pad(wgt, ((0, n_pad - n), (0, d_pad - dmax)))
    if n_pad != n or s_pad != s:
        F = jnp.pad(F, ((0, n_pad - n), (0, s_pad - s)))
    grid = (n_pad // bn, s_pad // bs, d_pad // bd)
    if interpret is None:
        interpret = default_interpret()
    kernel = functools.partial(_csr_agg_kernel, d_steps=grid[2], bd=bd)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, d: (i, d)),       # nbr tile
            pl.BlockSpec((bn, bd), lambda i, j, d: (i, d)),       # wgt tile
            pl.BlockSpec((n_pad, bs), lambda i, j, d: (0, j)),    # F panel
        ],
        out_specs=pl.BlockSpec((bn, bs), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, s_pad), F.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bs), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(nbr, wgt, F)
    if n_pad != n or s_pad != s:
        out = out[:n, :s]
    return out
