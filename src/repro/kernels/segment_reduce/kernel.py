"""Pallas TPU kernels: padded-CSR neighbor aggregation (gather + reduce).

TPU adaptation of the Giraph message loop: instead of scattering messages
edge-by-edge (GPU-style atomics have no TPU analogue), neighbors are packed
into an (M, max_deg) rectangle (``PaddedCSR`` / one ``BlockedCSR`` width
bucket) so each output row *gathers* its inputs — a pull model with fully
regular tiles:

  grid = (M/bm, S/bs, D/bd); for each (row-block, seat-block, deg-block):
      out[bm, bs] += Σ_{k<bd} wgt[bm, k] · F[nbr[bm, k], bs]

F's seed/feature column panel (N, bs) stays resident in VMEM across the
row-block sweep (BlockSpec index ignores i), so the gather is VMEM-local —
the HBM traffic is one read of F per column panel plus the nbr/wgt tiles.
VMEM budget: N·bs·4 bytes for the panel (N ≤ ~16k at bs=128 fits the 16MB
+ tiles).  For larger N the caller shards nodes first (the distributed
engine's node bands keep per-shard N bounded).

The output row count M may differ from the panel row count N: a blocked-CSR
width bucket aggregates only its own rows while gathering from the full
panel (DESIGN.md §11).

``csr_round`` is the fused LP round: the same accumulation with a
``c · base`` epilogue folded into the flush, so one kernel call computes
``A_eff @ F + β²·Y`` for its row bucket without a second HBM pass.

``csr_round_residual`` is the fused *superstep*: the round plus the
per-column convergence reduction ``max_r |out − prev|`` emitted from the
same flush, so the σ-check the LP loops run never re-reads the (N, S)
state from HBM.  ``prev`` is the pre-round state slice for this bucket's
rows; the second output is one max-partial row per row block, reduced to
the (S,) residual by a cheap (grid_m, S) host-side max.  Accumulation is
fp32 regardless of the storage dtype, so a bf16 ``F``/``wgt`` pair (the
engine's ``storage_dtype="bf16"`` mode) quantizes only the operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, default_interpret, tpu_compiler_params


def _csr_agg_kernel(nbr_ref, wgt_ref, f_ref, out_ref, acc_ref, *, d_steps, bd):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    nbr = nbr_ref[...]  # (bm, bd)
    wgt = wgt_ref[...].astype(jnp.float32)
    f = f_ref[...]  # (N, bs) resident panel
    # unrolled gather-accumulate over the neighbor-slot axis: each step is a
    # (bm,)-row gather from the VMEM panel + an axpy. bd is kept small (8-32)
    # so the unroll stays reasonable.
    for k in range(bd):
        rows = f[nbr[:, k], :].astype(jnp.float32)  # (bm, bs) gather
        acc_ref[...] += wgt[:, k][:, None] * rows

    @pl.when(d == d_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _csr_round_kernel(
    nbr_ref, wgt_ref, f_ref, base_ref, out_ref, acc_ref, *, d_steps, bd, c
):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        # epilogue folded into init: acc starts at c·base, the deg sweep
        # accumulates A_eff @ F on top — one VMEM-resident fused round.
        acc_ref[...] = c * base_ref[...].astype(jnp.float32)

    nbr = nbr_ref[...]
    wgt = wgt_ref[...].astype(jnp.float32)
    f = f_ref[...]
    for k in range(bd):
        rows = f[nbr[:, k], :].astype(jnp.float32)
        acc_ref[...] += wgt[:, k][:, None] * rows

    @pl.when(d == d_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _csr_round_res_kernel(
    nbr_ref,
    wgt_ref,
    f_ref,
    base_ref,
    prev_ref,
    out_ref,
    delta_ref,
    acc_ref,
    *,
    d_steps,
    bd,
    c,
):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = c * base_ref[...].astype(jnp.float32)

    nbr = nbr_ref[...]
    wgt = wgt_ref[...].astype(jnp.float32)
    f = f_ref[...]
    for k in range(bd):
        rows = f[nbr[:, k], :].astype(jnp.float32)
        acc_ref[...] += wgt[:, k][:, None] * rows

    @pl.when(d == d_steps - 1)
    def _flush():
        acc = acc_ref[...]
        out_ref[...] = acc.astype(out_ref.dtype)
        # the residual folded into the flush: padded rows carry zero base,
        # zero weights, and zero prev, so they contribute |0 − 0| = 0
        diff = jnp.abs(acc - prev_ref[...].astype(jnp.float32))
        delta_ref[...] = jnp.max(diff, axis=0, keepdims=True)


def _pad_inputs(nbr, wgt, F, bm, bs, bd):
    m, dmax = nbr.shape
    n, s = F.shape
    m_pad = cdiv(m, bm) * bm
    n_pad = cdiv(n, 8) * 8  # panel rows to the f32 sublane multiple
    s_pad = cdiv(s, bs) * bs
    d_pad = cdiv(dmax, bd) * bd
    if m_pad != m or d_pad != dmax:
        nbr = jnp.pad(nbr, ((0, m_pad - m), (0, d_pad - dmax)))
        wgt = jnp.pad(wgt, ((0, m_pad - m), (0, d_pad - dmax)))
    if n_pad != n or s_pad != s:
        F = jnp.pad(F, ((0, n_pad - n), (0, s_pad - s)))
    return nbr, wgt, F, m_pad, s_pad, d_pad


@functools.partial(jax.jit, static_argnames=("bn", "bs", "bd", "interpret"))
def csr_aggregate(
    nbr: jax.Array,  # (M, D) int32
    wgt: jax.Array,  # (M, D)
    F: jax.Array,  # (N, S)
    *,
    bn: int = 256,
    bs: int = 128,
    bd: int = 16,
    interpret: bool | None = None,
) -> jax.Array:
    """out[r] = Σ_k wgt[r, k] · F[nbr[r, k]] for M rows over an (N, S) panel."""
    m, dmax = nbr.shape
    n, s = F.shape
    bm = min(bn, m)
    bs = min(bs, s)
    bd = min(bd, dmax)
    nbr, wgt, F, m_pad, s_pad, d_pad = _pad_inputs(nbr, wgt, F, bm, bs, bd)
    grid = (m_pad // bm, s_pad // bs, d_pad // bd)
    if interpret is None:
        interpret = default_interpret()
    kernel = functools.partial(_csr_agg_kernel, d_steps=grid[2], bd=bd)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),  # nbr tile
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),  # wgt tile
            pl.BlockSpec((F.shape[0], bs), lambda i, j, d: (0, j)),  # F panel
        ],
        out_specs=pl.BlockSpec((bm, bs), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, s_pad), F.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bs), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(nbr, wgt, F)
    if m_pad != m or s_pad != s:
        out = out[:m, :s]
    return out


@functools.partial(
    jax.jit, static_argnames=("c", "bn", "bs", "bd", "interpret")
)
def csr_round(
    nbr: jax.Array,  # (M, D) int32
    wgt: jax.Array,  # (M, D)
    F: jax.Array,  # (N, S)
    base: jax.Array,  # (M, S)
    *,
    c: float,
    bn: int = 256,
    bs: int = 128,
    bd: int = 16,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused LP round for one row bucket: ``c·base + Σ_k wgt·F[nbr]``."""
    m, dmax = nbr.shape
    n, s = F.shape
    if base.shape != (m, s):
        raise ValueError(f"base must be ({m}, {s}), got {base.shape}")
    bm = min(bn, m)
    bs = min(bs, s)
    bd = min(bd, dmax)
    nbr, wgt, F, m_pad, s_pad, d_pad = _pad_inputs(nbr, wgt, F, bm, bs, bd)
    if base.shape != (m_pad, s_pad):
        base = jnp.pad(base, ((0, m_pad - m), (0, s_pad - s)))
    grid = (m_pad // bm, s_pad // bs, d_pad // bd)
    if interpret is None:
        interpret = default_interpret()
    kernel = functools.partial(
        _csr_round_kernel, d_steps=grid[2], bd=bd, c=c
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),  # nbr tile
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),  # wgt tile
            pl.BlockSpec((F.shape[0], bs), lambda i, j, d: (0, j)),  # F panel
            pl.BlockSpec((bm, bs), lambda i, j, d: (i, j)),  # base tile
        ],
        out_specs=pl.BlockSpec((bm, bs), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, s_pad), F.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bs), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(nbr, wgt, F, base)
    if m_pad != m or s_pad != s:
        out = out[:m, :s]
    return out


@functools.partial(
    jax.jit, static_argnames=("c", "bn", "bs", "bd", "interpret")
)
def csr_round_residual(
    nbr: jax.Array,  # (M, D) int32
    wgt: jax.Array,  # (M, D)
    F: jax.Array,  # (N, S) gather panel (storage dtype)
    base: jax.Array,  # (M, S) seed/base slice for this bucket
    prev: jax.Array,  # (M, S) pre-round state slice for this bucket
    *,
    c: float,
    bn: int = 256,
    bs: int = 128,
    bd: int = 16,
    interpret: bool | None = None,
) -> tuple:
    """Fused superstep for one row bucket.

    Returns ``(out, delta)`` where ``out = c·base + Σ_k wgt·F[nbr]`` in
    ``base.dtype`` (the state dtype — a bf16 panel still yields fp32
    state) and ``delta`` is the ``(grid_m, S)`` per-row-block partial of
    ``max_r |out − prev|``; reduce it with ``jnp.max(delta, axis=0)``.
    """
    m, dmax = nbr.shape
    n, s = F.shape
    if base.shape != (m, s) or prev.shape != (m, s):
        raise ValueError(
            f"base/prev must be ({m}, {s}), got {base.shape}/{prev.shape}"
        )
    bm = min(bn, m)
    bs = min(bs, s)
    bd = min(bd, dmax)
    nbr, wgt, F, m_pad, s_pad, d_pad = _pad_inputs(nbr, wgt, F, bm, bs, bd)
    if base.shape != (m_pad, s_pad):
        base = jnp.pad(base, ((0, m_pad - m), (0, s_pad - s)))
        prev = jnp.pad(prev, ((0, m_pad - m), (0, s_pad - s)))
    grid = (m_pad // bm, s_pad // bs, d_pad // bd)
    if interpret is None:
        interpret = default_interpret()
    kernel = functools.partial(
        _csr_round_res_kernel, d_steps=grid[2], bd=bd, c=c
    )
    out, delta = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),  # nbr tile
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),  # wgt tile
            pl.BlockSpec((F.shape[0], bs), lambda i, j, d: (0, j)),  # F panel
            pl.BlockSpec((bm, bs), lambda i, j, d: (i, j)),  # base tile
            pl.BlockSpec((bm, bs), lambda i, j, d: (i, j)),  # prev tile
        ],
        out_specs=[
            pl.BlockSpec((bm, bs), lambda i, j, d: (i, j)),
            pl.BlockSpec((1, bs), lambda i, j, d: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, s_pad), base.dtype),
            jax.ShapeDtypeStruct((grid[0], s_pad), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bs), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(nbr, wgt, F, base, prev)
    if m_pad != m or s_pad != s:
        out = out[:m, :s]
        delta = delta[:, :s]
    return out, delta
