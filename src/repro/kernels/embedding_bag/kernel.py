"""Pallas TPU kernel: embedding-bag (multi-hot gather + weighted reduce).

Recsys hot path.  The vocab-sharded table shard for one device is kept
HBM-resident; the kernel streams batch tiles and keeps a (rows_budget, D)
*table panel* in VMEM, processing the batch tile against each panel:

  grid = (B/bb, V/bv); out[b] += Σ_k w[b,k]·T[idx[b,k]]  for idx in panel v

Indices outside the current panel are masked to weight 0 (panel-local
offset), so the sweep over panels accumulates exactly once per index.  This
is the TPU-native replacement for row-atomic gathers: every memory access
is a regular tile, the irregularity is absorbed by the mask.

For tables whose embedding-dim panel fits VMEM whole (V·D·4 ≤ ~8MB — true
for the per-device shard after vocab sharding at production scale), set
``bv = V`` and the sweep collapses to one step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, default_interpret, tpu_compiler_params


def _embed_bag_kernel(idx_ref, w_ref, tab_ref, out_ref, acc_ref, *, bv, v_steps, k_slots):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[...]                       # (bb, K)
    w = w_ref[...].astype(jnp.float32)       # (bb, K)
    tab = tab_ref[...]                       # (bv, D) panel
    base = v * bv
    local = idx - base                       # panel-local
    in_panel = (local >= 0) & (local < bv)
    local = jnp.where(in_panel, local, 0)
    w_masked = jnp.where(in_panel, w, 0.0)
    for k in range(k_slots):
        rows = tab[local[:, k], :].astype(jnp.float32)   # (bb, D)
        acc_ref[...] += w_masked[:, k][:, None] * rows

    @pl.when(v == v_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bb", "bv", "interpret")
)
def embedding_bag(
    table: jax.Array,   # (V, D)
    idx: jax.Array,     # (B, K) int32
    w: jax.Array,       # (B, K)
    *,
    bb: int = 256,
    bv: int = 8192,
    interpret: bool | None = None,
) -> jax.Array:
    v_size, d = table.shape
    b, k_slots = idx.shape
    bb = min(bb, b)
    bv = min(bv, v_size)
    b_pad = cdiv(b, bb) * bb
    v_pad = cdiv(v_size, bv) * bv
    if b_pad != b:
        idx = jnp.pad(idx, ((0, b_pad - b), (0, 0)))
        w = jnp.pad(w, ((0, b_pad - b), (0, 0)))
    if v_pad != v_size:
        table = jnp.pad(table, ((0, v_pad - v_size), (0, 0)))
    grid = (b_pad // bb, v_pad // bv)
    if interpret is None:
        interpret = default_interpret()
    kernel = functools.partial(
        _embed_bag_kernel, bv=bv, v_steps=grid[1], k_slots=k_slots
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k_slots), lambda i, v: (i, 0)),   # idx tile
            pl.BlockSpec((bb, k_slots), lambda i, v: (i, 0)),   # w tile
            pl.BlockSpec((bv, d), lambda i, v: (v, 0)),         # table panel
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i, v: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, d), table.dtype),
        scratch_shapes=[pltpu.VMEM((bb, d), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(idx, w, table)
    if b_pad != b:
        out = out[:b]
    return out
