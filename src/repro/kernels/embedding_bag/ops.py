"""Public wrapper for embedding-bag with fallback to the jnp oracle."""
from __future__ import annotations

import jax

from repro.kernels.common import default_interpret
from repro.kernels.embedding_bag.kernel import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.obs.profiler import kernel_clock, kernel_time


def embedding_bag_op(
    table: jax.Array,
    idx: jax.Array,
    w: jax.Array,
    *,
    bb: int = 256,
    bv: int = 8192,
    use_kernel: bool | None = None,
) -> jax.Array:
    if use_kernel is None:
        use_kernel = idx.shape[0] >= 128
    t0 = kernel_clock()
    if not use_kernel:
        return kernel_time("embedding_bag.ref", t0, embedding_bag_ref(table, idx, w))
    out = embedding_bag(
        table, idx, w, bb=bb, bv=bv, interpret=default_interpret()
    )
    return kernel_time("embedding_bag.kernel", t0, out)
