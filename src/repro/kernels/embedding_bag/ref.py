"""Pure-jnp oracle for the embedding-bag lookup.

``out[b] = Σ_k w[b, k] · table[idx[b, k]]`` — the multi-hot gather+reduce
at the heart of the recsys arch (JAX has no native EmbeddingBag; this IS
the implementation, kernel-accelerated on TPU).
"""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(
    table: jnp.ndarray,   # (V, D)
    idx: jnp.ndarray,     # (B, K) int32
    w: jnp.ndarray,       # (B, K) per-sample weights (0 = pad)
) -> jnp.ndarray:
    gathered = table[idx]                    # (B, K, D)
    out = jnp.einsum(
        "bk,bkd->bd", w.astype(jnp.float32), gathered.astype(jnp.float32)
    )
    return out.astype(table.dtype)
