"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel subpackage ships: ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper with fallback), ``ref.py`` (pure-jnp
oracle used by the allclose test sweeps).
"""
from repro.kernels.embedding_bag import (
    embedding_bag,
    embedding_bag_op,
    embedding_bag_ref,
)
from repro.kernels.flash_attention import (
    attention_ref,
    flash_attention,
    gqa_attention_op,
)
from repro.kernels.lp_blockspmm import lp_round, lp_round_op, lp_round_ref
from repro.kernels.segment_reduce import (
    csr_aggregate,
    csr_aggregate_op,
    csr_aggregate_ref,
    csr_round,
    csr_round_op,
    csr_round_ref,
    csr_round_residual,
    csr_round_residual_op,
    csr_round_residual_ref,
)

__all__ = [
    "attention_ref",
    "csr_aggregate",
    "csr_aggregate_op",
    "csr_aggregate_ref",
    "csr_round",
    "csr_round_op",
    "csr_round_ref",
    "csr_round_residual",
    "csr_round_residual_op",
    "csr_round_residual_ref",
    "embedding_bag",
    "embedding_bag_op",
    "embedding_bag_ref",
    "flash_attention",
    "gqa_attention_op",
    "lp_round",
    "lp_round_op",
    "lp_round_ref",
]
