from repro.ft.runtime import (
    ElasticController,
    FailureInjector,
    StepGuard,
    StragglerWatch,
    TransientWorkerError,
    is_retryable,
)

__all__ = [
    "ElasticController",
    "FailureInjector",
    "StepGuard",
    "StragglerWatch",
    "TransientWorkerError",
    "is_retryable",
]
