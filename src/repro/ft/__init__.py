from repro.ft.elastic import remesh
from repro.ft.runtime import (
    ElasticController,
    FailureInjector,
    StepGuard,
    StragglerWatch,
    TransientWorkerError,
    is_retryable,
)

__all__ = [
    "ElasticController",
    "FailureInjector",
    "StepGuard",
    "StragglerWatch",
    "TransientWorkerError",
    "checkpointed_solve",
    "is_retryable",
    "remesh",
    "supports_checkpointed",
]


def __getattr__(name):
    # checkpointed_solve pulls in numpy/engine machinery; keep the base
    # package import-light for the spec layer
    if name in ("checkpointed_solve", "supports_checkpointed"):
        from repro.ft import solve as _solve

        return getattr(_solve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
