"""Elastic re-mesh: checkpoint-restore-reshard on device-count change.

Checkpoints are saved UNSHARDED (host-gathered, see
:mod:`repro.checkpoint.store`), so surviving a device-count change is a
policy decision plus a restore with new shardings — no resharding tool.
:class:`repro.ft.ElasticController` owns the policy (shrink to the
largest power-of-two ≤ healthy devices); :func:`remesh` executes it
end to end: save the current state, build shardings for the target mesh,
restore every leaf onto it with ``jax.device_put``.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

from repro.ft.runtime import ElasticController

PyTree = Any


def remesh(
    manager,
    tree: PyTree,
    *,
    healthy_devices: int,
    current_devices: int,
    make_shardings: Optional[Callable[[int], PyTree]] = None,
    controller: Optional[ElasticController] = None,
    step: int = 0,
    telemetry=None,
) -> Tuple[PyTree, Optional[Dict]]:
    """Plan and execute a re-mesh for ``tree``.

    ``make_shardings(target_devices)`` returns a shardings pytree (same
    structure as ``tree``) for the shrunk mesh; ``None`` restores to
    host arrays, which is still the correct durability round-trip on a
    single-device runner.  Returns ``(tree, plan)`` — the input tree
    untouched when the device count is unchanged (``plan is None``).
    """
    controller = controller or ElasticController()
    plan = controller.plan(healthy_devices, current_devices)
    if plan is None:
        return tree, None
    manager.save(step, tree, metadata={"elastic": plan})
    manager.wait()
    shardings = make_shardings(plan["to"]) if make_shardings else None
    restored_step, restored = manager.restore_latest(tree, shardings=shardings)
    if restored is None:
        raise RuntimeError("elastic remesh: checkpoint restore failed")
    if telemetry is not None:
        telemetry.count("ft.remeshes")
        telemetry.gauge("ft.mesh_devices", plan["to"])
    return restored, plan
