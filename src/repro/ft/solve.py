"""Checkpointed solve: the host-driven round loop with durable state.

Giraph gives the paper's DHLP-1/2 superstep checkpointing for free —
Pregel snapshots vertex state at superstep barriers and a worker failure
rolls the computation back to the last barrier.  This module is that
barrier snapshot for our engines: the same host-driven ``engine.round``
loop as :mod:`repro.obs.solve` (fused DHLP-2, fixed seeds, voteToHalt
freeze, optional heavy-ball momentum), but every ``interval`` supersteps
the full loop state — label panel ``F``, the momentum predecessor, the
per-column active mask and iteration counters — goes through
:class:`repro.checkpoint.CheckpointManager` together with the
outer-iteration cursor.

A killed run resumes by restoring the latest durable superstep and
continuing the identical iteration: every array is saved bit-exact
(float64 host loop, lossless ``.npy``), so the resumed trajectory —
and therefore the final rankings — match an uninterrupted run with
``max|Δ| == 0``.

Eligibility matches :func:`repro.obs.solve.supports_observed`; the
checkpointed loop always runs the whole seed panel in one block (a
chunked panel would need per-chunk cursors for no benefit — the fixed
point is chunk-independent).
"""

from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.solver import SolveResult
from repro.obs.solve import supports_observed

supports_checkpointed = supports_observed


class _NullTelemetry:
    """Telemetry shim for library use outside a Session."""

    def count(self, name: str, n: int = 1) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def maybe_flush(self) -> None:
        pass

    def trace_span(self, kind: str, name: str):
        import contextlib

        return contextlib.nullcontext()


def _initial_state(Y: np.ndarray, F0: Optional[np.ndarray]) -> Dict[str, Any]:
    F = Y.copy() if F0 is None else np.array(F0, dtype=np.float64, copy=True)
    return {
        "F": F,
        "F_prev": F.copy(),
        "active": np.ones(Y.shape[1], dtype=bool),
        "col_iters": np.zeros(Y.shape[1], dtype=np.int32),
    }


def checkpointed_solve(
    engine,
    net,
    seeds: Optional[np.ndarray] = None,
    F0: Optional[np.ndarray] = None,
    *,
    manager,
    interval: int = 5,
    telemetry=None,
    injector=None,
    straggler=None,
) -> Tuple[SolveResult, Dict[str, Any]]:
    """``engine.run`` semantics with durable superstep barriers.

    Returns ``(result, ft_stats)`` where ``ft_stats`` carries the
    durability roll-up (checkpoints written, resume cursor, checkpoint
    root).  ``injector`` (a :class:`repro.ft.FailureInjector`) fires at
    superstep boundaries on a *fresh* run only — a resumed run never
    re-fires, matching real crash semantics — so drills kill the process
    once and ``--resume`` completes cleanly.
    """
    from repro.core.network import seeds_identity

    tel = telemetry if telemetry is not None else _NullTelemetry()
    op = engine.prepare(net)
    n = op.num_nodes
    Y = seeds_identity(n) if seeds is None else np.asarray(seeds, dtype=np.float64)
    if Y.ndim == 1:
        Y = Y[:, None]
    if Y.shape[0] != n:
        raise ValueError(f"seeds must have {n} rows, got {Y.shape}")
    if F0 is not None:
        F0 = np.asarray(F0, dtype=np.float64)
        if F0.ndim == 1:
            F0 = F0[:, None]
        if F0.shape != Y.shape:
            raise ValueError(f"F0 shape {F0.shape} must match seeds shape {Y.shape}")

    cfg = engine.config
    state = _initial_state(Y, F0)
    start_step, restored = manager.restore_latest(state)
    resumed_from: Optional[int] = None
    if restored is not None:
        state = restored
        resumed_from = start_step
        tel.count("ft.resumes")
    else:
        start_step = 0

    checkpoints = 0
    converged = False
    step = start_step
    residual = 0.0
    while step < cfg.max_iter:
        if injector is not None and resumed_from is None:
            injector.maybe_fail(step)
        t0 = time.perf_counter()
        with tel.trace_span("superstep", f"superstep:{step}"):
            F, F_prev, active = state["F"], state["F_prev"], state["active"]
            Fn = np.asarray(engine.round(op, F, Y), dtype=np.float64)
            if cfg.momentum:
                Fn = Fn + cfg.momentum * (F - F_prev)
            Fn = np.where(active[None, :], Fn, F)
            delta = np.max(np.abs(Fn - F), axis=0)
            state["col_iters"] = state["col_iters"] + active.astype(np.int32)
            still = active & ~(delta < cfg.sigma)
            residual = float(delta[active].max()) if active.any() else 0.0
        if straggler is not None:
            straggler.observe(time.perf_counter() - t0)
        state["F_prev"], state["F"], state["active"] = F, Fn, still
        step += 1
        tel.gauge("solve.residual", residual)
        tel.gauge("solve.active_columns", int(still.sum()))
        tel.maybe_flush()
        converged = not still.any()
        if converged or step % interval == 0:
            manager.save(
                step,
                state,
                metadata={"step": step, "residual": residual, "kind": "solve"},
            )
            checkpoints += 1
            tel.count("ft.checkpoints")
        if converged:
            break

    manager.wait()
    result = SolveResult(
        F=state["F"],
        outer_iters=step,
        inner_iters=0,
        converged=converged,
        per_column_iters=state["col_iters"],
    )
    tel.count("solve.supersteps", step - start_step)
    tel.count("solve.columns", Y.shape[1])
    stats: Dict[str, Any] = {
        "checkpoints": checkpoints,
        "resumed_from": resumed_from,
        "ckpt_dir": manager.root,
    }
    return result, stats
