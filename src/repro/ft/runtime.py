"""Fault-tolerance runtime: failure handling, straggler watch, retries.

On a 1000+-node cluster the failure model is: a worker dies mid-step
(preemption, HBM ECC, network partition) or slows down (straggler).  The
driver-side mechanisms here are platform-agnostic:

* ``StepGuard`` — wraps the train step; classifies exceptions as
  retryable (transient runtime errors) vs fatal (shape/compile bugs),
  retries with backoff, and after ``max_retries`` restores from the last
  checkpoint and replays.
* ``StragglerWatch`` — EWMA of step times; flags steps slower than
  ``threshold ×`` the running mean.  In the LP engine the mitigation is
  bounded staleness (``ShardedHeteroLP(stale_sync=k)``); in the train loop
  it feeds the elastic controller below.
* ``ElasticController`` — decides on re-meshing when the healthy device
  count changes; checkpoints are saved unsharded, so a restore onto the
  new mesh is just ``CheckpointManager.restore(..., shardings=new)``.
* ``FailureInjector`` — deterministic fault injection for tests: raises a
  transient error on chosen steps so CI can exercise the recovery path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

PyTree = Any


class TransientWorkerError(RuntimeError):
    """A failure that a retry / restore-replay can heal."""


_RETRYABLE = (TransientWorkerError,)
_RETRYABLE_MESSAGES = (
    "DATA_LOSS", "UNAVAILABLE", "DEADLINE_EXCEEDED", "RESOURCE_EXHAUSTED",
    "socket closed", "connection reset",
)


def is_retryable(err: BaseException) -> bool:
    if isinstance(err, _RETRYABLE):
        return True
    msg = str(err)
    return any(tag in msg for tag in _RETRYABLE_MESSAGES)


@dataclasses.dataclass
class StragglerWatch:
    """EWMA step timer; flags outliers (the paper's fig. 4 problem: one
    slow worker gates every BSP superstep).

    With ``telemetry`` set, every flagged step bumps the
    ``ft.straggler_flags`` counter and each observation refreshes the
    ``ft.step_time_mean`` gauge, so the SLO watchdog can alert on
    straggler rate without polling this object.
    """

    alpha: float = 0.1
    threshold: float = 2.0
    _mean: Optional[float] = None
    slow_steps: int = 0
    telemetry: Optional[Any] = None

    def observe(self, step_time: float) -> bool:
        if self._mean is None:
            self._mean = step_time
            if self.telemetry is not None:
                self.telemetry.gauge("ft.step_time_mean", self._mean)
            return False
        is_slow = step_time > self.threshold * self._mean
        if is_slow:
            self.slow_steps += 1
            if self.telemetry is not None:
                self.telemetry.count("ft.straggler_flags")
        # slow steps perturb the mean less (they are the anomaly)
        a = self.alpha * (0.25 if is_slow else 1.0)
        self._mean = (1 - a) * self._mean + a * step_time
        if self.telemetry is not None:
            self.telemetry.gauge("ft.step_time_mean", self._mean)
        return is_slow

    @property
    def mean_step_time(self) -> Optional[float]:
        return self._mean


@dataclasses.dataclass
class FailureInjector:
    """Raise a transient error at the given steps (tests/chaos drills)."""

    fail_at: Tuple[int, ...] = ()
    fired: List[int] = dataclasses.field(default_factory=list)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.append(step)
            raise TransientWorkerError(f"injected fault at step {step}")


@dataclasses.dataclass
class StepGuard:
    """Retry/restore wrapper around one step of work.

    Transient failures retry with exponential backoff; once the retry
    budget is spent, ``restore_fn`` (if any) rolls state back to the last
    checkpoint and the replay re-enters the *same* guarded loop with a
    fresh budget — a transient fault during the replay is retried, not
    propagated.  One restore per ``run`` call: exhausting the budget a
    second time re-raises the last error.

    ``sleep`` is the backoff clock — injectable so tests (and simulated
    time) never wall-sleep.  With ``telemetry`` set, retries and restores
    bump the ``ft.retries`` / ``ft.restores`` counters.
    """

    max_retries: int = 3
    backoff_s: float = 0.05
    restore_fn: Optional[Callable[[], Tuple[int, PyTree]]] = None
    retries: int = 0
    restores: int = 0
    sleep: Callable[[float], None] = time.sleep
    telemetry: Optional[Any] = None

    def run(self, step_fn: Callable[[], PyTree]) -> PyTree:
        last: Optional[BaseException] = None
        attempt = 0
        restored = False
        while True:
            try:
                return step_fn()
            except BaseException as e:  # noqa: BLE001
                if not is_retryable(e):
                    raise
                last = e
                if attempt < self.max_retries:
                    self.retries += 1
                    if self.telemetry is not None:
                        self.telemetry.count("ft.retries")
                    self.sleep(self.backoff_s * (2 ** attempt))
                    attempt += 1
                    continue
                if self.restore_fn is not None and not restored:
                    restored = True
                    self.restores += 1
                    if self.telemetry is not None:
                        self.telemetry.count("ft.restores")
                    self.restore_fn()
                    attempt = 0  # the replay gets a fresh retry budget
                    continue
                raise last


@dataclasses.dataclass
class ElasticController:
    """Re-mesh policy: checkpoint → rebuild mesh on the healthy devices →
    restore with new shardings.  Device loss detection is platform-level;
    here we expose the decision + bookkeeping used by launch/train.py."""

    min_devices: int = 1
    history: List[Dict] = dataclasses.field(default_factory=list)

    def plan(self, healthy_devices: int, current_devices: int) -> Optional[Dict]:
        if healthy_devices == current_devices:
            return None
        if healthy_devices < self.min_devices:
            raise RuntimeError(
                f"{healthy_devices} devices < minimum {self.min_devices}"
            )
        # shrink to the largest power-of-two ≤ healthy (keeps meshes tidy)
        target = 1
        while target * 2 <= healthy_devices:
            target *= 2
        plan = {
            "from": current_devices,
            "to": target,
            "action": "checkpoint-restore-reshard",
        }
        self.history.append(plan)
        return plan
