"""Output stage (paper workflow steps E–G, Fig. 2).

From the converged label matrix ``F (N, N)`` (all-sources run) we produce:
  1. the *first output*: predicted interaction matrices per type pair,
  2. the *second output*: updated similarity matrices per type,
  3. the *final output*: per-entity sorted candidate lists (step G).

The paper symmetrizes mutual labels in the last superstep
("the vertices carry out mean operation for their mutual labels"):
``out(u, v) = (F[u, v] + F[v, u]) / 2``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.network import NormalizedNetwork, TypePair


@dataclasses.dataclass
class LPOutputs:
    similarities: List[np.ndarray]  # per type: (n_i, n_i)
    interactions: Dict[TypePair, np.ndarray]  # per pair (i<j): (n_i, n_j)

    def ranked_candidates(
        self, pair: TypePair, entity: int, top_k: int = 20
    ) -> np.ndarray:
        """Top-k entities of type ``pair[1]`` for ``entity`` of ``pair[0]``.

        The paper's step G: e.g. for the drug-target matrix, the targets are
        sorted per drug by similarity degree (Tables 3/4).
        """
        i, j = pair
        if (i, j) in self.interactions:
            row = self.interactions[(i, j)][entity]
        elif (j, i) in self.interactions:
            row = self.interactions[(j, i)][:, entity]
        else:
            raise KeyError(f"no interaction block for {pair}")
        order = np.argsort(-row, kind="stable")
        return order[:top_k]


def symmetrize(F: np.ndarray) -> np.ndarray:
    if F.shape[0] != F.shape[1]:
        raise ValueError(
            "symmetrization needs the all-sources (square) label matrix; "
            f"got {F.shape}"
        )
    return (F + F.T) / 2.0


def extract_outputs(F: np.ndarray, norm: NormalizedNetwork) -> LPOutputs:
    out = symmetrize(F)
    sl = norm.block_slices()
    sims = [out[sl[i], sl[i]].copy() for i in range(norm.num_types)]
    inters: Dict[TypePair, np.ndarray] = {}
    for i in range(norm.num_types):
        for j in range(i + 1, norm.num_types):
            inters[(i, j)] = out[sl[i], sl[j]].copy()
    return LPOutputs(similarities=sims, interactions=inters)


def topk_exclusive(
    scores: np.ndarray,
    top_k: int,
    exclude: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Indices of the ``top_k`` highest scores, skipping ``exclude``.

    The serving front-end's ranking step: candidate lists for drug
    repositioning must *exclude* the already-known associations (they would
    trivially top the list — the paper's Tables 3/4 rank the held-out /
    novel candidates).  ``exclude`` is an index array or boolean mask over
    ``scores``; ties break stably by index like :func:`rank_of`.
    """
    scores = np.asarray(scores, dtype=np.float64)
    if scores.ndim != 1:
        raise ValueError(f"scores must be 1-D, got {scores.shape}")
    keep = np.ones(scores.shape[0], dtype=bool)
    if exclude is not None:
        exclude = np.asarray(exclude)
        if exclude.dtype == bool:
            if exclude.shape != scores.shape:
                raise ValueError(
                    f"boolean exclude shape {exclude.shape} != {scores.shape}"
                )
            keep &= ~exclude
        elif exclude.size:
            keep[exclude.astype(np.int64)] = False
    candidates = np.nonzero(keep)[0]
    order = np.argsort(-scores[candidates], kind="stable")
    return candidates[order[:top_k]]


def rank_of(scores: np.ndarray, index: int) -> int:
    """1-based rank of ``index`` under descending score (ties: stable)."""
    order = np.argsort(-scores, kind="stable")
    return int(np.where(order == index)[0][0]) + 1
