"""Core library: the paper's contribution (DHLP-1/2) as composable modules."""
from repro.core.blocked_csr import (
    BlockedCSR,
    blocked_csr_from_network,
    split_blocked_csr_from_network,
)
from repro.core.closed_form import dhlp1_inner_solution, fixed_seed_solution
from repro.core.network import (
    GraphDelta,
    HeteroCOO,
    HeteroNetwork,
    NormalizedNetwork,
    seeds_for_nodes,
    seeds_identity,
)
from repro.core.normalize import (
    bipartite_normalize,
    spectral_radius_upper_bound,
    symmetric_normalize,
)
from repro.core.ranking import (
    LPOutputs,
    extract_outputs,
    rank_of,
    symmetrize,
    topk_exclusive,
)
from repro.core.reference import (
    RefResult,
    heterlp_single_seed,
    minprop_single_seed,
    run_all_seeds,
)
from repro.core.solver import HeteroLP, LPConfig, SolveResult

__all__ = [
    "BlockedCSR",
    "GraphDelta",
    "HeteroCOO",
    "HeteroLP",
    "HeteroNetwork",
    "LPConfig",
    "LPOutputs",
    "NormalizedNetwork",
    "RefResult",
    "SolveResult",
    "bipartite_normalize",
    "blocked_csr_from_network",
    "dhlp1_inner_solution",
    "extract_outputs",
    "fixed_seed_solution",
    "heterlp_single_seed",
    "minprop_single_seed",
    "rank_of",
    "run_all_seeds",
    "seeds_for_nodes",
    "seeds_identity",
    "spectral_radius_upper_bound",
    "split_blocked_csr_from_network",
    "symmetric_normalize",
    "symmetrize",
    "topk_exclusive",
]
