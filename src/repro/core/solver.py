"""DHLP-1 / DHLP-2 solvers — dense batched engine.

The paper's Giraph programs are re-expressed as tensor iterations
(DESIGN.md §2):

* one BSP superstep of message passing  ==  one (Sp)MM ``S @ F``
* the per-seed sweep (``y=1`` for one vertex at a time) ==  batched seed
  columns ``Y → F`` (the paper-faithful sequential sweep is kept as
  ``mode="sequential"`` and is the baseline the speedup tables measure
  against)
* ``voteToHalt`` == per-column convergence mask (converged columns freeze)

Engines:
  - :func:`dhlp2_dense` — one fused update per round.
  - :func:`dhlp1_dense` — outer injection + inner homogeneous solve.
Both run under ``jax.jit`` with ``lax.while_loop`` so the whole propagation
is a single XLA program (the distributed story lives in
``repro/parallel/lp_sharded.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Literal, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.network import (
    HeteroCOO,
    HeteroNetwork,
    NormalizedNetwork,
    seeds_identity,
)

Algorithm = Literal["dhlp1", "dhlp2"]
SeedMode = Literal["fixed", "drift"]


@dataclasses.dataclass(frozen=True)
class LPConfig:
    """Solver hyper-parameters (paper Table 1 symbols α, σ)."""

    alg: Algorithm = "dhlp2"
    alpha: float = 0.5
    sigma: float = 1e-3
    max_iter: int = 1000  # outer-iteration cap (DHLP-2 rounds)
    max_inner: int = 200  # DHLP-1 inner-loop cap
    seed_mode: Optional[SeedMode] = None  # default: per-pseudocode
    mode: Literal["batched", "sequential"] = "batched"
    seed_chunk: int = 0  # 0 = all seeds in one program
    dtype: jnp.dtype = jnp.float32
    fused: bool = True  # DHLP-2: pre-combine αβH + αM (beyond-paper)
    # Execution backend, a `repro.engine` registry key ("dense", "sparse",
    # "sharded", "kernel", "auto").  None lets the caller decide (HeteroLP
    # stays dense, serve/launch/bench pick via registry).
    backend: Optional[str] = None
    # DEPRECATED — use backend="kernel".  Routes the dense fused round
    # through the Pallas lp_blockspmm kernel (interpret-mode on CPU; Mosaic
    # on TPU).  Constructing LPConfig(use_kernel=True) without an explicit
    # backend warns and maps to backend="kernel" (see __post_init__).
    use_kernel: bool = False
    # Heavy-ball acceleration (beyond-paper): F ← β²·base + A·F_t
    # + momentum·(F_t − F_{t−1}).  Same fixed point (fixed-seed mode), the
    # spectral radius of the iteration drops from ρ to ~√ρ-ish, cutting
    # rounds — and every roofline term of a solve scales with rounds.
    momentum: float = 0.0
    # The paper's pseudocode applies a uniform α to ALL heterogeneous
    # neighbors.  With T>2 node types the cross-type operator H then has
    # spectral radius up to T−1 and the iteration can diverge (MINProp's
    # convergence condition is that the cross-subnetwork coefficients sum
    # below 1).  ``None`` = auto-scale H by 1/(T−1); pass 1.0 for the
    # strictly-literal paper update.
    hetero_scale: Optional[float] = None
    # Mixed precision (sparse/kernel backends): "bf16" stores operator
    # weights and the per-round gather panel in bfloat16 while state and
    # accumulation stay fp32 — halves superstep memory traffic at a
    # slightly shifted fixed point (gated by agree_dense/recovery-AUC in
    # the bench matrix).  "f32" is exact.
    storage_dtype: Literal["f32", "bf16"] = "f32"
    # Consult the persisted autotune cache (repro.engine.autotune) for
    # blocked-CSR layout + kernel panel parameters.  A cold cache falls
    # back to the defaults; False pins the defaults unconditionally.
    autotune: bool = True

    def __post_init__(self) -> None:
        if self.storage_dtype not in ("f32", "bf16"):
            raise ValueError(
                f"storage_dtype must be 'f32' or 'bf16', got "
                f"{self.storage_dtype!r}"
            )
        if self.use_kernel and self.backend is None:
            warnings.warn(
                "LPConfig(use_kernel=True) is deprecated; use "
                "LPConfig(backend='kernel') — the engine registry routes it "
                "through the fused blocked-CSR Pallas round (DESIGN.md §11)",
                DeprecationWarning,
                stacklevel=3,
            )
            object.__setattr__(self, "backend", "kernel")

    def resolved_hetero_scale(self, num_types: int) -> float:
        if self.hetero_scale is not None:
            return float(self.hetero_scale)
        return 1.0 / max(1, num_types - 1)

    def resolved_seed_mode(self) -> SeedMode:
        if self.seed_mode is not None:
            return self.seed_mode
        # Pseudocode defaults: DHLP-1 reads gety() (fixed seed), DHLP-2
        # reads getf() (drifting seed).
        return "fixed" if self.alg == "dhlp1" else "drift"


@dataclasses.dataclass
class SolveResult:
    F: np.ndarray  # (N, S) final labels
    outer_iters: int  # rounds until all columns converged
    inner_iters: int  # DHLP-1 total inner iterations (0 for -2)
    converged: bool
    per_column_iters: Optional[np.ndarray] = None

    @property
    def supersteps(self) -> int:
        """Giraph superstep count equivalent (2 messages rounds per iter)."""
        return 2 * self.outer_iters + self.inner_iters


# --------------------------------------------------------------------------
# DHLP-2  (distributed Heter-LP)
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("alpha", "sigma", "max_iter", "seed_mode")
)
def _dhlp2_step_loop(
    H: jax.Array,
    M: jax.Array,
    Y: jax.Array,
    F0: jax.Array,
    *,
    alpha: float,
    sigma: float,
    max_iter: int,
    seed_mode: str,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Un-fused (paper-faithful) DHLP-2: two propagation ops per round.

    ``F0`` is the warm-start state (pass ``Y`` for a cold solve).  In
    fixed-seed mode the fixed point is independent of ``F0``, so a warm
    start reaches the same answer in fewer rounds (DESIGN.md §9).
    """
    beta = 1.0 - alpha
    acc = jnp.float32

    def cond(state):
        _, active, it, _ = state
        return jnp.logical_and(it < max_iter, jnp.any(active))

    def body(state):
        F, active, it, col_iters = state
        src = Y if seed_mode == "fixed" else F
        # superstep A: heterogeneous injection  y' = βy + αHF
        Yp = beta * src + alpha * jnp.matmul(
            H, F, preferred_element_type=acc
        ).astype(F.dtype)
        # superstep B: homogeneous propagation  f = βy' + αMF
        Fn = beta * Yp + alpha * jnp.matmul(
            M, F, preferred_element_type=acc
        ).astype(F.dtype)
        Fn = jnp.where(active[None, :], Fn, F)  # voteToHalt: freeze
        delta = jnp.max(jnp.abs(Fn - F), axis=0)
        still = jnp.logical_and(active, ~(delta < sigma))
        col_iters = col_iters + active.astype(jnp.int32)
        return Fn, still, it + 1, col_iters

    s = Y.shape[1]
    state0 = (
        F0,
        jnp.ones((s,), dtype=bool),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((s,), jnp.int32),
    )
    F, active, iters, col_iters = jax.lax.while_loop(cond, body, state0)
    return F, iters, col_iters


@functools.partial(
    jax.jit,
    static_argnames=("sigma", "max_iter", "seed_mode", "momentum", "use_kernel"),
)
def _dhlp2_fused_loop(
    A_eff: jax.Array,
    beta2: jax.Array,
    Y: jax.Array,
    F0: jax.Array,
    *,
    sigma: float,
    max_iter: int,
    seed_mode: str,
    momentum: float = 0.0,
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused DHLP-2: one SpMM per round (DESIGN.md §2).

      drift:  F ← β²F + A_eff @ F
      fixed:  F ← β²Y + A_eff @ F [+ μ(F − F_prev) heavy-ball]

    ``F0`` warm-starts the iteration (pass ``Y`` for cold; DESIGN.md §9).
    """
    acc = jnp.float32

    def cond(state):
        _, _, active, it, _ = state
        return jnp.logical_and(it < max_iter, jnp.any(active))

    def body(state):
        F, F_prev, active, it, col_iters = state
        base = Y if seed_mode == "fixed" else F
        if use_kernel:
            from repro.kernels.lp_blockspmm import lp_round_op

            # beta2 is traced; fold it into the base operand (c stays
            # static for the kernel's BlockSpec closure).  use_kernel=True
            # here forces the kernel path: when the config opts in (e.g.
            # the bench backend matrix), the op's size heuristic must not
            # silently fall back to the jnp reference.
            Fn = lp_round_op(A_eff, F, beta2 * base, c=1.0, use_kernel=True)
        else:
            Fn = beta2 * base + jnp.matmul(
                A_eff, F, preferred_element_type=acc
            ).astype(F.dtype)
        if momentum:
            Fn = Fn + momentum * (F - F_prev)
        Fn = jnp.where(active[None, :], Fn, F)
        delta = jnp.max(jnp.abs(Fn - F), axis=0)
        still = jnp.logical_and(active, ~(delta < sigma))
        col_iters = col_iters + active.astype(jnp.int32)
        return Fn, F, still, it + 1, col_iters

    s = Y.shape[1]
    state0 = (
        F0,
        F0,
        jnp.ones((s,), dtype=bool),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((s,), jnp.int32),
    )
    F, _, active, iters, col_iters = jax.lax.while_loop(cond, body, state0)
    return F, iters, col_iters


# --------------------------------------------------------------------------
# DHLP-1  (distributed MINProp)
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("alpha", "sigma", "max_iter", "max_inner", "seed_mode"),
)
def _dhlp1_loop(
    H: jax.Array,
    M: jax.Array,
    Y: jax.Array,
    F0: jax.Array,
    *,
    alpha: float,
    sigma: float,
    max_iter: int,
    max_inner: int,
    seed_mode: str,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """DHLP-1: outer hetero injection, inner homogeneous iterative solve.

    Pseudocode mapping: lines 1–10 (phase A, compute y′ and message) are the
    outer body's first op; lines 11–24 (phase B, iterate f_t until
    |current−last| < σ, then check outer |f − f_old| < σ) are the inner
    while_loop.
    """
    beta = 1.0 - alpha
    acc = jnp.float32

    def inner(Yp, F0, active):
        """Solve F = βY' + αMF to tolerance σ on active columns."""

        def icond(istate):
            _, iact, it = istate
            return jnp.logical_and(it < max_inner, jnp.any(iact))

        def ibody(istate):
            F, iact, it = istate
            Fn = beta * Yp + alpha * jnp.matmul(
                M, F, preferred_element_type=acc
            ).astype(F.dtype)
            Fn = jnp.where(iact[None, :], Fn, F)
            delta = jnp.max(jnp.abs(Fn - F), axis=0)
            return Fn, jnp.logical_and(iact, ~(delta < sigma)), it + 1

        F, _, inner_it = jax.lax.while_loop(
            icond, ibody, (F0, active, jnp.asarray(0, jnp.int32))
        )
        return F, inner_it

    def cond(state):
        _, active, it, _, _ = state
        return jnp.logical_and(it < max_iter, jnp.any(active))

    def body(state):
        F, active, it, tot_inner, col_iters = state
        src = Y if seed_mode == "fixed" else F
        Yp = beta * src + alpha * jnp.matmul(
            H, F, preferred_element_type=acc
        ).astype(F.dtype)
        Fn, inner_it = inner(Yp, F, active)
        Fn = jnp.where(active[None, :], Fn, F)
        delta = jnp.max(jnp.abs(Fn - F), axis=0)
        still = jnp.logical_and(active, ~(delta < sigma))
        col_iters = col_iters + active.astype(jnp.int32)
        return Fn, still, it + 1, tot_inner + inner_it, col_iters

    s = Y.shape[1]
    state0 = (
        F0,
        jnp.ones((s,), dtype=bool),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((s,), jnp.int32),
    )
    F, active, iters, tot_inner, col_iters = jax.lax.while_loop(
        cond, body, state0
    )
    return F, iters, tot_inner, col_iters


# --------------------------------------------------------------------------
# Public solver
# --------------------------------------------------------------------------
class HeteroLP:
    """The paper's contribution as a composable module.

    >>> solver = HeteroLP(LPConfig(alg="dhlp2", alpha=0.5, sigma=1e-3))
    >>> result = solver.run(net)          # all-sources propagation
    """

    def __init__(self, config: LPConfig = LPConfig()):
        self.config = config

    # -- assembly ----------------------------------------------------------
    @staticmethod
    def _prepare(net) -> NormalizedNetwork:
        return coerce_normalized(net)

    # -- main entry ---------------------------------------------------------
    def run(
        self,
        net,
        seeds: Optional[np.ndarray] = None,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        """Solve LP on ``net`` from ``seeds``.

        ``F0`` (same shape as ``seeds``) warm-starts the iteration from a
        previous solution — the fixed point is unchanged in fixed-seed mode
        and already-converged columns freeze in round 0 (DESIGN.md §9).
        """
        cfg = self.config
        norm = self._prepare(net)
        n = norm.num_nodes
        Y = seeds_identity(n) if seeds is None else np.asarray(seeds)
        if Y.ndim == 1:
            Y = Y[:, None]
        if Y.shape[0] != n:
            raise ValueError(f"seeds must have {n} rows, got {Y.shape}")
        if F0 is not None:
            F0 = np.asarray(F0)
            if F0.ndim == 1:
                F0 = F0[:, None]
            if F0.shape != Y.shape:
                raise ValueError(
                    f"F0 shape {F0.shape} must match seeds shape {Y.shape}"
                )

        if cfg.mode == "sequential":
            return self._run_sequential(norm, Y, F0)
        return self._run_batched(norm, Y, F0)

    # -- batched ------------------------------------------------------------
    def _run_batched(
        self,
        norm: NormalizedNetwork,
        Y: np.ndarray,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        cfg = self.config
        chunks = self._chunk_columns(Y, cfg.seed_chunk)
        f0_chunks = (
            [None] * len(chunks)
            if F0 is None
            else self._chunk_columns(F0, cfg.seed_chunk)
        )
        F_parts, outer, inner, col_iters = [], 0, 0, []
        arrays = self._device_arrays(norm)
        for Yc, F0c in zip(chunks, f0_chunks):
            Yd = jnp.asarray(Yc, dtype=cfg.dtype)
            F0d = Yd if F0c is None else jnp.asarray(F0c, dtype=cfg.dtype)
            if cfg.alg == "dhlp2":
                if cfg.fused:
                    A_eff, beta2 = arrays["fused"]
                    F, it, ci = _dhlp2_fused_loop(
                        A_eff,
                        beta2,
                        Yd,
                        F0d,
                        sigma=cfg.sigma,
                        max_iter=cfg.max_iter,
                        seed_mode=cfg.resolved_seed_mode(),
                        momentum=cfg.momentum,
                        use_kernel=cfg.use_kernel,
                    )
                else:
                    H, M = arrays["split"]
                    F, it, ci = _dhlp2_step_loop(
                        H,
                        M,
                        Yd,
                        F0d,
                        alpha=cfg.alpha,
                        sigma=cfg.sigma,
                        max_iter=cfg.max_iter,
                        seed_mode=cfg.resolved_seed_mode(),
                    )
                ii = 0
            else:
                H, M = arrays["split"]
                F, it, tot_inner, ci = _dhlp1_loop(
                    H,
                    M,
                    Yd,
                    F0d,
                    alpha=cfg.alpha,
                    sigma=cfg.sigma,
                    max_iter=cfg.max_iter,
                    max_inner=cfg.max_inner,
                    seed_mode=cfg.resolved_seed_mode(),
                )
                ii = int(tot_inner)
            F_parts.append(np.asarray(F, dtype=np.float64))
            outer = max(outer, int(it))
            inner += ii
            col_iters.append(np.asarray(ci))
        F = np.concatenate(F_parts, axis=1)
        col = np.concatenate(col_iters)
        return SolveResult(
            F=F,
            outer_iters=outer,
            inner_iters=inner,
            converged=bool(outer < cfg.max_iter),
            per_column_iters=col,
        )

    # -- sequential (paper-faithful per-seed sweep) --------------------------
    def _run_sequential(
        self,
        norm: NormalizedNetwork,
        Y: np.ndarray,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        """One seed at a time, exactly like the Giraph sweep.

        Kept as the faithful baseline; the batched mode is the beyond-paper
        optimization (DESIGN.md §2).  Runtime difference between the two is
        the repro analogue of the paper's distributed-vs-non-distributed
        Tables 5/6.
        """
        cfg = self.config
        arrays = self._device_arrays(norm)
        cols, outer, inner, per_col = [], 0, 0, []
        for c in range(Y.shape[1]):
            Yc = jnp.asarray(Y[:, c : c + 1], dtype=cfg.dtype)
            F0c = (
                Yc
                if F0 is None
                else jnp.asarray(F0[:, c : c + 1], dtype=cfg.dtype)
            )
            if cfg.alg == "dhlp2":
                H, M = arrays["split"]
                F, it, ci = _dhlp2_step_loop(
                    H,
                    M,
                    Yc,
                    F0c,
                    alpha=cfg.alpha,
                    sigma=cfg.sigma,
                    max_iter=cfg.max_iter,
                    seed_mode=cfg.resolved_seed_mode(),
                )
                ii = 0
            else:
                H, M = arrays["split"]
                F, it, tot_inner, ci = _dhlp1_loop(
                    H,
                    M,
                    Yc,
                    F0c,
                    alpha=cfg.alpha,
                    sigma=cfg.sigma,
                    max_iter=cfg.max_iter,
                    max_inner=cfg.max_inner,
                    seed_mode=cfg.resolved_seed_mode(),
                )
                ii = int(tot_inner)
            cols.append(np.asarray(F, dtype=np.float64))
            outer = max(outer, int(it))
            inner += ii
            per_col.append(int(ci[0]))
        return SolveResult(
            F=np.concatenate(cols, axis=1),
            outer_iters=outer,
            inner_iters=inner,
            converged=True,
            per_column_iters=np.asarray(per_col, np.int32),
        )

    # -- helpers -------------------------------------------------------------
    def operator_arrays(self, norm: NormalizedNetwork):
        """Device-resident dense operator arrays, cached per network.

        Public so the engine layer (``repro/engine/dense.py``) can reuse the
        prepared ``split``/``fused`` arrays for its ``round`` contract.
        """
        return self._device_arrays(norm)

    def _device_arrays(self, norm: NormalizedNetwork):
        cfg = self.config
        # key by identity of the live object (held in the cache entry, so
        # the address can't be recycled for a different network)
        cache = getattr(self, "_cache", None)
        if cache is not None and cache[0] is norm:
            return cache[1]
        H, M = norm.assemble_dense()
        H = H * cfg.resolved_hetero_scale(norm.num_types)
        out = {
            "split": (
                jnp.asarray(H, dtype=cfg.dtype),
                jnp.asarray(M, dtype=cfg.dtype),
            )
        }
        if cfg.alg == "dhlp2" and cfg.fused:
            beta = 1.0 - cfg.alpha
            A_eff = cfg.alpha * beta * H + cfg.alpha * M
            out["fused"] = (
                jnp.asarray(A_eff, dtype=cfg.dtype),
                jnp.asarray(beta * beta, dtype=jnp.float32),
            )
        self._cache = (norm, out)
        return out

    @staticmethod
    def _chunk_columns(Y: np.ndarray, chunk: int):
        return chunk_columns(Y, chunk)


def chunk_columns(Y: np.ndarray, chunk: int):
    """Split seed/state columns into ``chunk``-wide slices (0 = no split).

    Shared by every engine that honors ``LPConfig.seed_chunk`` — one copy
    of the boundary rule, not one per backend.
    """
    if chunk <= 0 or chunk >= Y.shape[1]:
        return [Y]
    return [Y[:, i : i + chunk] for i in range(0, Y.shape[1], chunk)]


def coerce_normalized(net) -> NormalizedNetwork:
    """Accept a raw or normalized network; the one coercion boundary.

    Shared by :class:`HeteroLP` and the engine registry
    (``repro/engine/base.py``) so the accepted-input rule cannot drift.
    """
    if isinstance(net, HeteroNetwork):
        return net.normalize()
    if isinstance(net, NormalizedNetwork):
        return net
    raise TypeError(f"unsupported network type {type(net)}")
