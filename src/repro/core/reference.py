"""Non-distributed baselines: MINProp [11] and Heter-LP [14].

The paper compares DHLP-1/DHLP-2 against these single-machine algorithms
(Tables 2, 5, 6).  We implement them as faithful per-seed numpy loops:

* MINProp (Hwang & Kuang, SDM 2010) — *sequential* (Gauss–Seidel) sweeps over
  subnetworks: subnetwork i's injection uses the freshest labels of the other
  subnetworks, then an inner iterative solve runs to convergence on i.
* Heter-LP (Shahreza et al., JBI 2017) — per-subnetwork projection+LP update
  applied cyclically with the drifting-seed update of DHLP-2's pseudocode.

Note the DHLP algorithms update all subnetworks *simultaneously* (Jacobi)
because every Giraph vertex runs the same program in a superstep, while the
originals are sequential (Gauss–Seidel).  Both orderings converge to the same
fixed point for fixed seeds (tests assert this); iteration counts differ.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.network import NormalizedNetwork


@dataclasses.dataclass
class RefResult:
    F: np.ndarray
    outer_iters: int
    inner_iters: int


def _hetero_sum(
    norm: NormalizedNetwork, f_blocks: List[np.ndarray], i: int
) -> np.ndarray:
    """Σ_{j≠i} S_ij f_j for one type block."""
    out = np.zeros_like(f_blocks[i])
    for (a, b), S in norm.S_het.items():
        if a == i:
            out += S @ f_blocks[b]
        elif b == i:
            out += S.T @ f_blocks[a]
    return out


def minprop_single_seed(
    norm: NormalizedNetwork,
    y: np.ndarray,
    alpha: float = 0.5,
    sigma: float = 1e-3,
    max_outer: int = 1000,
    max_inner: int = 200,
) -> RefResult:
    """MINProp for one seed vector y (N,) — Gauss–Seidel over subnetworks."""
    beta = 1.0 - alpha
    sl = norm.block_slices()
    y_blocks = [y[s].copy() for s in sl]
    f_blocks = [np.zeros_like(yb) for yb in y_blocks]
    total_inner = 0
    for outer in range(max_outer):
        f_prev = [fb.copy() for fb in f_blocks]
        for i in range(norm.num_types):
            y_prime = beta * y_blocks[i] + alpha * _hetero_sum(norm, f_blocks, i)
            # inner LP solve on subnetwork i (Zhou et al. local/global):
            f = f_blocks[i]
            for _ in range(max_inner):
                f_new = beta * y_prime + alpha * (norm.S_homo[i] @ f)
                total_inner += 1
                if np.max(np.abs(f_new - f)) < sigma:
                    f = f_new
                    break
                f = f_new
            f_blocks[i] = f
        delta = max(
            np.max(np.abs(f_blocks[i] - f_prev[i]))
            for i in range(norm.num_types)
        )
        if delta < sigma:
            return RefResult(np.concatenate(f_blocks), outer + 1, total_inner)
    return RefResult(np.concatenate(f_blocks), max_outer, total_inner)


def heterlp_single_seed(
    norm: NormalizedNetwork,
    y: np.ndarray,
    alpha: float = 0.5,
    sigma: float = 1e-3,
    max_iter: int = 1000,
    seed_mode: str = "drift",
) -> RefResult:
    """Heter-LP-style single-seed propagation (cyclic per-subnetwork)."""
    beta = 1.0 - alpha
    sl = norm.block_slices()
    y_blocks = [y[s].copy() for s in sl]
    f_blocks = [yb.copy() for yb in y_blocks]
    for it in range(max_iter):
        f_prev = [fb.copy() for fb in f_blocks]
        for i in range(norm.num_types):
            src = y_blocks[i] if seed_mode == "fixed" else f_blocks[i]
            y_prime = beta * src + alpha * _hetero_sum(norm, f_blocks, i)
            f_blocks[i] = beta * y_prime + alpha * (norm.S_homo[i] @ f_prev[i])
        delta = max(
            np.max(np.abs(f_blocks[i] - f_prev[i]))
            for i in range(norm.num_types)
        )
        if delta < sigma:
            return RefResult(np.concatenate(f_blocks), it + 1, 0)
    return RefResult(np.concatenate(f_blocks), max_iter, 0)


def run_all_seeds(
    norm: NormalizedNetwork,
    alg: str = "heterlp",
    alpha: float = 0.5,
    sigma: float = 1e-3,
    seeds: Optional[np.ndarray] = None,
    **kw,
) -> RefResult:
    """Sweep all (or given) seeds one at a time — the non-distributed runtime
    the paper's Tables 5/6 measure."""
    n = norm.num_nodes
    if seeds is None:
        seeds = np.eye(n)
    cols, outer, inner = [], 0, 0
    fn = minprop_single_seed if alg == "minprop" else heterlp_single_seed
    for c in range(seeds.shape[1]):
        r = fn(norm, seeds[:, c], alpha=alpha, sigma=sigma, **kw)
        cols.append(r.F[:, None])
        outer = max(outer, r.outer_iters)
        inner += r.inner_iters
    return RefResult(np.concatenate(cols, axis=1), outer, inner)
