"""Padded blocked-CSR — the shared sparse operator format (DESIGN.md §11).

The COO/segment-sum layout pays a scatter per superstep and carries one
``(src, dst, w)`` triple per edge.  Blocked-CSR instead groups rows (message
*destinations*) into fixed-size row blocks; each block stores its rows'
in-neighbors in a *fixed-width* rectangle whose width is the block's max
in-degree rounded up to ``width_mult`` slots:

    row_ptr[b]          slot offset of block b's storage
    widths[b]           slots per row inside block b  (multiple of width_mult)
    col_idx[s], val[s]  flat row-major neighbor ids / weights, zero-padded

Three consumers share this one format:

* the ``sparse`` engine (``repro/engine/sparse.py``) aggregates per
  width-bucket with a gather + einsum — no scatter, regular shapes;
* the ``sharded`` engine flattens it back to destination-sorted edge shards
  (``to_edges``) so every shard's segment-sum sees contiguous key runs;
* the Pallas ``csr_aggregate`` / ``csr_round`` kernels consume each bucket's
  ``(rows, width)`` rectangle directly as VMEM tiles.

Why blocks instead of one uniform rectangle (``graph/structures.PaddedCSR``):
on degree-skewed graphs a single ``max_deg``-wide table pads every leaf row
to the hub width; per-block widths keep the padding local to hub blocks
(``padding_ratio`` reports the win).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class WidthBucket:
    """All row blocks sharing one width, stacked into one rectangle.

    ``rows`` are the (true, un-padded) global row ids covered by the bucket;
    ``nbr``/``wgt`` are ``(len(rows), width)`` — the regular tile the dense
    gather path and the Pallas kernels consume.
    """

    width: int
    rows: np.ndarray  # (R,) int32 global row ids (padding rows dropped)
    nbr: np.ndarray  # (R, width) int32
    wgt: np.ndarray  # (R, width) float32


@dataclasses.dataclass
class BlockedCSR:
    """Padded blocked-CSR operator: ``out[r] = Σ_k val[r,k] · F[col_idx[r,k]]``.

    Rows are grouped into blocks of ``block_rows``; block ``b`` stores
    ``block_rows × widths[b]`` slots starting at ``row_ptr[b]``.  Slots past a
    row's true degree (and rows past ``num_rows`` in the last block) are
    zero-weight pads pointing at column 0 — no-ops under any aggregation.
    """

    col_idx: np.ndarray  # (total_slots,) int32
    val: np.ndarray  # (total_slots,) float32
    row_ptr: np.ndarray  # (num_blocks + 1,) int64 slot offsets
    widths: np.ndarray  # (num_blocks,) int32 slots per row
    block_rows: int
    num_rows: int
    num_cols: int

    # ------------------------------------------------------------ properties
    @property
    def num_blocks(self) -> int:
        return int(self.widths.shape[0])

    @property
    def total_slots(self) -> int:
        return int(self.row_ptr[-1])

    @property
    def nnz(self) -> int:
        return int(np.count_nonzero(self.val))

    @property
    def padding_ratio(self) -> float:
        """Fraction of stored slots that are pads (lower is better)."""
        slots = max(self.total_slots, 1)
        return 1.0 - self.nnz / slots

    @property
    def max_width(self) -> int:
        return int(self.widths.max(initial=0))

    # -------------------------------------------------------------- builders
    @classmethod
    def from_edges(
        cls,
        src: np.ndarray,
        dst: np.ndarray,
        w: np.ndarray,
        *,
        num_rows: int,
        num_cols: Optional[int] = None,
        block_rows: int = 64,
        width_mult: int = 8,
    ) -> "BlockedCSR":
        """Build from a COO triple (``dst`` receives from ``src``).

        Zero-weight edges are dropped (they are COO padding); duplicate
        ``(dst, src)`` entries keep separate slots (aggregation sums them,
        matching segment-sum semantics).
        """
        if block_rows < 1 or width_mult < 1:
            raise ValueError("block_rows and width_mult must be >= 1")
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)
        w = np.asarray(w, dtype=np.float32)
        keep = w != 0.0
        src, dst, w = src[keep], dst[keep], w[keep]
        order = np.argsort(dst, kind="stable")
        src, dst, w = src[order], dst[order], w[order]

        num_cols = num_rows if num_cols is None else int(num_cols)
        num_blocks = max(1, -(-num_rows // block_rows))
        deg = np.bincount(dst, minlength=num_rows).astype(np.int64)
        pad_rows = num_blocks * block_rows - num_rows
        deg_blocked = np.concatenate([deg, np.zeros(pad_rows, np.int64)])
        block_max = deg_blocked.reshape(num_blocks, block_rows).max(axis=1)
        widths = (
            np.maximum(
                width_mult,
                ((block_max + width_mult - 1) // width_mult) * width_mult,
            )
        ).astype(np.int32)

        row_ptr = np.zeros(num_blocks + 1, dtype=np.int64)
        np.cumsum(widths.astype(np.int64) * block_rows, out=row_ptr[1:])
        col_idx = np.zeros(int(row_ptr[-1]), dtype=np.int32)
        val = np.zeros(int(row_ptr[-1]), dtype=np.float32)

        # slot of edge e = block base + local row offset + rank within row
        starts = np.zeros(num_rows, dtype=np.int64)
        np.cumsum(deg[:-1], out=starts[1:])
        rank = np.arange(dst.shape[0], dtype=np.int64) - starts[dst]
        blk = dst // block_rows
        local = (dst % block_rows).astype(np.int64)
        slot = row_ptr[blk] + local * widths[blk] + rank
        col_idx[slot] = src
        val[slot] = w
        return cls(
            col_idx=col_idx,
            val=val,
            row_ptr=row_ptr,
            widths=widths,
            block_rows=block_rows,
            num_rows=int(num_rows),
            num_cols=num_cols,
        )

    @classmethod
    def from_dense(
        cls,
        A: np.ndarray,
        *,
        block_rows: int = 64,
        width_mult: int = 8,
    ) -> "BlockedCSR":
        dst, src = np.nonzero(A)
        return cls.from_edges(
            src.astype(np.int32),
            dst.astype(np.int32),
            A[dst, src].astype(np.float32),
            num_rows=A.shape[0],
            num_cols=A.shape[1],
            block_rows=block_rows,
            width_mult=width_mult,
        )

    # ----------------------------------------------------------------- views
    def block_view(self, b: int) -> Tuple[np.ndarray, np.ndarray]:
        """Block ``b`` as a ``(block_rows, widths[b])`` (nbr, wgt) rectangle."""
        lo, hi = int(self.row_ptr[b]), int(self.row_ptr[b + 1])
        shape = (self.block_rows, int(self.widths[b]))
        return (
            self.col_idx[lo:hi].reshape(shape),
            self.val[lo:hi].reshape(shape),
        )

    def width_buckets(self) -> List[WidthBucket]:
        """Group equal-width blocks into stacked rectangles.

        Buckets partition the true rows ``[0, num_rows)``: every row appears
        in exactly one bucket, padding rows of the last block are dropped.
        """
        by_width: Dict[int, List[int]] = {}
        for b, wd in enumerate(self.widths):
            by_width.setdefault(int(wd), []).append(b)
        out: List[WidthBucket] = []
        for wd in sorted(by_width):
            blocks = by_width[wd]
            rows_parts, nbr_parts, wgt_parts = [], [], []
            for b in blocks:
                r0 = b * self.block_rows
                r1 = min(r0 + self.block_rows, self.num_rows)
                if r1 <= r0:
                    continue
                nbr, wgt = self.block_view(b)
                rows_parts.append(np.arange(r0, r1, dtype=np.int32))
                nbr_parts.append(nbr[: r1 - r0])
                wgt_parts.append(wgt[: r1 - r0])
            if not rows_parts:
                continue
            out.append(
                WidthBucket(
                    width=wd,
                    rows=np.concatenate(rows_parts),
                    nbr=np.concatenate(nbr_parts, axis=0),
                    wgt=np.concatenate(wgt_parts, axis=0),
                )
            )
        return out

    def to_edges(
        self, include_pads: bool = True
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Flatten back to a destination-sorted COO triple.

        The sharded engine consumes this directly: slots are row-major, so
        ``dst`` is non-decreasing and equal-width shard slices see contiguous
        destination runs (balanced segment-sum output bands).  Pad slots keep
        weight 0 and clamp their row id into range — no-ops under psum, but
        pure overhead for an edge-list consumer, so ``include_pads=False``
        drops them (order-preserving; on hub-skewed graphs this shrinks the
        result several-fold).
        """
        dst = np.empty(self.total_slots, dtype=np.int32)
        for b in range(self.num_blocks):
            lo, hi = int(self.row_ptr[b]), int(self.row_ptr[b + 1])
            r0 = b * self.block_rows
            rows = np.arange(r0, r0 + self.block_rows, dtype=np.int64)
            rows = np.minimum(rows, self.num_rows - 1)
            dst[lo:hi] = np.repeat(rows, int(self.widths[b])).astype(np.int32)
        if not include_pads:
            keep = self.val != 0.0
            return self.col_idx[keep], dst[keep], self.val[keep]
        return self.col_idx.copy(), dst, self.val.copy()

    def to_dense(self) -> np.ndarray:
        A = np.zeros((self.num_rows, self.num_cols), dtype=np.float64)
        src, dst, w = self.to_edges()
        np.add.at(A, (dst, src), w.astype(np.float64))
        return A


def blocked_csr_from_network(
    norm,
    *,
    alpha: float,
    hetero_scale: float,
    block_rows: int = 64,
    width_mult: int = 8,
) -> BlockedCSR:
    """Fused DHLP-2 operator ``A_eff = αβ·scale·H + α·M`` in blocked-CSR.

    ``norm`` is a :class:`~repro.core.network.NormalizedNetwork`; the homo
    and hetero supports are disjoint so one blocked-CSR holds both.
    """
    coo = norm.to_coo()
    beta = 1.0 - alpha
    src = np.concatenate([coo.het_src, coo.hom_src])
    dst = np.concatenate([coo.het_dst, coo.hom_dst])
    w = np.concatenate(
        [alpha * beta * hetero_scale * coo.het_w, alpha * coo.hom_w]
    )
    return BlockedCSR.from_edges(
        src,
        dst,
        w,
        num_rows=norm.num_nodes,
        block_rows=block_rows,
        width_mult=width_mult,
    )


def split_blocked_csr_from_network(
    norm,
    *,
    hetero_scale: float,
    block_rows: int = 64,
    width_mult: int = 8,
) -> Tuple[BlockedCSR, BlockedCSR]:
    """(hetero, homo) blocked-CSR pair for DHLP-1's two-phase schedule.

    Weights are *unscaled* by α (the DHLP-1 loop applies α/β per phase);
    the hetero block does carry ``hetero_scale`` (a property of the
    operator, not of the schedule).
    """
    coo = norm.to_coo()
    het = BlockedCSR.from_edges(
        coo.het_src,
        coo.het_dst,
        hetero_scale * coo.het_w,
        num_rows=norm.num_nodes,
        block_rows=block_rows,
        width_mult=width_mult,
    )
    hom = BlockedCSR.from_edges(
        coo.hom_src,
        coo.hom_dst,
        coo.hom_w,
        num_rows=norm.num_nodes,
        block_rows=block_rows,
        width_mult=width_mult,
    )
    return het, hom


__all__ = [
    "BlockedCSR",
    "WidthBucket",
    "blocked_csr_from_network",
    "split_blocked_csr_from_network",
]
