"""Heterogeneous network container.

The paper's network (Fig. 1) has T node types (drug / disease / target in the
case study, T=3 but the container is generic), with

* ``P[i]``   — an ``(n_i, n_i)`` similarity (proximity) matrix per type, and
* ``R[(i,j)]`` — an ``(n_i, n_j)`` binary association matrix per type pair.

Node ids are globally flattened by concatenating types: type ``i`` occupies
rows ``[offset[i], offset[i] + n_i)``.  (The paper instead interleaves ids as
``3x + i`` so a Giraph vertex can recover its type with ``id % 3``; with
tensorized storage the block layout carries the same information and keeps
every block contiguous, which is what the MXU wants.)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.normalize import bipartite_normalize, symmetric_normalize

TypePair = Tuple[int, int]


def _as_f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


@dataclasses.dataclass
class HeteroNetwork:
    """A heterogeneous network: T homogeneous nets + inter-type associations.

    Attributes:
      P: list of per-type similarity matrices, ``P[i]: (n_i, n_i)``,
         nonnegative, assumed symmetric (symmetrized on construction).
      R: dict mapping ``(i, j)`` with ``i < j`` to the ``(n_i, n_j)``
         association matrix.
      type_names: optional human names per type (e.g. drug/disease/target).
    """

    P: List[np.ndarray]
    R: Dict[TypePair, np.ndarray]
    type_names: Optional[Sequence[str]] = None

    def __post_init__(self) -> None:
        self.P = [_as_f64(p) for p in self.P]
        canon: Dict[TypePair, np.ndarray] = {}
        for (i, j), r in self.R.items():
            r = _as_f64(r)
            if i == j:
                raise ValueError(f"R[{(i, j)}] must connect two distinct types")
            if i > j:  # canonicalize to i < j
                i, j, r = j, i, r.T
            if (i, j) in canon:
                raise ValueError(f"duplicate association block {(i, j)}")
            canon[(i, j)] = r
        self.R = canon
        for i, p in enumerate(self.P):
            if p.ndim != 2 or p.shape[0] != p.shape[1]:
                raise ValueError(f"P[{i}] must be square, got {p.shape}")
            # Similarity must be symmetric for the convergence proof; enforce.
            self.P[i] = (p + p.T) / 2.0
        for (i, j), r in self.R.items():
            want = (self.P[i].shape[0], self.P[j].shape[0])
            if r.shape != want:
                raise ValueError(f"R[{(i, j)}] shape {r.shape} != {want}")
        if self.type_names is not None and len(self.type_names) != self.num_types:
            raise ValueError("type_names length mismatch")

    # ---------------------------------------------------------------- sizes
    @property
    def num_types(self) -> int:
        return len(self.P)

    @property
    def sizes(self) -> List[int]:
        return [p.shape[0] for p in self.P]

    @property
    def num_nodes(self) -> int:
        return int(sum(self.sizes))

    @property
    def offsets(self) -> List[int]:
        out, acc = [], 0
        for n in self.sizes:
            out.append(acc)
            acc += n
        return out

    @property
    def num_edges(self) -> int:
        """Count of nonzero (undirected) entries, paper's |E| convention."""
        total = 0
        for p in self.P:
            total += int(np.count_nonzero(p))
        for r in self.R.values():
            total += 2 * int(np.count_nonzero(r))
        return total

    def type_of_node(self) -> np.ndarray:
        """Global-node-id -> type-id vector (the ``id % 3`` analogue)."""
        out = np.empty(self.num_nodes, dtype=np.int32)
        for i, (off, n) in enumerate(zip(self.offsets, self.sizes)):
            out[off : off + n] = i
        return out

    def block_slices(self) -> List[slice]:
        return [slice(off, off + n) for off, n in zip(self.offsets, self.sizes)]

    # -------------------------------------------------------------- storage
    def save_npz(self, path: str) -> str:
        """Write the network to one ``.npz`` (``NetworkSpec(kind='file')``).

        Layout: ``P_<t>`` per similarity block, ``R_<i>_<j>`` per
        association block, optional ``type_names``.  Returns the path
        actually written — numpy appends ``.npz`` when missing, and a
        return value that :meth:`load_npz` cannot open would be a trap.
        """
        arrays: Dict[str, np.ndarray] = {
            f"P_{t}": p for t, p in enumerate(self.P)
        }
        for (i, j), r in self.R.items():
            arrays[f"R_{i}_{j}"] = r
        if self.type_names is not None:
            arrays["type_names"] = np.asarray(list(self.type_names))
        np.savez_compressed(path, **arrays)
        return path if path.endswith(".npz") else path + ".npz"

    @classmethod
    def load_npz(cls, path: str) -> "HeteroNetwork":
        """Inverse of :meth:`save_npz`."""
        with np.load(path, allow_pickle=False) as data:
            p_keys = sorted(
                (k for k in data.files if k.startswith("P_")),
                key=lambda k: int(k.split("_")[1]),
            )
            if not p_keys:
                raise ValueError(f"{path}: no P_<t> similarity blocks found")
            P = [data[k] for k in p_keys]
            R = {}
            for k in data.files:
                if k.startswith("R_"):
                    _, i, j = k.split("_")
                    R[(int(i), int(j))] = data[k]
            names = (
                tuple(str(s) for s in data["type_names"])
                if "type_names" in data.files
                else None
            )
        return cls(P=P, R=R, type_names=names)

    # ----------------------------------------------------------- transforms
    def normalize(self) -> "NormalizedNetwork":
        """Paper §3.1: normalize all P_i and R_ij so LP converges."""
        S_homo = [symmetric_normalize(p) for p in self.P]
        S_het = {k: bipartite_normalize(r) for k, r in self.R.items()}
        return NormalizedNetwork(
            S_homo=S_homo,
            S_het=S_het,
            sizes=self.sizes,
            type_names=self.type_names,
        )

    def apply_delta(self, delta: "GraphDelta") -> "HeteroNetwork":
        """Return a new network with ``delta``'s edits applied.

        The serving layer (``repro/serve``) uses this as its incremental
        update path: apply, bump the network version, invalidate cached
        label columns whose types the delta touches, and warm-start the
        re-solve from the stale columns (DESIGN.md §9).
        """
        P = [p.copy() for p in self.P]
        R = {k: v.copy() for k, v in self.R.items()}

        # 1. grow blocks first so subsequent edge edits may target new nodes
        for t, count in sorted(delta.add_nodes.items()):
            if not 0 <= t < len(P):
                raise ValueError(f"add_nodes: no such type {t}")
            if count < 0:
                raise ValueError("add_nodes count must be >= 0")
            n_old = P[t].shape[0]
            grown = np.zeros((n_old + count, n_old + count), dtype=np.float64)
            grown[:n_old, :n_old] = P[t]
            P[t] = grown
            for (i, j) in list(R):
                r = R[(i, j)]
                if i == t:
                    R[(i, j)] = np.concatenate(
                        [r, np.zeros((count, r.shape[1]))], axis=0
                    )
                elif j == t:
                    R[(i, j)] = np.concatenate(
                        [r, np.zeros((r.shape[0], count))], axis=1
                    )

        # 2. similarity edits (kept symmetric; weight 0 removes the edge)
        for t, u, v, w in delta.sim:
            if not 0 <= t < len(P):
                raise ValueError(f"sim edit: no such type {t}")
            n = P[t].shape[0]
            if not (0 <= u < n and 0 <= v < n):
                raise ValueError(
                    f"sim edit ({u}, {v}) out of range for type {t} (n={n})"
                )
            P[t][u, v] = w
            P[t][v, u] = w

        # 3. association edits (weight 0 removes the edge)
        for pair, u, v, w in delta.assoc:
            i, j = min(pair), max(pair)
            if pair[0] > pair[1]:
                u, v = v, u
            if (i, j) not in R:
                if not (0 <= i < len(P) and 0 <= j < len(P)):
                    raise ValueError(f"assoc edit: no such pair {pair}")
                R[(i, j)] = np.zeros((P[i].shape[0], P[j].shape[0]))
            r = R[(i, j)]
            if not (0 <= u < r.shape[0] and 0 <= v < r.shape[1]):
                raise ValueError(
                    f"assoc edit ({u}, {v}) out of range for {r.shape}"
                )
            r[u, v] = w

        return HeteroNetwork(P=P, R=R, type_names=self.type_names)

    def with_masked_fold(
        self, pair: TypePair, mask: np.ndarray
    ) -> "HeteroNetwork":
        """Return a copy with the given association entries zeroed.

        Used by 10-fold CV (paper §6.2.1) and the deleted-interaction
        experiments (§6.2.2/§6.2.3): ``mask`` is a boolean array over
        ``R[pair]`` marking held-out entries.
        """
        i, j = min(pair), max(pair)
        R = {k: v.copy() for k, v in self.R.items()}
        R[(i, j)] = np.where(mask, 0.0, R[(i, j)])
        return HeteroNetwork(
            P=[p.copy() for p in self.P], R=R, type_names=self.type_names
        )


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """A batch of edits to a :class:`HeteroNetwork` (the online-update unit).

    Attributes:
      assoc: ``(pair, row, col, weight)`` association edits; ``row``/``col``
        are local indices within the pair's blocks and ``weight == 0``
        removes the edge.  Pairs are given in either orientation.
      sim: ``(type, u, v, weight)`` similarity edits (applied symmetrically).
      add_nodes: ``{type: count}`` — append ``count`` isolated nodes to the
        end of the type's block (no re-indexing of existing nodes).
    """

    assoc: Tuple[Tuple[TypePair, int, int, float], ...] = ()
    sim: Tuple[Tuple[int, int, int, float], ...] = ()
    add_nodes: Mapping[int, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        object.__setattr__(self, "assoc", tuple(tuple(e) for e in self.assoc))
        object.__setattr__(self, "sim", tuple(tuple(e) for e in self.sim))
        object.__setattr__(self, "add_nodes", dict(self.add_nodes))

    @property
    def is_empty(self) -> bool:
        return not (self.assoc or self.sim or self.add_nodes)

    def touched_types(self) -> frozenset:
        """Types whose nodes the delta edits (serving's invalidation set)."""
        out = set()
        for (i, j), _, _, _ in self.assoc:
            out.add(i)
            out.add(j)
        for t, _, _, _ in self.sim:
            out.add(t)
        out.update(self.add_nodes)
        return frozenset(out)


@dataclasses.dataclass
class NormalizedNetwork:
    """Normalized similarity blocks, ready for propagation."""

    S_homo: List[np.ndarray]
    S_het: Dict[TypePair, np.ndarray]
    sizes: List[int]
    type_names: Optional[Sequence[str]] = None

    @property
    def num_types(self) -> int:
        return len(self.S_homo)

    @property
    def num_nodes(self) -> int:
        return int(sum(self.sizes))

    @property
    def offsets(self) -> List[int]:
        out, acc = [], 0
        for n in self.sizes:
            out.append(acc)
            acc += n
        return out

    def block_slices(self) -> List[slice]:
        return [slice(off, off + n) for off, n in zip(self.offsets, self.sizes)]

    # ------------------------------------------------------- dense assembly
    def assemble_dense(self) -> Tuple[np.ndarray, np.ndarray]:
        """Assemble the (N, N) homogeneous operator M and heterogeneous H.

        ``M`` is block-diagonal (within-type propagation), ``H`` holds the
        off-diagonal association blocks (cross-type propagation).  Their
        supports are disjoint; together they are the full propagation
        operator of one BSP superstep.
        """
        n = self.num_nodes
        sl = self.block_slices()
        M = np.zeros((n, n), dtype=np.float64)
        H = np.zeros((n, n), dtype=np.float64)
        for i, s in enumerate(self.S_homo):
            M[sl[i], sl[i]] = s
        for (i, j), s in self.S_het.items():
            H[sl[i], sl[j]] = s
            H[sl[j], sl[i]] = s.T
        return H, M

    def assemble_effective(self, alpha: float) -> Tuple[np.ndarray, float]:
        """Beyond-paper fused operator for DHLP-2 (DESIGN.md §2).

        One DHLP-2 round ``F ← β(βF + αHF) + αMF`` equals
        ``F ← β²F + A_eff @ F`` with ``A_eff = αβH + αM`` (disjoint support).
        Returns ``(A_eff, β²)``.
        """
        beta = 1.0 - alpha
        H, M = self.assemble_dense()
        return alpha * beta * H + alpha * M, beta * beta

    # --------------------------------------------------------- COO assembly
    def to_coo(self) -> "HeteroCOO":
        H, M = self.assemble_dense()
        return HeteroCOO.from_dense(H, M, sizes=self.sizes)


@dataclasses.dataclass
class HeteroCOO:
    """COO edge-list view (the scalable/sparse engine's input).

    Homo and hetero edge sets are kept separate because DHLP mixes them with
    different coefficients.  Edges are stored destination-major so a
    segment-sum over ``dst`` is a contiguous reduce-by-key — the tensorized
    equivalent of Giraph delivering all messages addressed to a vertex in one
    superstep.
    """

    het_src: np.ndarray  # (E_h,) int32 — message source (column index)
    het_dst: np.ndarray  # (E_h,) int32 — message destination (row index)
    het_w: np.ndarray  # (E_h,) float — normalized weight
    hom_src: np.ndarray
    hom_dst: np.ndarray
    hom_w: np.ndarray
    num_nodes: int
    sizes: List[int]

    @classmethod
    def from_dense(
        cls, H: np.ndarray, M: np.ndarray, sizes: Sequence[int]
    ) -> "HeteroCOO":
        def _coo(a: np.ndarray):
            dst, src = np.nonzero(a)  # row=dst receives from col=src
            order = np.argsort(dst, kind="stable")
            dst, src = dst[order], src[order]
            return (
                src.astype(np.int32),
                dst.astype(np.int32),
                a[dst, src].astype(np.float64),
            )

        hs, hd, hw = _coo(H)
        ms, md, mw = _coo(M)
        return cls(
            het_src=hs,
            het_dst=hd,
            het_w=hw,
            hom_src=ms,
            hom_dst=md,
            hom_w=mw,
            num_nodes=int(H.shape[0]),
            sizes=list(sizes),
        )

    @property
    def num_edges(self) -> int:
        return int(self.het_src.shape[0] + self.hom_src.shape[0])

    def pad_to(self, het_mult: int = 1024, hom_mult: int = 1024) -> "HeteroCOO":
        """Pad edge arrays to a multiple so shapes are shard-friendly.

        Padding edges point at a zero-weight self-loop on node 0, which is a
        no-op under segment-sum (weight 0).
        """

        def _pad(src, dst, w, mult):
            e = src.shape[0]
            target = max(mult, ((e + mult - 1) // mult) * mult)
            pad = target - e
            if pad == 0:
                return src, dst, w
            return (
                np.concatenate([src, np.zeros(pad, np.int32)]),
                np.concatenate([dst, np.zeros(pad, np.int32)]),
                np.concatenate([w, np.zeros(pad, np.float64)]),
            )

        hs, hd, hw = _pad(self.het_src, self.het_dst, self.het_w, het_mult)
        ms, md, mw = _pad(self.hom_src, self.hom_dst, self.hom_w, hom_mult)
        return HeteroCOO(
            het_src=hs,
            het_dst=hd,
            het_w=hw,
            hom_src=ms,
            hom_dst=md,
            hom_w=mw,
            num_nodes=self.num_nodes,
            sizes=self.sizes,
        )


def seeds_identity(num_nodes: int) -> np.ndarray:
    """All-sources seed matrix: Y = I_N.

    The paper sweeps seeds one at a time (``y=1`` for a single vertex per
    sweep); the batched engines treat each seed as a column of Y.
    """
    return np.eye(num_nodes, dtype=np.float64)


def seeds_for_nodes(num_nodes: int, nodes: Sequence[int]) -> np.ndarray:
    y = np.zeros((num_nodes, len(nodes)), dtype=np.float64)
    for c, v in enumerate(nodes):
        y[v, c] = 1.0
    return y
