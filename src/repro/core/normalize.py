"""Matrix normalization (paper §3.1 / §5).

All propagation matrices must be normalized so the iteration is a
contraction-like map (spectral radius ≤ 1); this is the hypothesis of the
convergence proof inherited from MINProp [11] and Heter-LP [14].

* homogeneous similarity:  S = D^{-1/2} P D^{-1/2}
* bipartite association:   S = D_r^{-1/2} R D_c^{-1/2}

Zero-degree rows/columns (isolated entities — e.g. a "new drug" whose
interactions were all deleted in the §6.2.3 experiment) get a zero inverse
degree instead of inf, i.e. they emit/receive nothing through that block.
"""
from __future__ import annotations

import numpy as np


def _inv_sqrt(d: np.ndarray) -> np.ndarray:
    out = np.zeros_like(d, dtype=np.float64)
    nz = d > 0
    out[nz] = 1.0 / np.sqrt(d[nz])
    return out


def symmetric_normalize(P: np.ndarray) -> np.ndarray:
    """D^{-1/2} P D^{-1/2} with zero-degree guard."""
    P = np.asarray(P, dtype=np.float64)
    if P.ndim != 2 or P.shape[0] != P.shape[1]:
        raise ValueError(f"expected square matrix, got {P.shape}")
    d = P.sum(axis=1)
    inv = _inv_sqrt(d)
    return inv[:, None] * P * inv[None, :]


def bipartite_normalize(R: np.ndarray) -> np.ndarray:
    """D_r^{-1/2} R D_c^{-1/2} with zero-degree guard."""
    R = np.asarray(R, dtype=np.float64)
    if R.ndim != 2:
        raise ValueError(f"expected matrix, got {R.shape}")
    dr = R.sum(axis=1)
    dc = R.sum(axis=0)
    return _inv_sqrt(dr)[:, None] * R * _inv_sqrt(dc)[None, :]


def spectral_radius_upper_bound(S: np.ndarray) -> float:
    """Cheap upper bound via the max row sum (∞-norm)."""
    return float(np.abs(S).sum(axis=1).max(initial=0.0))
