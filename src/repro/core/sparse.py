"""Sparse (COO / segment-sum) LP engine — the legacy scalability path.

The dense engine materializes (N, N) operators; fine for the case-study
network, hopeless for the paper's 20M-edge scaling experiments and beyond.
This engine keeps the operator as edge lists and performs each superstep as
``gather → multiply → segment_sum`` — exactly Giraph's
send-messages / combine / update cycle, tensorized.

Superseded as the default sparse path by the blocked-CSR engine
(``repro/engine/sparse.py`` over ``core/blocked_csr.py``, DESIGN.md §11);
kept registered as backend ``sparse_coo`` so every bench pass A/Bs the
two layouts.  The distributed version (edge shards over a device mesh +
psum) lives in ``repro/parallel/lp_sharded.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.network import HeteroCOO, NormalizedNetwork
from repro.core.solver import LPConfig, SolveResult, chunk_columns
from repro.graph.segment import scatter_spmm


@dataclasses.dataclass
class COOOperator:
    """Device-resident fused LP operator in COO form.

    For DHLP-2 the homo and hetero edge sets collapse into one weighted set
    (weights pre-scaled by αβ·hetero_scale and α respectively); DHLP-1 needs
    them separate because the inner loop iterates only homogeneous edges.
    """

    het_src: jax.Array
    het_dst: jax.Array
    het_w: jax.Array
    hom_src: jax.Array
    hom_dst: jax.Array
    hom_w: jax.Array
    num_nodes: int

    @classmethod
    def from_network(
        cls, norm: NormalizedNetwork, cfg: LPConfig, pad_mult: int = 1024
    ) -> "COOOperator":
        coo = norm.to_coo().pad_to(pad_mult, pad_mult)
        scale = cfg.resolved_hetero_scale(norm.num_types)
        return cls(
            het_src=jnp.asarray(coo.het_src),
            het_dst=jnp.asarray(coo.het_dst),
            het_w=jnp.asarray(coo.het_w * scale, dtype=jnp.float32),
            hom_src=jnp.asarray(coo.hom_src),
            hom_dst=jnp.asarray(coo.hom_dst),
            hom_w=jnp.asarray(coo.hom_w, dtype=jnp.float32),
            num_nodes=coo.num_nodes,
        )

    def fused_arrays(self, alpha: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """concat(αβ·het, α·hom) — one segment-sum per DHLP-2 round."""
        memo = getattr(self, "_fused_memo", None)
        if memo is not None and memo[0] == alpha:
            return memo[1]
        beta = 1.0 - alpha
        src = jnp.concatenate([self.het_src, self.hom_src])
        dst = jnp.concatenate([self.het_dst, self.hom_dst])
        w = jnp.concatenate([alpha * beta * self.het_w, alpha * self.hom_w])
        self._fused_memo = (alpha, (src, dst, w))
        return src, dst, w


def make_dhlp2_coo(alpha: float):
    """Build a jit-able fused DHLP-2 COO loop closed over α."""
    beta2 = (1.0 - alpha) ** 2

    @functools.partial(
        jax.jit,
        static_argnames=("num_nodes", "sigma", "max_iter", "seed_mode"),
    )
    def loop(src, dst, w, Y, F0, *, num_nodes, sigma, max_iter, seed_mode):
        def cond(state):
            _, active, it, _ = state
            return jnp.logical_and(it < max_iter, jnp.any(active))

        def body(state):
            F, active, it, col_iters = state
            base = Y if seed_mode == "fixed" else F
            Fn = beta2 * base + scatter_spmm(src, dst, w, F, num_nodes)
            Fn = jnp.where(active[None, :], Fn, F)
            delta = jnp.max(jnp.abs(Fn - F), axis=0)
            still = jnp.logical_and(active, ~(delta < sigma))
            col_iters = col_iters + active.astype(jnp.int32)
            return Fn, still, it + 1, col_iters

        s = Y.shape[1]
        state0 = (
            F0,
            jnp.ones((s,), dtype=bool),
            jnp.asarray(0, jnp.int32),
            jnp.zeros((s,), jnp.int32),
        )
        F, _, iters, col_iters = jax.lax.while_loop(cond, body, state0)
        return F, iters, col_iters

    return loop


def make_dhlp1_coo(alpha: float):
    """DHLP-1 COO loops: outer hetero injection + inner homo solve."""
    beta = 1.0 - alpha

    @functools.partial(
        jax.jit,
        static_argnames=(
            "num_nodes",
            "sigma",
            "max_iter",
            "max_inner",
            "seed_mode",
        ),
    )
    def loop(
        het_src,
        het_dst,
        het_w,
        hom_src,
        hom_dst,
        hom_w,
        Y,
        F0,
        *,
        num_nodes,
        sigma,
        max_iter,
        max_inner,
        seed_mode,
    ):
        def inner(Yp, F0, active):
            def icond(istate):
                _, iact, it = istate
                return jnp.logical_and(it < max_inner, jnp.any(iact))

            def ibody(istate):
                F, iact, it = istate
                Fn = beta * Yp + alpha * scatter_spmm(
                    hom_src, hom_dst, hom_w, F, num_nodes
                )
                Fn = jnp.where(iact[None, :], Fn, F)
                delta = jnp.max(jnp.abs(Fn - F), axis=0)
                return Fn, jnp.logical_and(iact, ~(delta < sigma)), it + 1

            F, _, inner_it = jax.lax.while_loop(
                icond, ibody, (F0, active, jnp.asarray(0, jnp.int32))
            )
            return F, inner_it

        def cond(state):
            _, active, it, _, _ = state
            return jnp.logical_and(it < max_iter, jnp.any(active))

        def body(state):
            F, active, it, tot_inner, col_iters = state
            src_lbl = Y if seed_mode == "fixed" else F
            Yp = beta * src_lbl + alpha * scatter_spmm(
                het_src, het_dst, het_w, F, num_nodes
            )
            Fn, inner_it = inner(Yp, F, active)
            Fn = jnp.where(active[None, :], Fn, F)
            delta = jnp.max(jnp.abs(Fn - F), axis=0)
            still = jnp.logical_and(active, ~(delta < sigma))
            col_iters = col_iters + active.astype(jnp.int32)
            return Fn, still, it + 1, tot_inner + inner_it, col_iters

        s = Y.shape[1]
        state0 = (
            F0,
            jnp.ones((s,), dtype=bool),
            jnp.asarray(0, jnp.int32),
            jnp.asarray(0, jnp.int32),
            jnp.zeros((s,), jnp.int32),
        )
        F, _, iters, tot_inner, col_iters = jax.lax.while_loop(
            cond, body, state0
        )
        return F, iters, tot_inner, col_iters

    return loop


class SparseHeteroLP:
    """COO/segment-sum engine with the same interface as ``HeteroLP``."""

    def __init__(self, config: LPConfig = LPConfig()):
        self.config = config
        self._op_cache = None

    def _operator(self, norm: NormalizedNetwork, pad_mult: int) -> COOOperator:
        """Device-resident operator, cached per (network, padding).

        The serving path re-solves against the same normalized network many
        times per version; rebuilding (and re-uploading) the edge arrays per
        query batch would dominate small solves.  The cache entry holds the
        norm object itself and compares by identity — an `id()` key could
        silently match a new network allocated at a recycled address.
        """
        cache = self._op_cache
        if cache is not None and cache[0] is norm and cache[1] == pad_mult:
            return cache[2]
        op = COOOperator.from_network(norm, self.config, pad_mult)
        self._op_cache = (norm, pad_mult, op)
        return op

    def run(
        self,
        norm: NormalizedNetwork,
        seeds: Optional[np.ndarray] = None,
        pad_mult: int = 1024,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        cfg = self.config
        op = self._operator(norm, pad_mult)
        n = op.num_nodes
        Y = np.eye(n, dtype=np.float32) if seeds is None else np.asarray(seeds)
        if Y.ndim == 1:
            Y = Y[:, None]
        if F0 is not None:
            F0 = np.asarray(F0)
            if F0.ndim == 1:
                F0 = F0[:, None]
            if F0.shape != Y.shape:
                raise ValueError(
                    f"F0 shape {F0.shape} must match seeds shape {Y.shape}"
                )

        chunks = chunk_columns(Y, cfg.seed_chunk)
        f0_chunks = (
            [None] * len(chunks)
            if F0 is None
            else chunk_columns(F0, cfg.seed_chunk)
        )
        # hetero weights in `op` are already scaled by hetero_scale.
        parts, outer, inner_tot, cols = [], 0, 0, []
        if cfg.alg == "dhlp2":
            loop = make_dhlp2_coo(cfg.alpha)
            fsrc, fdst, fw = op.fused_arrays(cfg.alpha)
            for Yc, F0c in zip(chunks, f0_chunks):
                Yd = jnp.asarray(Yc, jnp.float32)
                F0d = Yd if F0c is None else jnp.asarray(F0c, jnp.float32)
                F, it, ci = loop(
                    fsrc,
                    fdst,
                    fw,
                    Yd,
                    F0d,
                    num_nodes=n,
                    sigma=cfg.sigma,
                    max_iter=cfg.max_iter,
                    seed_mode=cfg.resolved_seed_mode(),
                )
                parts.append(np.asarray(F, np.float64))
                outer = max(outer, int(it))
                cols.append(np.asarray(ci))
        else:
            loop = make_dhlp1_coo(cfg.alpha)
            for Yc, F0c in zip(chunks, f0_chunks):
                Yd = jnp.asarray(Yc, jnp.float32)
                F0d = Yd if F0c is None else jnp.asarray(F0c, jnp.float32)
                F, it, ti, ci = loop(
                    op.het_src,
                    op.het_dst,
                    op.het_w,
                    op.hom_src,
                    op.hom_dst,
                    op.hom_w,
                    Yd,
                    F0d,
                    num_nodes=n,
                    sigma=cfg.sigma,
                    max_iter=cfg.max_iter,
                    max_inner=cfg.max_inner,
                    seed_mode=cfg.resolved_seed_mode(),
                )
                parts.append(np.asarray(F, np.float64))
                outer = max(outer, int(it))
                inner_tot += int(ti)
                cols.append(np.asarray(ci))
        return SolveResult(
            F=np.concatenate(parts, axis=1),
            outer_iters=outer,
            inner_iters=inner_tot,
            converged=bool(outer < cfg.max_iter),
            per_column_iters=np.concatenate(cols),
        )
