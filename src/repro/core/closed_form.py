"""Exact fixed points of the DHLP iterations (test oracles).

With fixed seeds (``seed_mode="fixed"``) both algorithms converge to the
same linear-system solution:

  DHLP-1 outer fixed point with the inner solve run to convergence:
      F = β(βY + αHF) + αMF
  DHLP-2 fixed-seed fixed point (same algebra):
      F = β(βY + αHF) + αMF

  =>  (I − αβH − αM) F* = β² Y

This is the regularization-framework optimum the paper's §5 proof refers to
(equivalent to MINProp's global optimum for the stacked system).  The matrix
``I − αβH − αM`` is strictly diagonally dominant for α ∈ (0,1) given the
normalization bounds, hence invertible.
"""
from __future__ import annotations

import numpy as np


def fixed_seed_solution(
    H: np.ndarray, M: np.ndarray, Y: np.ndarray, alpha: float
) -> np.ndarray:
    beta = 1.0 - alpha
    n = H.shape[0]
    A = np.eye(n) - alpha * beta * H - alpha * M
    return np.linalg.solve(A, beta * beta * Y)


def dhlp1_inner_solution(
    M_i: np.ndarray, y_prime: np.ndarray, alpha: float
) -> np.ndarray:
    """Closed form of DHLP-1's inner loop: f = (1-α)(I − αS_i)^{-1} y'."""
    beta = 1.0 - alpha
    n = M_i.shape[0]
    return beta * np.linalg.solve(np.eye(n) - alpha * M_i, y_prime)
