"""Autotune cache for blocked-CSR layout and kernel panel parameters.

`block_rows`/`width_mult` (layout) and `bn`/`bs`/`bd` (Pallas panel sizes)
ran hard-coded CPU defaults everywhere before this module.  The right
values depend on the host (cache sizes, core count, interpret-vs-Mosaic)
and on the operator's *shape class* — node count and mean degree decide
whether wide hub rectangles or many narrow buckets win.  Sweeping them
per solve would dwarf the solve; hard-coding them leaves throughput on
the table on every other host.

So: sweep once per (host fingerprint, shape class), persist the winner
under ``results/autotune/<host>.json``, and answer every later query
from a process-level memo — ``lookup`` is a dict probe, zero per-call
overhead.  A cold miss returns ``None`` and callers fall back to
:data:`DEFAULT_PARAMS` (today's defaults), so nothing ever blocks on a
sweep implicitly; only :func:`ensure_tuned` (called by the bench suite
and by users who opt in) pays the sweep cost.  ``LPConfig.autotune=False``
opts a solve out of consulting the cache entirely.

Shape classes bucket (num_nodes, nnz) by rounded log2 so one sweep covers
the whole neighborhood of sizes the serving tier replays — exact keying
would re-sweep on every scenario scale tweak.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import platform
import time
from pathlib import Path
from typing import Dict, Optional, Tuple

import numpy as np

DEFAULT_CACHE_DIR = Path("results") / "autotune"

# Candidate grid: layout first (dominates), panels on the winning layout.
LAYOUT_GRID: Tuple[Tuple[int, int], ...] = tuple(
    (br, wm) for br in (32, 64, 128) for wm in (4, 8, 16)
)
PANEL_GRID: Tuple[Tuple[int, int, int], ...] = (
    (128, 128, 8),
    (256, 128, 16),
    (256, 128, 32),
    (512, 128, 16),
)


@dataclasses.dataclass(frozen=True)
class TunedParams:
    """One winning parameter set for a (host, shape class) cell."""

    block_rows: int = 64
    width_mult: int = 8
    bn: int = 256  # kernel row-panel
    bs: int = 128  # kernel label-column panel
    bd: int = 16  # kernel degree-slab

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, int]) -> "TunedParams":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: int(v) for k, v in d.items() if k in fields})


DEFAULT_PARAMS = TunedParams()

# process-level memo: resolved cache file path -> {shape_class: TunedParams}
_MEMO: Dict[str, Dict[str, TunedParams]] = {}


def host_fingerprint() -> str:
    """Stable id of the machine class the timings were taken on.

    Deliberately coarse — machine arch + core count + jax backend/version —
    so re-created containers of the same class share one cache file.
    """
    import jax

    parts = (
        platform.machine(),
        platform.system().lower(),
        f"cpu{os.cpu_count() or 1}",
        jax.default_backend(),
        f"jax{jax.__version__}",
    )
    return "-".join(parts).replace(" ", "_")


def shape_class(num_nodes: int, nnz: int) -> str:
    """Bucket an operator by rounded log2(nodes) and log2(mean degree)."""
    n = max(int(num_nodes), 2)
    d = max(float(nnz) / n, 1.0)
    return f"n{round(math.log2(n))}_d{round(math.log2(d))}"


def network_nnz(norm) -> int:
    """Cheap nnz estimate off the normalized blocks (no COO assembly)."""
    nnz = sum(int(np.count_nonzero(s)) for s in norm.S_homo)
    nnz += 2 * sum(int(np.count_nonzero(s)) for s in norm.S_het.values())
    return nnz


def cache_path(cache_dir: Optional[os.PathLike] = None) -> Path:
    base = Path(cache_dir) if cache_dir is not None else DEFAULT_CACHE_DIR
    return base / f"{host_fingerprint()}.json"


def _load(cache_dir: Optional[os.PathLike] = None) -> Dict[str, TunedParams]:
    path = cache_path(cache_dir)
    key = str(path.resolve())
    if key in _MEMO:
        return _MEMO[key]
    entries: Dict[str, TunedParams] = {}
    if path.exists():
        try:
            raw = json.loads(path.read_text())
            for sc, d in raw.get("entries", {}).items():
                entries[sc] = TunedParams.from_dict(d)
        except (json.JSONDecodeError, TypeError, ValueError):
            entries = {}  # corrupt cache == cold cache
    _MEMO[key] = entries
    return entries


def clear_memo() -> None:
    """Drop the process memo (tests re-point cache_dir mid-process)."""
    _MEMO.clear()


def lookup(
    num_nodes: int,
    nnz: int,
    *,
    cache_dir: Optional[os.PathLike] = None,
) -> Optional[TunedParams]:
    """Cached winner for this host + shape class, or None on a cold miss."""
    return _load(cache_dir).get(shape_class(num_nodes, nnz))


def save(
    num_nodes: int,
    nnz: int,
    params: TunedParams,
    *,
    cache_dir: Optional[os.PathLike] = None,
) -> Path:
    """Persist a winner and refresh the memo (atomic file replace)."""
    path = cache_path(cache_dir)
    path.parent.mkdir(parents=True, exist_ok=True)
    entries = dict(_load(cache_dir))
    entries[shape_class(num_nodes, nnz)] = params
    doc = {
        "host": host_fingerprint(),
        "entries": {sc: p.to_dict() for sc, p in sorted(entries.items())},
    }
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    os.replace(tmp, path)
    _MEMO[str(path.resolve())] = entries
    return path


# --------------------------------------------------------------- the sweep


def _time_layout(norm, *, alpha, hetero_scale, block_rows, width_mult, s, repeats):
    """Seconds per einsum round at one (block_rows, width_mult) layout."""
    import jax
    import jax.numpy as jnp

    from repro.core.blocked_csr import blocked_csr_from_network

    bcsr = blocked_csr_from_network(
        norm,
        alpha=alpha,
        hetero_scale=hetero_scale,
        block_rows=block_rows,
        width_mult=width_mult,
    )
    buckets = tuple(
        (jnp.asarray(b.nbr), jnp.asarray(b.wgt, jnp.float32))
        for b in bcsr.width_buckets()
    )
    order = np.concatenate([b.rows for b in bcsr.width_buckets()])
    inv = jnp.asarray(np.argsort(order).astype(np.int32))

    @jax.jit
    def _round(bk, iv, F):
        parts = [
            jnp.einsum("rw,rws->rs", w, F[nbr].astype(jnp.float32))
            for nbr, w in bk
        ]
        return jnp.concatenate(parts, axis=0)[iv]

    F = jnp.asarray(
        np.random.default_rng(0).random((norm.num_nodes, s)), jnp.float32
    )
    _round(buckets, inv, F).block_until_ready()  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _round(buckets, inv, F).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best, bcsr


def _time_panels(bcsr, *, bn, bs, bd, s, repeats):
    """Seconds per fused-kernel round at one (bn, bs, bd) panel choice."""
    import jax.numpy as jnp

    from repro.kernels.segment_reduce import csr_round_residual_op

    buckets = [
        (jnp.asarray(b.nbr), jnp.asarray(b.wgt, jnp.float32))
        for b in bcsr.width_buckets()
    ]
    n = bcsr.num_rows
    rng = np.random.default_rng(0)
    F = jnp.asarray(rng.random((n, s)), jnp.float32)

    def _round():
        outs = []
        off = 0
        for nbr, wgt in buckets:
            m = nbr.shape[0]
            sl = F[off : off + m]
            out, _ = csr_round_residual_op(
                nbr, wgt, F, sl, sl, c=0.25, bn=bn, bs=bs, bd=bd, use_kernel=True
            )
            outs.append(out)
            off += m
        return [o.block_until_ready() for o in outs]

    _round()  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _round()
        best = min(best, time.perf_counter() - t0)
    return best


def ensure_tuned(
    norm,
    *,
    alpha: float = 0.5,
    hetero_scale: float = 1.0,
    s: int = 8,
    repeats: int = 2,
    cache_dir: Optional[os.PathLike] = None,
    force: bool = False,
    sweep_panels: bool = True,
) -> Tuple[TunedParams, bool]:
    """Return ``(params, cache_hit)`` for this host + operator shape.

    On a hit nothing is timed.  On a miss (or ``force=True``) sweeps the
    layout grid with the einsum round, then — for operators small enough
    for the VMEM-resident kernel — the panel grid with the fused-superstep
    kernel on the winning layout, and persists the combined winner.
    """
    nnz = network_nnz(norm)
    n = norm.num_nodes
    if not force:
        hit = lookup(n, nnz, cache_dir=cache_dir)
        if hit is not None:
            return hit, True

    best_t, best_layout, best_bcsr = float("inf"), LAYOUT_GRID[0], None
    for block_rows, width_mult in LAYOUT_GRID:
        t, bcsr = _time_layout(
            norm,
            alpha=alpha,
            hetero_scale=hetero_scale,
            block_rows=block_rows,
            width_mult=width_mult,
            s=s,
            repeats=repeats,
        )
        if t < best_t:
            best_t, best_layout, best_bcsr = t, (block_rows, width_mult), bcsr

    bn, bs, bd = DEFAULT_PARAMS.bn, DEFAULT_PARAMS.bs, DEFAULT_PARAMS.bd
    from repro.kernels.segment_reduce.ops import _MAX_RESIDENT_NODES

    if sweep_panels and n <= _MAX_RESIDENT_NODES:
        best_pt = float("inf")
        for cand in PANEL_GRID:
            t = _time_panels(
                best_bcsr, bn=cand[0], bs=cand[1], bd=cand[2], s=s,
                repeats=repeats,
            )
            if t < best_pt:
                best_pt, (bn, bs, bd) = t, cand

    params = TunedParams(
        block_rows=best_layout[0],
        width_mult=best_layout[1],
        bn=bn,
        bs=bs,
        bd=bd,
    )
    save(n, nnz, params, cache_dir=cache_dir)
    return params, False


__all__ = [
    "DEFAULT_PARAMS",
    "LAYOUT_GRID",
    "PANEL_GRID",
    "TunedParams",
    "cache_path",
    "clear_memo",
    "ensure_tuned",
    "host_fingerprint",
    "lookup",
    "network_nnz",
    "save",
    "shape_class",
]
