"""Sharded backend: the shard_map distributed engine behind the registry.

The operator shards are flat slices of the blocked-CSR slot storage
(``BlockedCSR.to_edges``) — the same format the sparse/kernel engines
aggregate, reshaped for the edge axis (DESIGN.md §6/§11).  The mesh is a
deployment knob: pass ``devices=`` (edge-axis size, seed axis 1) or a
ready ``mesh=``; ``auto`` never selects this backend.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.network import NormalizedNetwork
from repro.core.solver import LPConfig, SolveResult
from repro.engine.base import LPEngine, Operator, register_backend


@register_backend("sharded")
class ShardedEngine(LPEngine):
    def __init__(
        self,
        config: Optional[LPConfig] = None,
        *,
        devices: Optional[int] = None,
        mesh=None,
        edge_axis: str = "model",
        seed_axis: str = "data",
        stale_sync: int = 1,
        compression: str = "none",
    ):
        super().__init__(config if config is not None else LPConfig())
        from repro.parallel.lp_sharded import ShardedHeteroLP

        self.devices = devices
        self.edge_axis = edge_axis
        self.seed_axis = seed_axis
        self._mesh = mesh
        self._solver = ShardedHeteroLP(
            self.config, stale_sync=stale_sync, compression=compression
        )

    def mesh(self):
        if self._mesh is None:
            import jax

            from repro.parallel.hints import make_mesh_compat

            k = self.devices or jax.device_count()
            if k > jax.device_count():
                raise ValueError(
                    f"sharded backend needs {k} devices, host has "
                    f"{jax.device_count()}"
                )
            self._mesh = make_mesh_compat((1, k), (self.seed_axis, self.edge_axis))
        return self._mesh

    def _build(self, norm: NormalizedNetwork) -> Operator:
        prep = self._solver.prepare(
            norm,
            self.mesh(),
            edge_axis=self.edge_axis,
            seed_axis=self.seed_axis,
        )
        return Operator(
            backend=self.name,
            norm=norm,
            num_nodes=norm.num_nodes,
            payload=prep,
        )

    def solve(
        self,
        op: Operator,
        Y: np.ndarray,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        return self._solver.solve_prepared(op.payload, Y, F0=F0)

    def _round_fn(self, op: Operator):
        """Compiled one-round kernel + fused shards, cached per operator.

        DHLP-2 operators reuse the solver's fused edge shards; DHLP-1
        operators (split hetero/homo shards) build the fused triple on
        first use — ``round`` is the fused DHLP-2 update for every
        backend (DESIGN.md §11.1), independent of the solve schedule.
        """
        cache = getattr(self, "_round_cache", None)
        if cache is not None and cache[0] is op:
            return cache[1], cache[2]
        import jax.numpy as jnp

        from repro.parallel.lp_sharded import (
            build_sharded_round,
            prepare_sharded_operator,
        )

        cfg = self.config
        mesh = self.mesh()
        beta = 1.0 - cfg.alpha
        prep = op.payload
        if prep.alg == "dhlp2":
            arrays = prep.arrays
        else:
            arrs = prepare_sharded_operator(
                op.norm, cfg, mesh.shape[self.edge_axis]
            )
            arrays = (
                jnp.asarray(arrs.src),
                jnp.asarray(arrs.dst),
                jnp.asarray(arrs.w),
            )
        fn = build_sharded_round(
            mesh,
            num_nodes=op.num_nodes,
            beta2=beta * beta,
            edge_axis=self.edge_axis,
            seed_axis=self.seed_axis,
            compression=self._solver.compression,
        )
        self._round_cache = (op, fn, arrays)
        return fn, arrays

    def round(self, op: Operator, F, Y):
        import jax.numpy as jnp

        fn, arrays = self._round_fn(op)
        k_seeds = self.mesh().shape[self.seed_axis]
        F = np.asarray(F, np.float32)
        Y = np.asarray(Y, np.float32)
        if F.ndim == 1:
            F = F[:, None]
        if Y.ndim == 1:
            Y = Y[:, None]
        s = F.shape[1]
        pad = (-s) % k_seeds
        if pad:
            z = np.zeros((F.shape[0], pad), np.float32)
            F = np.concatenate([F, z], axis=1)
            Y = np.concatenate([Y, z], axis=1)
        out = fn(*arrays, jnp.asarray(F), jnp.asarray(Y))
        return np.asarray(out, np.float64)[:, :s]
