"""LPEngine protocol + string-keyed backend registry (DESIGN.md §11).

Every execution path the repo has (dense XLA, blocked-CSR sparse, COO
segment-sum, shard_map distributed, Pallas kernel) implements the same
three-method contract:

* ``prepare(net) -> Operator`` — assemble + upload the propagation operator
  once per network (identity-cached, like the solvers' internal caches);
* ``solve(op, Y, F0=None) -> SolveResult`` — batched σ-convergence solve
  with optional warm start (the F0 threading serving relies on);
* ``round(op, F, Y) -> F`` — ONE fused fixed-seed DHLP-2 round, the unit
  serve's incremental refresh steps stale columns with.

Backends register under a string key; callers go through
:func:`make_engine` so backend choice is one ``LPConfig.backend`` value
(``"auto"`` resolves via :func:`select_backend`).
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Any, ClassVar, Dict, Optional, Tuple, Type

import numpy as np

from repro.core.network import NormalizedNetwork, seeds_identity
from repro.core.solver import LPConfig, SolveResult, coerce_normalized

# `auto` picks dense while the (N, N) fused operator stays comfortably
# in device memory (4096² f32 = 64 MB) AND the network is dense enough
# that gather/reduce bookkeeping would not pay for itself.
AUTO_DENSE_MAX_NODES = 4096


class UnknownBackendError(ValueError):
    """Requested backend key is not in the registry."""


class BackendUnsupported(ValueError):
    """Backend exists but cannot run the requested configuration."""


@dataclasses.dataclass
class Operator:
    """A prepared, device-resident propagation operator.

    ``payload`` is backend-specific (dense arrays, CSR buckets, edge
    shards); callers treat operators as opaque handles returned by
    ``prepare`` and passed to ``solve``/``round``.
    """

    backend: str
    norm: NormalizedNetwork
    num_nodes: int
    payload: Any = None


class LPEngine(abc.ABC):
    """Base class for LP execution backends."""

    name: ClassVar[str] = "?"
    #: algorithms this backend can execute
    supports_algs: ClassVar[Tuple[str, ...]] = ("dhlp1", "dhlp2")
    #: whether the fused loop honors LPConfig.momentum (heavy-ball)
    supports_momentum: ClassVar[bool] = False

    def __init__(self, config: LPConfig = LPConfig()):
        self.config = config
        # (norm, Operator): identity-keyed like the solvers' caches — the
        # entry holds the norm object itself so a recycled id() cannot
        # alias a different network.
        self._op_cache: Optional[Tuple[NormalizedNetwork, Operator]] = None

    # ------------------------------------------------------------- contract
    def prepare(self, net) -> Operator:
        """Assemble the operator for ``net`` (cached per network identity).

        The cache key is the object the caller handed in — a raw
        ``HeteroNetwork`` hits the cache without re-normalizing, and the
        derived ``NormalizedNetwork`` is accepted as an alias so callers
        holding either handle share one prepared operator.
        """
        cache = self._op_cache
        if cache is not None and (cache[0] is net or cache[1].norm is net):
            return cache[1]
        if self.config.alg not in self.supports_algs:
            raise BackendUnsupported(
                f"backend {self.name!r} does not support alg "
                f"{self.config.alg!r} (supports {self.supports_algs})"
            )
        if self.config.momentum and not self.supports_momentum:
            # running unaccelerated would silently drop a configured
            # convergence knob — fail loudly like any other capability gap
            raise BackendUnsupported(
                f"backend {self.name!r} has no momentum loop "
                f"(LPConfig.momentum={self.config.momentum})"
            )
        norm = coerce_normalized(net)
        op = self._build(norm)
        self._op_cache = (net, op)
        return op

    @abc.abstractmethod
    def _build(self, norm: NormalizedNetwork) -> Operator:
        """Backend-specific operator assembly."""

    @abc.abstractmethod
    def solve(
        self,
        op: Operator,
        Y: np.ndarray,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        """Batched solve from seed columns ``Y``, warm-started at ``F0``."""

    def round(self, op: Operator, F, Y):
        """One fused fixed-seed DHLP-2 round ``β²Y + A_eff @ F``."""
        raise NotImplementedError(f"backend {self.name!r} has no incremental round")

    def round_with_residual(self, op: Operator, F, Y):
        """One round plus its per-column residual ``max_r |Fn − F|``.

        Convergence-driven callers (serve's early-exit loop) consume this
        instead of ``round`` + a host-side reduction so fused backends can
        emit the residual from the same kernel launch.  Default: compose
        from ``round``.
        """
        Fn = self.round(op, F, Y)
        delta = np.max(
            np.abs(np.asarray(Fn) - np.asarray(F, dtype=np.float64)), axis=0
        )
        return Fn, delta

    # ---------------------------------------------------------- convenience
    def run(
        self,
        net,
        seeds: Optional[np.ndarray] = None,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        """``prepare`` + ``solve`` with the shared seed/F0 validation."""
        op = self.prepare(net)
        n = op.num_nodes
        Y = seeds_identity(n) if seeds is None else np.asarray(seeds)
        if Y.ndim == 1:
            Y = Y[:, None]
        if Y.shape[0] != n:
            raise ValueError(f"seeds must have {n} rows, got {Y.shape}")
        if F0 is not None:
            F0 = np.asarray(F0)
            if F0.ndim == 1:
                F0 = F0[:, None]
            if F0.shape != Y.shape:
                raise ValueError(
                    f"F0 shape {F0.shape} must match seeds shape {Y.shape}"
                )
        return self.solve(op, Y, F0=F0)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
_REGISTRY: Dict[str, Type[LPEngine]] = {}


def register_backend(name: str):
    """Class decorator: ``@register_backend("sparse")`` on an LPEngine."""

    def deco(cls: Type[LPEngine]) -> Type[LPEngine]:
        existing = _REGISTRY.get(name)
        if existing is not None and existing is not cls:
            raise ValueError(f"backend {name!r} already registered")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_backends(*, include_auto: bool = False) -> Tuple[str, ...]:
    """Registered backend keys (sorted); ``auto`` is a policy, not a class."""
    names = sorted(_REGISTRY)
    return tuple(names + ["auto"]) if include_auto else tuple(names)


def get_backend_class(name: str) -> Type[LPEngine]:
    if name not in _REGISTRY:
        known = ", ".join(available_backends(include_auto=True))
        raise UnknownBackendError(f"unknown LP backend {name!r}; registered: {known}")
    return _REGISTRY[name]


def select_backend(num_nodes: int, config: Optional[LPConfig] = None) -> str:
    """The ``auto`` policy (DESIGN.md §11).

    Dense while the (N, N) operator is small (``AUTO_DENSE_MAX_NODES``),
    blocked-CSR sparse beyond.  ``sharded`` is never auto-selected — it
    needs an explicit device count/mesh, which is a deployment decision.
    """
    if num_nodes <= AUTO_DENSE_MAX_NODES:
        return "dense"
    return "sparse"


def resolve_backend(
    name: Optional[str],
    *,
    num_nodes: Optional[int] = None,
    config: Optional[LPConfig] = None,
) -> str:
    """Validate a backend key, resolving ``auto``/``None`` via the policy."""
    if name is None:
        name = "auto"
    if name == "auto":
        if num_nodes is None:
            raise ValueError(
                "resolving backend 'auto' needs num_nodes (the policy is "
                "size-based)"
            )
        return select_backend(num_nodes, config)
    get_backend_class(name)  # raises UnknownBackendError
    return name


def make_engine(
    backend: Optional[str] = None,
    config: LPConfig = LPConfig(),
    *,
    num_nodes: Optional[int] = None,
    **kwargs,
) -> LPEngine:
    """Instantiate a backend engine.

    ``backend=None`` falls back to ``config.backend`` then ``auto`` (which
    needs ``num_nodes``).  Extra ``kwargs`` are backend-specific (e.g.
    ``devices=`` for ``sharded``, ``block_rows=`` for ``sparse``).
    """
    name = resolve_backend(
        backend or config.backend, num_nodes=num_nodes, config=config
    )
    return get_backend_class(name)(config, **kwargs)
