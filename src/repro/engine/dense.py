"""Dense backend: the (N, N) XLA-matmul engine behind the registry.

Delegates to :class:`~repro.core.solver.HeteroLP` (the loops stay the
single source of truth for the dense math) and exposes the prepared
``fused``/``split`` device arrays through the engine ``round`` contract.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.network import NormalizedNetwork
from repro.core.solver import HeteroLP, LPConfig, SolveResult
from repro.engine.base import LPEngine, Operator, register_backend


@register_backend("dense")
class DenseEngine(LPEngine):
    supports_momentum = True

    def _build(self, norm: NormalizedNetwork) -> Operator:
        solver = HeteroLP(self.config)
        solver.operator_arrays(norm)  # assemble + upload now, not per solve
        return Operator(
            backend=self.name,
            norm=norm,
            num_nodes=norm.num_nodes,
            payload=solver,
        )

    def solve(
        self,
        op: Operator,
        Y: np.ndarray,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        return op.payload.run(op.norm, seeds=Y, F0=F0)

    def _round_arrays(self, op: Operator):
        """(A_eff, β²) for the fused round, derived once per operator."""
        cache = getattr(self, "_round_cache", None)
        if cache is not None and cache[0] is op:
            return cache[1], cache[2]
        cfg: LPConfig = self.config
        arrays = op.payload.operator_arrays(op.norm)
        if "fused" in arrays:
            A_eff, beta2 = arrays["fused"]
        else:
            H, M = arrays["split"]
            beta = 1.0 - cfg.alpha
            A_eff = cfg.alpha * beta * H + cfg.alpha * M
            beta2 = beta * beta
        self._round_cache = (op, A_eff, beta2)
        return A_eff, beta2

    def round(self, op: Operator, F, Y):
        cfg: LPConfig = self.config
        A_eff, beta2 = self._round_arrays(op)
        F = jnp.asarray(F, dtype=cfg.dtype)
        Y = jnp.asarray(Y, dtype=cfg.dtype)
        out = beta2 * Y + jnp.matmul(
            A_eff, F, preferred_element_type=jnp.float32
        ).astype(F.dtype)
        return np.asarray(out, dtype=np.float64)
