"""Unified LP execution backends (DESIGN.md §11).

One propagation contract — ``prepare(norm) → Operator``,
``solve(op, Y, F0=None) → SolveResult``, ``round(op, F, Y) → F`` — over a
string-keyed backend registry, so backend choice is one
``LPConfig.backend`` field instead of per-call-site branching:

>>> from repro.engine import make_engine
>>> engine = make_engine("sparse", LPConfig(alg="dhlp2"))
>>> result = engine.run(net)            # prepare + solve

Registered backends: ``dense`` (XLA matmul), ``sparse`` (blocked-CSR
width-bucket gather), ``sharded`` (device-mesh shard_map), ``kernel``
(fused blocked-CSR Pallas round), and the ``auto`` selection policy
(:func:`select_backend`).
"""

from repro.engine.base import (
    AUTO_DENSE_MAX_NODES,
    BackendUnsupported,
    LPEngine,
    Operator,
    UnknownBackendError,
    available_backends,
    get_backend_class,
    make_engine,
    register_backend,
    resolve_backend,
    select_backend,
)

# importing the submodules registers the built-in backends
from repro.engine import dense as _dense  # noqa: E402,F401
from repro.engine import sharded as _sharded  # noqa: E402,F401
from repro.engine import sparse as _sparse  # noqa: E402,F401

__all__ = [
    "AUTO_DENSE_MAX_NODES",
    "BackendUnsupported",
    "LPEngine",
    "Operator",
    "UnknownBackendError",
    "available_backends",
    "get_backend_class",
    "make_engine",
    "register_backend",
    "resolve_backend",
    "select_backend",
]
