"""Sparse backends: blocked-CSR, the repo's scalability path.

``sparse`` runs the *fused-superstep* plan by default: buckets are
remapped into permuted row order once at prepare time (neighbor ids
rewritten through the inverse permutation), so every round writes its
output rows contiguously — no per-round inverse-permute gather — and the
round + the per-column convergence reduction ``max_r |Fn − F|`` come out
of one fused op (``csr_round_residual``) instead of separate HLO ops.
Label state crosses the whole ``while_loop`` in permuted space and is
inverse-permuted exactly once on exit.  ``kernel`` is the same engine
with each bucket's fused round routed through the Pallas kernel
(VMEM-resident panel, fp32 accumulation).  The pre-fusion per-round path
(separate aggregate, add, and residual ops) is kept behind
``fused_superstep=False`` as the bench A/B baseline.

Layout (``block_rows``/``width_mult``) and kernel panel sizes default to
the persisted autotune winners for this host + operator shape class
(``repro.engine.autotune``; ``LPConfig.autotune=False`` or explicit
constructor kwargs opt out).  ``storage_dtype="bf16"`` stores operator
weights and the per-round gather panel in bfloat16 with fp32 state and
accumulation.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked_csr import (
    blocked_csr_from_network,
    split_blocked_csr_from_network,
)
from repro.core.network import NormalizedNetwork
from repro.core.solver import LPConfig, SolveResult, chunk_columns
from repro.engine import autotune
from repro.engine.base import LPEngine, Operator, register_backend
from repro.kernels.segment_reduce import csr_round_op, csr_round_residual_op

# device-side bucket: (rows, nbr, wgt) with nbr/wgt (R, width)
Bucket = Tuple[jax.Array, jax.Array, jax.Array]

_STORAGE = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _device_buckets(bcsr) -> Tuple[Tuple[Bucket, ...], jax.Array]:
    """Upload width buckets + the inverse row permutation (legacy path)."""
    buckets = bcsr.width_buckets()
    dev = tuple(
        (
            jnp.asarray(b.rows),
            jnp.asarray(b.nbr),
            jnp.asarray(b.wgt, dtype=jnp.float32),
        )
        for b in buckets
    )
    order = np.concatenate([b.rows for b in buckets])
    inv = np.argsort(order).astype(np.int32)
    return dev, jnp.asarray(inv)


def _bucket_agg(buckets, inv_perm, F):
    """``A @ F`` via per-bucket gather + einsum, back in node order."""
    parts = []
    for _, nbr, wgt in buckets:
        gathered = F[nbr].astype(jnp.float32)  # (R, w, S)
        parts.append(jnp.einsum("rw,rws->rs", wgt, gathered).astype(F.dtype))
    return jnp.concatenate(parts, axis=0)[inv_perm]


def _bucket_round(buckets, inv_perm, F, base, *, beta2: float):
    """Fused kernel round: ``β²·base + A @ F`` per bucket, node order.

    ``use_kernel=True`` through the op wrapper: an opted-in kernel
    backend must never silently fall back to the oracle on a size
    heuristic.
    """
    parts = [
        csr_round_op(nbr, wgt, F, base[rows], c=beta2, use_kernel=True)
        for rows, nbr, wgt in buckets
    ]
    return jnp.concatenate(parts, axis=0)[inv_perm]


@functools.partial(
    jax.jit,
    static_argnames=(
        "beta2",
        "sigma",
        "max_iter",
        "seed_mode",
        "momentum",
        "use_kernel",
    ),
)
def _dhlp2_csr_loop(
    buckets,
    inv_perm,
    Y,
    F0,
    *,
    beta2: float,
    sigma: float,
    max_iter: int,
    seed_mode: str,
    momentum: float,
    use_kernel: bool,
):
    """Pre-fusion DHLP-2 on blocked-CSR buckets (bench A/B baseline)."""

    def cond(state):
        _, _, active, it, _ = state
        return jnp.logical_and(it < max_iter, jnp.any(active))

    def body(state):
        F, F_prev, active, it, col_iters = state
        base = Y if seed_mode == "fixed" else F
        if use_kernel:
            Fn = _bucket_round(buckets, inv_perm, F, base, beta2=beta2)
        else:
            agg = _bucket_agg(buckets, inv_perm, F)
            Fn = beta2 * base + agg
        if momentum:
            Fn = Fn + momentum * (F - F_prev)
        Fn = jnp.where(active[None, :], Fn, F)
        delta = jnp.max(jnp.abs(Fn - F), axis=0)
        still = jnp.logical_and(active, ~(delta < sigma))
        col_iters = col_iters + active.astype(jnp.int32)
        return Fn, F, still, it + 1, col_iters

    s = Y.shape[1]
    state0 = (
        F0,
        F0,
        jnp.ones((s,), dtype=bool),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((s,), jnp.int32),
    )
    F, _, _, iters, col_iters = jax.lax.while_loop(cond, body, state0)
    return F, iters, col_iters


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "sigma", "max_iter", "max_inner", "seed_mode"),
)
def _dhlp1_csr_loop(
    het_buckets,
    het_inv,
    hom_buckets,
    hom_inv,
    Y,
    F0,
    *,
    alpha: float,
    sigma: float,
    max_iter: int,
    max_inner: int,
    seed_mode: str,
):
    """Pre-fusion DHLP-1 on blocked-CSR (bench A/B baseline)."""
    beta = 1.0 - alpha

    def inner(Yp, F0i, active):
        def icond(istate):
            _, iact, it = istate
            return jnp.logical_and(it < max_inner, jnp.any(iact))

        def ibody(istate):
            F, iact, it = istate
            Fn = beta * Yp + alpha * _bucket_agg(hom_buckets, hom_inv, F)
            Fn = jnp.where(iact[None, :], Fn, F)
            delta = jnp.max(jnp.abs(Fn - F), axis=0)
            return Fn, jnp.logical_and(iact, ~(delta < sigma)), it + 1

        F, _, inner_it = jax.lax.while_loop(
            icond, ibody, (F0i, active, jnp.asarray(0, jnp.int32))
        )
        return F, inner_it

    def cond(state):
        _, active, it, _, _ = state
        return jnp.logical_and(it < max_iter, jnp.any(active))

    def body(state):
        F, active, it, tot_inner, col_iters = state
        src = Y if seed_mode == "fixed" else F
        Yp = beta * src + alpha * _bucket_agg(het_buckets, het_inv, F)
        Fn, inner_it = inner(Yp, F, active)
        Fn = jnp.where(active[None, :], Fn, F)
        delta = jnp.max(jnp.abs(Fn - F), axis=0)
        still = jnp.logical_and(active, ~(delta < sigma))
        col_iters = col_iters + active.astype(jnp.int32)
        return Fn, still, it + 1, tot_inner + inner_it, col_iters

    s = Y.shape[1]
    state0 = (
        F0,
        jnp.ones((s,), dtype=bool),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((s,), jnp.int32),
    )
    F, _, iters, tot_inner, col_iters = jax.lax.while_loop(cond, body, state0)
    return F, iters, tot_inner, col_iters


# --------------------------------------------------------------------------
# Fused-superstep plan: permuted-space buckets with remapped neighbor ids
# --------------------------------------------------------------------------


#: exact-width re-bucketing policy: a bucket closes when the next row's
#: width drops below ``SLACK`` of the bucket max (once it has at least
#: ``MIN_ROWS`` rows); bucket widths round up to a multiple of 8 so the
#: kernel's width panels stay aligned.  On heavy-tailed graphs this cuts
#: padded nnz ~3x vs the block-rows layout (which pads every row in a
#: 64-row block to the block max).
_TIGHTEN_SLACK = 0.9
_TIGHTEN_MIN_ROWS = 16
_TIGHTEN_ALIGN = 8


def _tighten_buckets(buckets):
    """Re-bucket rows by exact nonzero width (row order is free here).

    The block-rows layout pads every row in a block to the block's max
    width — on power-law degree graphs that is ~2-3x wasted gather+FMA
    per round.  The permuted-space plan owns the row order outright, so
    it can sort all rows by true width and group near-equal widths,
    keeping padding to a few percent.  Zero-weight padding entries are
    dropped (exact: they contribute nothing to the aggregation).

    Returns ``[(rows, nbr, wgt), ...]`` numpy triples, widest first.
    """
    rows_all = np.concatenate([b.rows for b in buckets])
    nbr_all = [b.nbr[i] for b in buckets for i in range(b.nbr.shape[0])]
    wgt_all = [b.wgt[i] for b in buckets for i in range(b.wgt.shape[0])]
    widths = np.array([int((w != 0).sum()) for w in wgt_all])
    order = np.argsort(-widths, kind="stable")
    out = []
    i, n = 0, len(order)
    while i < n:
        wmax = max(int(widths[order[i]]), 1)
        j = i + 1
        while j < n and (
            widths[order[j]] >= _TIGHTEN_SLACK * wmax
            or j - i < _TIGHTEN_MIN_ROWS
        ):
            j += 1
        bw = -(-wmax // _TIGHTEN_ALIGN) * _TIGHTEN_ALIGN
        sel = order[i:j]
        nbr = np.zeros((len(sel), bw), dtype=np.int32)
        wgt = np.zeros((len(sel), bw), dtype=np.float32)
        for k, r in enumerate(sel):
            nz = np.flatnonzero(wgt_all[r])
            nbr[k, : nz.size] = nbr_all[r][nz]
            wgt[k, : nz.size] = wgt_all[r][nz]
        out.append((rows_all[sel], nbr, wgt))
        i = j
    return out


def _device_plan(bcsr, *, storage: str, weight_scale: float = 1.0):
    """Permuted-space bucket plan for the fused-superstep loops.

    Returns ``(buckets, perm, rank)``: ``perm`` is the bucket-concat row
    order (node id at each permuted position), ``rank = argsort(perm)``
    (permuted position of each node id).  Bucket neighbor ids are
    pre-remapped through ``rank`` so rounds gather from — and write to —
    permuted space directly: output rows land contiguously at static
    offsets, no per-round inverse permute.  Rows are re-bucketed by
    exact width (:func:`_tighten_buckets`) — the plan's main perf lever.
    """
    tight = _tighten_buckets(bcsr.width_buckets())
    order = np.concatenate([rows for rows, _, _ in tight])
    rank = np.argsort(order).astype(np.int32)
    wdt = _STORAGE[storage]
    dev = tuple(
        (
            jnp.asarray(rank[nbr]),
            jnp.asarray(weight_scale * wgt, dtype=wdt),
        )
        for _, nbr, wgt in tight
    )
    return dev, jnp.asarray(order.astype(np.int32)), jnp.asarray(rank)


def _plan_round(
    buckets, F, base, *, c, use_kernel, storage, bn, bs, bd
):
    """One fused superstep over a permuted-space plan.

    ``F``/``base`` live in permuted space; returns ``(Fn, delta)`` with
    ``Fn`` permuted-space fp32 and ``delta`` the per-column residual
    ``max_r |Fn − F|`` (exact: the row max is permutation-invariant).

    Two lowerings of the same math: the Pallas path keeps the epilogue
    and residual partials on-chip per bucket (``csr_round_residual``);
    the oracle path only fuses per-bucket gathers — there XLA lowers the
    epilogue + residual best as ONE pass over the whole concatenated
    state, and the f32 accumulator never round-trips through ``storage``.
    Element order is identical either way, so f32 results are
    bit-identical across the two lowerings.
    """
    Fq = F.astype(_STORAGE[storage]) if storage != "f32" else F
    if not use_kernel:
        parts = [
            jnp.einsum(
                "rw,rws->rs",
                wgt.astype(jnp.float32),
                Fq[nbr].astype(jnp.float32),
            )
            for nbr, wgt in buckets
        ]
        Fn = c * base.astype(jnp.float32) + jnp.concatenate(parts, axis=0)
        delta = jnp.max(jnp.abs(Fn - F.astype(jnp.float32)), axis=0)
        return Fn, delta
    parts, dparts = [], []
    off = 0
    for nbr, wgt in buckets:
        m = nbr.shape[0]
        out, dl = csr_round_residual_op(
            nbr,
            wgt,
            Fq,
            base[off : off + m],
            F[off : off + m],
            c=c,
            bn=bn,
            bs=bs,
            bd=bd,
            use_kernel=True,
        )
        parts.append(out)
        dparts.append(dl)
        off += m
    Fn = jnp.concatenate(parts, axis=0)
    delta = jnp.max(jnp.concatenate(dparts, axis=0), axis=0)
    return Fn, delta


@functools.partial(
    jax.jit,
    static_argnames=(
        "beta2",
        "sigma",
        "max_iter",
        "seed_mode",
        "momentum",
        "use_kernel",
        "storage",
        "bn",
        "bs",
        "bd",
    ),
)
def _dhlp2_plan_loop(
    buckets,
    perm,
    rank,
    Y,
    F0,
    *,
    beta2: float,
    sigma: float,
    max_iter: int,
    seed_mode: str,
    momentum: float,
    use_kernel: bool,
    storage: str,
    bn: int,
    bs: int,
    bd: int,
):
    """Fused-superstep DHLP-2: state stays in permuted space end to end.

    Entry/exit permutes live inside the jit so a solve is ONE dispatch;
    on small networks the per-call op overhead of out-of-jit gathers
    would otherwise dominate the round work.
    """
    Yp = Y[perm]
    F0p = F0[perm]

    def cond(state):
        _, _, active, it, _ = state
        return jnp.logical_and(it < max_iter, jnp.any(active))

    def body(state):
        F, F_prev, active, it, col_iters = state
        base = Yp if seed_mode == "fixed" else F
        Fn, delta = _plan_round(
            buckets,
            F,
            base,
            c=beta2,
            use_kernel=use_kernel,
            storage=storage,
            bn=bn,
            bs=bs,
            bd=bd,
        )
        if momentum:
            # the kernel residual is pre-momentum; fold the heavy-ball
            # term in and recompute — still gather-free in permuted space
            Fn = Fn + momentum * (F - F_prev)
            delta = jnp.max(jnp.abs(Fn - F), axis=0)
        Fn = jnp.where(active[None, :], Fn, F)
        still = jnp.logical_and(active, ~(delta < sigma))
        col_iters = col_iters + active.astype(jnp.int32)
        return Fn, F, still, it + 1, col_iters

    s = Yp.shape[1]
    state0 = (
        F0p,
        F0p,
        jnp.ones((s,), dtype=bool),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((s,), jnp.int32),
    )
    F, _, _, iters, col_iters = jax.lax.while_loop(cond, body, state0)
    return F[rank], iters, col_iters


@functools.partial(
    jax.jit,
    static_argnames=(
        "alpha",
        "sigma",
        "max_iter",
        "max_inner",
        "seed_mode",
        "use_kernel",
        "storage",
        "bn",
        "bs",
        "bd",
    ),
)
def _dhlp1_plan_loop(
    hom_buckets,
    het_buckets,
    het_base_map,
    reorder,
    hom_perm,
    hom_rank,
    Y,
    F0,
    *,
    alpha: float,
    sigma: float,
    max_iter: int,
    max_inner: int,
    seed_mode: str,
    use_kernel: bool,
    storage: str,
    bn: int,
    bs: int,
    bd: int,
):
    """Fused-superstep DHLP-1: state lives in *hom*-permuted space.

    The inner homogeneous solve dominates the superstep count, so its
    plan is fully gather-free; the outer hetero injection pays one base
    gather (``het_base_map``) and one output regather (``reorder``) per
    outer iteration.  α is folded into both plans' weights, so inner and
    outer rounds are plain fused rounds with ``c = β``.  Entry/exit
    permutes live inside the jit: one dispatch per solve.
    """
    beta = 1.0 - alpha
    Y = Y[hom_perm]
    F0 = F0[hom_perm]

    def inner(Yp, F0i, active):
        def icond(istate):
            _, iact, it = istate
            return jnp.logical_and(it < max_inner, jnp.any(iact))

        def ibody(istate):
            F, iact, it = istate
            Fn, delta = _plan_round(
                hom_buckets,
                F,
                Yp,
                c=beta,
                use_kernel=use_kernel,
                storage=storage,
                bn=bn,
                bs=bs,
                bd=bd,
            )
            Fn = jnp.where(iact[None, :], Fn, F)
            return Fn, jnp.logical_and(iact, ~(delta < sigma)), it + 1

        F, _, inner_it = jax.lax.while_loop(
            icond, ibody, (F0i, active, jnp.asarray(0, jnp.int32))
        )
        return F, inner_it

    def cond(state):
        _, active, it, _, _ = state
        return jnp.logical_and(it < max_iter, jnp.any(active))

    def body(state):
        F, active, it, tot_inner, col_iters = state
        src = Y if seed_mode == "fixed" else F
        Fq = F.astype(_STORAGE[storage]) if storage != "f32" else F
        src_het = src[het_base_map]
        parts = []
        off = 0
        for nbr, wgt in het_buckets:
            m = nbr.shape[0]
            parts.append(
                csr_round_op(
                    nbr,
                    wgt,
                    Fq,
                    src_het[off : off + m],
                    c=beta,
                    bn=bn,
                    bs=bs,
                    bd=bd,
                    use_kernel=use_kernel,
                )
            )
            off += m
        Yp = jnp.concatenate(parts, axis=0)[reorder]
        Fn, inner_it = inner(Yp, F, active)
        Fn = jnp.where(active[None, :], Fn, F)
        delta = jnp.max(jnp.abs(Fn - F), axis=0)
        still = jnp.logical_and(active, ~(delta < sigma))
        col_iters = col_iters + active.astype(jnp.int32)
        return Fn, still, it + 1, tot_inner + inner_it, col_iters

    s = Y.shape[1]
    state0 = (
        F0,
        jnp.ones((s,), dtype=bool),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((s,), jnp.int32),
    )
    F, _, iters, tot_inner, col_iters = jax.lax.while_loop(cond, body, state0)
    return F[hom_rank], iters, tot_inner, col_iters


class _CSRPayload:
    """Device-resident blocked-CSR operator bundle.

    ``plan``/``split_plan`` are the fused-superstep permuted-space plans;
    ``fused``/``split`` are the legacy node-order bundles (only built
    when ``fused_superstep=False``).  DHLP-1 members stay None for
    DHLP-2 configs and vice versa; ``plan`` is also built lazily for
    DHLP-1 when ``round`` needs the fused operator.
    """

    def __init__(self):
        self.fused = None
        self.fused_inv = None
        self.split = None  # ((het_buckets, het_inv), (hom_buckets, hom_inv))
        self.plan = None  # (buckets, perm, rank)
        self.split_plan = None  # (hom_bk, het_bk, het_base_map, reorder,
        #                          hom_perm, hom_rank)
        self.layout = None  # resolved (block_rows, width_mult)
        self.panels = None  # resolved (bn, bs, bd)


@register_backend("sparse")
class SparseCSREngine(LPEngine):
    """Blocked-CSR width-bucket engine — the default scalability path."""

    supports_momentum = True
    use_kernel = False

    def __init__(
        self,
        config=None,
        *,
        block_rows=None,
        width_mult=None,
        fused_superstep=True,
    ):
        super().__init__(config if config is not None else LPConfig())
        self.block_rows = block_rows  # None = autotuned (or default)
        self.width_mult = width_mult
        self.fused_superstep = fused_superstep
        self._round_jit = None  # built lazily; compiled per (F, Y) shape

    # ---------------------------------------------------------- param wiring
    def _resolve_params(self, norm: NormalizedNetwork) -> autotune.TunedParams:
        """Layout + panel parameters: explicit kwargs > cache > defaults."""
        tuned = None
        if self.config.autotune and (
            self.block_rows is None or self.width_mult is None
        ):
            tuned = autotune.lookup(norm.num_nodes, autotune.network_nnz(norm))
        base = tuned if tuned is not None else autotune.DEFAULT_PARAMS
        return autotune.TunedParams(
            block_rows=self.block_rows or base.block_rows,
            width_mult=self.width_mult or base.width_mult,
            bn=base.bn,
            bs=base.bs,
            bd=base.bd,
        )

    def _build(self, norm: NormalizedNetwork) -> Operator:
        cfg = self.config
        params = self._resolve_params(norm)
        pay = _CSRPayload()
        pay.layout = (params.block_rows, params.width_mult)
        pay.panels = (params.bn, params.bs, params.bd)
        if cfg.alg == "dhlp1":
            het, hom = split_blocked_csr_from_network(
                norm,
                hetero_scale=cfg.resolved_hetero_scale(norm.num_types),
                block_rows=params.block_rows,
                width_mult=params.width_mult,
            )
            if self.fused_superstep:
                hom_bk, hom_perm, hom_rank = _device_plan(
                    hom, storage=cfg.storage_dtype, weight_scale=cfg.alpha
                )
                het_buckets = het.width_buckets()
                het_order = np.concatenate([b.rows for b in het_buckets])
                het_rank = np.argsort(het_order).astype(np.int32)
                hom_rank_np = np.asarray(hom_rank)
                wdt = _STORAGE[cfg.storage_dtype]
                het_bk = tuple(
                    (
                        jnp.asarray(hom_rank_np[b.nbr]),
                        jnp.asarray(cfg.alpha * b.wgt, dtype=wdt),
                    )
                    for b in het_buckets
                )
                het_base_map = jnp.asarray(hom_rank_np[het_order])
                reorder = jnp.asarray(het_rank[np.asarray(hom_perm)])
                pay.split_plan = (
                    hom_bk,
                    het_bk,
                    het_base_map,
                    reorder,
                    hom_perm,
                    hom_rank,
                )
            else:
                pay.split = (_device_buckets(het), _device_buckets(hom))
        op = Operator(
            backend=self.name,
            norm=norm,
            num_nodes=norm.num_nodes,
            payload=pay,
        )
        if cfg.alg == "dhlp2":
            if self.fused_superstep:
                self._fused_plan(op)
            else:
                self._fused_buckets(op)
        return op

    def _fused_bcsr(self, op: Operator):
        cfg = self.config
        br, wm = op.payload.layout
        return blocked_csr_from_network(
            op.norm,
            alpha=cfg.alpha,
            hetero_scale=cfg.resolved_hetero_scale(op.norm.num_types),
            block_rows=br,
            width_mult=wm,
        )

    def _fused_buckets(self, op: Operator):
        """Legacy node-order fused buckets, built on first use."""
        pay: _CSRPayload = op.payload
        if pay.fused is None:
            pay.fused, pay.fused_inv = _device_buckets(self._fused_bcsr(op))
        return pay.fused, pay.fused_inv

    def _fused_plan(self, op: Operator):
        """Permuted-space fused plan, built on first use."""
        pay: _CSRPayload = op.payload
        if pay.plan is None:
            pay.plan = _device_plan(
                self._fused_bcsr(op), storage=self.config.storage_dtype
            )
        return pay.plan

    def solve(
        self,
        op: Operator,
        Y: np.ndarray,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        cfg = self.config
        pay: _CSRPayload = op.payload
        Y = np.asarray(Y)
        if Y.ndim == 1:
            Y = Y[:, None]

        chunks = chunk_columns(Y, cfg.seed_chunk)
        f0_chunks = (
            [None] * len(chunks)
            if F0 is None
            else chunk_columns(np.asarray(F0), cfg.seed_chunk)
        )
        parts: List[np.ndarray] = []
        outer, inner_tot, cols = 0, 0, []
        beta = 1.0 - cfg.alpha
        bn, bs, bd = pay.panels or (256, 128, 16)
        for Yc, F0c in zip(chunks, f0_chunks):
            Yd = jnp.asarray(Yc, jnp.float32)
            F0d = Yd if F0c is None else jnp.asarray(F0c, jnp.float32)
            if cfg.alg == "dhlp2":
                if self.fused_superstep:
                    buckets, perm, rank = self._fused_plan(op)
                    F, it, ci = _dhlp2_plan_loop(
                        buckets,
                        perm,
                        rank,
                        Yd,
                        F0d,
                        beta2=beta * beta,
                        sigma=cfg.sigma,
                        max_iter=cfg.max_iter,
                        seed_mode=cfg.resolved_seed_mode(),
                        momentum=cfg.momentum,
                        use_kernel=self.use_kernel,
                        storage=cfg.storage_dtype,
                        bn=bn,
                        bs=bs,
                        bd=bd,
                    )
                else:
                    fused, fused_inv = self._fused_buckets(op)
                    F, it, ci = _dhlp2_csr_loop(
                        fused,
                        fused_inv,
                        Yd,
                        F0d,
                        beta2=beta * beta,
                        sigma=cfg.sigma,
                        max_iter=cfg.max_iter,
                        seed_mode=cfg.resolved_seed_mode(),
                        momentum=cfg.momentum,
                        use_kernel=self.use_kernel,
                    )
            else:
                if self.fused_superstep:
                    (hom_bk, het_bk, het_base_map, reorder, hom_perm,
                     hom_rank) = pay.split_plan
                    F, it, ti, ci = _dhlp1_plan_loop(
                        hom_bk,
                        het_bk,
                        het_base_map,
                        reorder,
                        hom_perm,
                        hom_rank,
                        Yd,
                        F0d,
                        alpha=cfg.alpha,
                        sigma=cfg.sigma,
                        max_iter=cfg.max_iter,
                        max_inner=cfg.max_inner,
                        seed_mode=cfg.resolved_seed_mode(),
                        use_kernel=self.use_kernel,
                        storage=cfg.storage_dtype,
                        bn=bn,
                        bs=bs,
                        bd=bd,
                    )
                else:
                    (hb, hi), (mb, mi) = pay.split
                    F, it, ti, ci = _dhlp1_csr_loop(
                        hb,
                        hi,
                        mb,
                        mi,
                        Yd,
                        F0d,
                        alpha=cfg.alpha,
                        sigma=cfg.sigma,
                        max_iter=cfg.max_iter,
                        max_inner=cfg.max_inner,
                        seed_mode=cfg.resolved_seed_mode(),
                    )
                inner_tot += int(ti)
            parts.append(np.asarray(F, np.float64))
            outer = max(outer, int(it))
            cols.append(np.asarray(ci))
        return SolveResult(
            F=np.concatenate(parts, axis=1),
            outer_iters=outer,
            inner_iters=inner_tot,
            converged=bool(outer < cfg.max_iter),
            per_column_iters=np.concatenate(cols),
        )

    # -------------------------------------------------------------- rounds
    def _ensure_round_jit(self, op: Operator):
        if self._round_jit is not None:
            return self._round_jit
        cfg = self.config
        beta2 = (1.0 - cfg.alpha) ** 2
        if self.fused_superstep:
            bn, bs, bd = op.payload.panels or (256, 128, 16)
            storage = cfg.storage_dtype
            use_kernel = self.use_kernel

            def _round_impl(buckets, perm, rank, Fc, Yc):
                Fn, delta = _plan_round(
                    buckets,
                    Fc[perm],
                    Yc[perm],
                    c=beta2,
                    use_kernel=use_kernel,
                    storage=storage,
                    bn=bn,
                    bs=bs,
                    bd=bd,
                )
                return Fn[rank], delta

        elif self.use_kernel:

            def _round_impl(buckets, inv, Fc, Yc):
                out = _bucket_round(buckets, inv, Fc, Yc, beta2=beta2)
                return out, jnp.max(jnp.abs(out - Fc), axis=0)

        else:

            def _round_impl(buckets, inv, Fc, Yc):
                out = beta2 * Yc + _bucket_agg(buckets, inv, Fc)
                return out, jnp.max(jnp.abs(out - Fc), axis=0)

        # one jitted program per (F, Y) shape instead of eager per-bucket
        # dispatch — the serve tier's early-exit loop and hint refresh
        # call round once per superstep, so per-call overhead is its hot
        # path.  beta2 folds in as a constant (alpha is frozen per
        # engine).
        self._round_jit = jax.jit(_round_impl)
        return self._round_jit

    def round_with_residual(self, op: Operator, F, Y):
        """One fused superstep + its residual (serve's early-exit unit)."""
        fn = self._ensure_round_jit(op)
        Fd = jnp.asarray(F, jnp.float32)
        Yd = jnp.asarray(Y, jnp.float32)
        if self.fused_superstep:
            buckets, perm, rank = self._fused_plan(op)
            out, delta = fn(buckets, perm, rank, Fd, Yd)
        else:
            fused, fused_inv = self._fused_buckets(op)
            out, delta = fn(fused, fused_inv, Fd, Yd)
        return (
            np.asarray(out, dtype=np.float64),
            np.asarray(delta, dtype=np.float64),
        )

    def round(self, op: Operator, F, Y):
        return self.round_with_residual(op, F, Y)[0]


@register_backend("kernel")
class KernelCSREngine(SparseCSREngine):
    """Blocked-CSR with the fused Pallas superstep kernel per bucket.

    Interpret-mode on CPU, Mosaic on TPU.  Only the fused DHLP-2 round
    has a kernel; DHLP-1's two-phase schedule stays on ``sparse``/
    ``dense``.
    """

    supports_algs = ("dhlp2",)
    use_kernel = True
