"""Sparse backends: blocked-CSR, the repo's scalability path.

``sparse`` aggregates per blocked-CSR width bucket — a gather + einsum
over each ``(rows, width)`` rectangle, concatenated and inverse-permuted
back to node order.  No scatter: every shape is static and regular, which
is what replaced the retired COO gather/segment-sum layout as the default
(DESIGN.md §11; the ``sparse_coo`` backend was deleted after blocked-CSR
dominated it on consecutive bench passes).  ``kernel`` is the same engine
with each bucket's round routed through the fused ``csr_round`` Pallas
kernel (``β²·Y + A_bucket @ F`` in one VMEM-resident pass).
"""

from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked_csr import (
    blocked_csr_from_network,
    split_blocked_csr_from_network,
)
from repro.core.network import NormalizedNetwork
from repro.core.solver import LPConfig, SolveResult, chunk_columns
from repro.engine.base import LPEngine, Operator, register_backend
from repro.kernels.segment_reduce import csr_round_op

# device-side bucket: (rows, nbr, wgt) with nbr/wgt (R, width)
Bucket = Tuple[jax.Array, jax.Array, jax.Array]


def _device_buckets(bcsr) -> Tuple[Tuple[Bucket, ...], jax.Array]:
    """Upload width buckets + the inverse row permutation."""
    buckets = bcsr.width_buckets()
    dev = tuple(
        (
            jnp.asarray(b.rows),
            jnp.asarray(b.nbr),
            jnp.asarray(b.wgt, dtype=jnp.float32),
        )
        for b in buckets
    )
    order = np.concatenate([b.rows for b in buckets])
    inv = np.argsort(order).astype(np.int32)
    return dev, jnp.asarray(inv)


def _bucket_agg(buckets, inv_perm, F):
    """``A @ F`` via per-bucket gather + einsum, back in node order."""
    parts = []
    for _, nbr, wgt in buckets:
        gathered = F[nbr].astype(jnp.float32)  # (R, w, S)
        parts.append(jnp.einsum("rw,rws->rs", wgt, gathered).astype(F.dtype))
    return jnp.concatenate(parts, axis=0)[inv_perm]


def _bucket_round(buckets, inv_perm, F, base, *, beta2: float):
    """Fused kernel round: ``β²·base + A @ F`` per bucket, node order.

    ``use_kernel=True`` through the op wrapper: an opted-in kernel
    backend must never silently fall back to the oracle on a size
    heuristic.
    """
    parts = [
        csr_round_op(nbr, wgt, F, base[rows], c=beta2, use_kernel=True)
        for rows, nbr, wgt in buckets
    ]
    return jnp.concatenate(parts, axis=0)[inv_perm]


@functools.partial(
    jax.jit,
    static_argnames=(
        "beta2",
        "sigma",
        "max_iter",
        "seed_mode",
        "momentum",
        "use_kernel",
    ),
)
def _dhlp2_csr_loop(
    buckets,
    inv_perm,
    Y,
    F0,
    *,
    beta2: float,
    sigma: float,
    max_iter: int,
    seed_mode: str,
    momentum: float,
    use_kernel: bool,
):
    """Fused DHLP-2 on blocked-CSR buckets (same math as the dense loop)."""

    def cond(state):
        _, _, active, it, _ = state
        return jnp.logical_and(it < max_iter, jnp.any(active))

    def body(state):
        F, F_prev, active, it, col_iters = state
        base = Y if seed_mode == "fixed" else F
        if use_kernel:
            Fn = _bucket_round(buckets, inv_perm, F, base, beta2=beta2)
        else:
            agg = _bucket_agg(buckets, inv_perm, F)
            Fn = beta2 * base + agg
        if momentum:
            Fn = Fn + momentum * (F - F_prev)
        Fn = jnp.where(active[None, :], Fn, F)
        delta = jnp.max(jnp.abs(Fn - F), axis=0)
        still = jnp.logical_and(active, ~(delta < sigma))
        col_iters = col_iters + active.astype(jnp.int32)
        return Fn, F, still, it + 1, col_iters

    s = Y.shape[1]
    state0 = (
        F0,
        F0,
        jnp.ones((s,), dtype=bool),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((s,), jnp.int32),
    )
    F, _, _, iters, col_iters = jax.lax.while_loop(cond, body, state0)
    return F, iters, col_iters


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "sigma", "max_iter", "max_inner", "seed_mode"),
)
def _dhlp1_csr_loop(
    het_buckets,
    het_inv,
    hom_buckets,
    hom_inv,
    Y,
    F0,
    *,
    alpha: float,
    sigma: float,
    max_iter: int,
    max_inner: int,
    seed_mode: str,
):
    """DHLP-1 on blocked-CSR: outer hetero injection + inner homo solve."""
    beta = 1.0 - alpha

    def inner(Yp, F0i, active):
        def icond(istate):
            _, iact, it = istate
            return jnp.logical_and(it < max_inner, jnp.any(iact))

        def ibody(istate):
            F, iact, it = istate
            Fn = beta * Yp + alpha * _bucket_agg(hom_buckets, hom_inv, F)
            Fn = jnp.where(iact[None, :], Fn, F)
            delta = jnp.max(jnp.abs(Fn - F), axis=0)
            return Fn, jnp.logical_and(iact, ~(delta < sigma)), it + 1

        F, _, inner_it = jax.lax.while_loop(
            icond, ibody, (F0i, active, jnp.asarray(0, jnp.int32))
        )
        return F, inner_it

    def cond(state):
        _, active, it, _, _ = state
        return jnp.logical_and(it < max_iter, jnp.any(active))

    def body(state):
        F, active, it, tot_inner, col_iters = state
        src = Y if seed_mode == "fixed" else F
        Yp = beta * src + alpha * _bucket_agg(het_buckets, het_inv, F)
        Fn, inner_it = inner(Yp, F, active)
        Fn = jnp.where(active[None, :], Fn, F)
        delta = jnp.max(jnp.abs(Fn - F), axis=0)
        still = jnp.logical_and(active, ~(delta < sigma))
        col_iters = col_iters + active.astype(jnp.int32)
        return Fn, still, it + 1, tot_inner + inner_it, col_iters

    s = Y.shape[1]
    state0 = (
        F0,
        jnp.ones((s,), dtype=bool),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((s,), jnp.int32),
    )
    F, _, iters, tot_inner, col_iters = jax.lax.while_loop(cond, body, state0)
    return F, iters, tot_inner, col_iters


class _CSRPayload:
    """Device-resident blocked-CSR operator bundle.

    ``fused`` stays None for DHLP-1 configs until ``round`` needs it —
    the DHLP-1 solve runs on the split pair only, so the fused build
    (COO sort + bucket packing + upload) would be wasted per prepare.
    """

    def __init__(self, fused=None, fused_inv=None, split=None):
        self.fused = fused
        self.fused_inv = fused_inv
        self.split = split  # ((het_buckets, het_inv), (hom_buckets, hom_inv))


@register_backend("sparse")
class SparseCSREngine(LPEngine):
    """Blocked-CSR width-bucket engine — the default scalability path."""

    supports_momentum = True
    use_kernel = False

    def __init__(self, config=None, *, block_rows=64, width_mult=8):
        super().__init__(config if config is not None else LPConfig())
        self.block_rows = block_rows
        self.width_mult = width_mult
        self._round_jit = None  # built lazily; compiled per (F, Y) shape

    def _build(self, norm: NormalizedNetwork) -> Operator:
        cfg = self.config
        pay = _CSRPayload()
        if cfg.alg == "dhlp1":
            het, hom = split_blocked_csr_from_network(
                norm,
                hetero_scale=cfg.resolved_hetero_scale(norm.num_types),
                block_rows=self.block_rows,
                width_mult=self.width_mult,
            )
            pay.split = (_device_buckets(het), _device_buckets(hom))
        op = Operator(
            backend=self.name,
            norm=norm,
            num_nodes=norm.num_nodes,
            payload=pay,
        )
        if cfg.alg == "dhlp2":
            self._fused_buckets(op)
        return op

    def _fused_buckets(self, op: Operator):
        """Fused-operator buckets, built on first use (eager for dhlp2)."""
        pay: _CSRPayload = op.payload
        if pay.fused is None:
            cfg = self.config
            bcsr = blocked_csr_from_network(
                op.norm,
                alpha=cfg.alpha,
                hetero_scale=cfg.resolved_hetero_scale(op.norm.num_types),
                block_rows=self.block_rows,
                width_mult=self.width_mult,
            )
            pay.fused, pay.fused_inv = _device_buckets(bcsr)
        return pay.fused, pay.fused_inv

    def solve(
        self,
        op: Operator,
        Y: np.ndarray,
        F0: Optional[np.ndarray] = None,
    ) -> SolveResult:
        cfg = self.config
        pay: _CSRPayload = op.payload
        Y = np.asarray(Y)
        if Y.ndim == 1:
            Y = Y[:, None]

        chunks = chunk_columns(Y, cfg.seed_chunk)
        f0_chunks = (
            [None] * len(chunks)
            if F0 is None
            else chunk_columns(np.asarray(F0), cfg.seed_chunk)
        )
        parts: List[np.ndarray] = []
        outer, inner_tot, cols = 0, 0, []
        beta = 1.0 - cfg.alpha
        for Yc, F0c in zip(chunks, f0_chunks):
            Yd = jnp.asarray(Yc, jnp.float32)
            F0d = Yd if F0c is None else jnp.asarray(F0c, jnp.float32)
            if cfg.alg == "dhlp2":
                fused, fused_inv = self._fused_buckets(op)
                F, it, ci = _dhlp2_csr_loop(
                    fused,
                    fused_inv,
                    Yd,
                    F0d,
                    beta2=beta * beta,
                    sigma=cfg.sigma,
                    max_iter=cfg.max_iter,
                    seed_mode=cfg.resolved_seed_mode(),
                    momentum=cfg.momentum,
                    use_kernel=self.use_kernel,
                )
            else:
                (hb, hi), (mb, mi) = pay.split
                F, it, ti, ci = _dhlp1_csr_loop(
                    hb,
                    hi,
                    mb,
                    mi,
                    Yd,
                    F0d,
                    alpha=cfg.alpha,
                    sigma=cfg.sigma,
                    max_iter=cfg.max_iter,
                    max_inner=cfg.max_inner,
                    seed_mode=cfg.resolved_seed_mode(),
                )
                inner_tot += int(ti)
            parts.append(np.asarray(F, np.float64))
            outer = max(outer, int(it))
            cols.append(np.asarray(ci))
        return SolveResult(
            F=np.concatenate(parts, axis=1),
            outer_iters=outer,
            inner_iters=inner_tot,
            converged=bool(outer < cfg.max_iter),
            per_column_iters=np.concatenate(cols),
        )

    def round(self, op: Operator, F, Y):
        cfg = self.config
        fused, fused_inv = self._fused_buckets(op)
        beta2 = (1.0 - cfg.alpha) ** 2
        Fd = jnp.asarray(F, jnp.float32)
        Yd = jnp.asarray(Y, jnp.float32)
        if self._round_jit is None:
            # one jitted program per (F, Y) shape instead of eager
            # per-bucket dispatch — the serve tier's early-exit loop and
            # hint refresh call round once per superstep, so per-call
            # overhead is its hot path.  beta2 folds in as a constant
            # (alpha is frozen per engine).
            if self.use_kernel:
                def _round_impl(buckets, inv, Fc, Yc):
                    return _bucket_round(buckets, inv, Fc, Yc, beta2=beta2)
            else:
                def _round_impl(buckets, inv, Fc, Yc):
                    return beta2 * Yc + _bucket_agg(buckets, inv, Fc)

            self._round_jit = jax.jit(_round_impl)
        out = self._round_jit(fused, fused_inv, Fd, Yd)
        return np.asarray(out, dtype=np.float64)


@register_backend("kernel")
class KernelCSREngine(SparseCSREngine):
    """Blocked-CSR with the fused ``csr_round`` Pallas kernel per bucket.

    Interpret-mode on CPU, Mosaic on TPU.  Only the fused DHLP-2 round has
    a kernel; DHLP-1's two-phase schedule stays on ``sparse``/``dense``.
    """

    supports_algs = ("dhlp2",)
    use_kernel = True
