"""Shared model building blocks (pure-functional, pytree params)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale=None):
    if scale is None:
        scale = 1.0 / (in_dim ** 0.5)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (out * gamma + beta).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def mlp(x, weights: Sequence[jax.Array], biases: Sequence[jax.Array],
        act=jax.nn.relu, final_act: bool = False):
    h = x
    n = len(weights)
    for i, (w, b) in enumerate(zip(weights, biases)):
        h = jnp.einsum("...d,df->...f", h, w) + b
        if i < n - 1 or final_act:
            h = act(h)
    return h


# ------------------------------------------------------------------- RoPE
def rope_frequencies(d_head: int, max_pos: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))
    pos = jnp.arange(max_pos, dtype=jnp.float32)
    ang = pos[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)          # (max_pos, d_head/2)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: (..., L, D) with D even; positions: (..., L) absolute positions."""
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., L, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def causal_mask(lq: int, lk: int, q_offset: int = 0,
                window: Optional[int] = None) -> jax.Array:
    q_pos = jnp.arange(lq) + q_offset
    k_pos = jnp.arange(lk)
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits (..., V), labels (...) int — mean CE in fp32."""
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(
        logits32, labels[..., None], axis=-1
    )[..., 0]
    return jnp.mean(logz - gold)
