"""Decoder-only transformer family covering the assigned LM archs.

Attention variants (selected per config):
  * ``gqa``  — grouped-query attention with RoPE (granite, moonshot,
               stablelm; danube sets ``window`` = sliding-window attention)
  * ``mla``  — multi-head latent attention (minicpm3): queries/keys/values
               projected through low-rank latents; the KV cache stores only
               the compressed latent + shared rope key (DeepSeek-V2 style).

FFN variants: dense SwiGLU, or mixture-of-experts (GShard-style capacity
dispatch entirely in einsums, shardable over an expert axis).

Layers are scanned (stacked params) so the HLO is O(1) in depth — essential
for 62-layer configs compiled for 512 devices.

Serving uses FIXED-length cache buffers + ``dynamic_update_slice`` (one
compiled program serves every position), masked by absolute position:
  ``train_step``   — loss + grads + AdamW update (train_4k cells)
  ``prefill``      — full-sequence forward returning logits + cache
  ``decode_step``  — one-token step against a cache (decode/long cells)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    apply_rope,
    dense_init,
    embed_init,
    rms_norm,
    softmax_cross_entropy,
)
from repro.parallel.hints import BATCH, TP, shard_hint

PyTree = Any


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    n_shared: int = 0             # shared (always-on) experts
    group_size: int = 1024        # GShard dispatch group (see _moe_ffn)
    # Pad the expert count so it divides the expert-parallel mesh axis
    # (e.g. granite's 40e → 48 on a 16-way axis).  Padded experts get
    # -inf router logits and are never routed; their weights are dead
    # rows that let BOTH the weights and the (g,e,c,d) activation blocks
    # shard over the model axis (ff-TP keeps all E per device otherwise).
    pad_experts_to: Optional[int] = None

    @property
    def padded_experts(self) -> int:
        return max(self.num_experts, self.pad_experts_to or 0)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    d_head_nope: int = 64
    d_head_rope: int = 32
    d_head_v: int = 64


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None          # default d_model // n_heads
    attention: str = "gqa"                # "gqa" | "mla"
    window: Optional[int] = None          # sliding-window size (SWA)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    remat: bool = True                    # activation checkpoint per layer
    # MLA serving: absorb W_uk/W_uv into q/out projections so decode runs
    # in the latent space (no per-step per-head K/V decompression).
    mla_absorbed: bool = True
    # Query-chunked attention (scan over query blocks): caps the live
    # (b, h, chunk, Lk) score tensor — the XLA-level flash attention.
    # None disables; used when Lq > attn_chunk and Lq % attn_chunk == 0.
    attn_chunk: Optional[int] = 1024
    # Chunked cross-entropy: the training loss projects hidden states to
    # logits chunk-by-chunk (rematted), so the (B·L, V) fp32 logits are
    # never materialized.  Opt-in (None = full-logit CE): measured on the
    # dry-run metric it did NOT reduce per-device temp (XLA stacked the
    # chunk inputs and cotangents instead — EXPERIMENTS §Perf, refuted).
    ce_chunk: Optional[int] = None

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a 128 multiple: TPU lane alignment AND the
        divisibility pjit needs to shard embeddings over the model axis.
        ``param_count`` keeps the true vocab; padded logit columns are
        masked to -inf before the loss/sampler sees them."""
        return ((self.vocab + 127) // 128) * 128

    @property
    def sub_quadratic(self) -> bool:
        """True if serve memory is o(L) in context length (SWA ring cache)."""
        return self.window is not None

    def param_count(self) -> int:
        """Total parameters (for 6·N·D roofline bookkeeping)."""
        d, v = self.d_model, self.vocab
        hd = self.head_dim
        if self.attention == "mla":
            m = self.mla or MLAConfig()
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.d_head_nope + m.d_head_rope)
                + d * (m.kv_lora_rank + m.d_head_rope)
                + m.kv_lora_rank * self.n_heads * (m.d_head_nope + m.d_head_v)
                + self.n_heads * m.d_head_v * d
            )
        else:
            attn = (
                d * self.n_heads * hd
                + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d
            )
        if self.moe is not None:
            ffn = (
                d * self.moe.num_experts
                + (self.moe.num_experts + self.moe.n_shared)
                * 3 * d * self.moe.d_ff_expert
            )
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return v * d + self.n_layers * per_layer + d + d * v

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        unused = (
            self.moe.num_experts - self.moe.top_k
        ) * 3 * self.d_model * self.moe.d_ff_expert
        return full - self.n_layers * unused


# ---------------------------------------------------------------- params
def init_params(cfg: TransformerConfig, key: jax.Array) -> PyTree:
    d, hd = cfg.d_model, cfg.head_dim
    keys = jax.random.split(key, 16)
    L = cfg.n_layers

    def stack(f, k):
        if L == 0:          # cost-probe configs: empty layer stack
            single = jax.eval_shape(f, k)
            return jnp.zeros((0,) + single.shape, single.dtype)
        ks = jax.random.split(k, L)
        return jax.vmap(f)(ks)

    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.padded_vocab, d, cfg.dtype),
        "final_norm": jnp.ones((d,), cfg.dtype),
        "lm_head": dense_init(keys[1], d, cfg.padded_vocab, cfg.dtype),
    }
    if cfg.attention == "mla":
        m = cfg.mla or MLAConfig()
        layers = {
            "norm_attn": jnp.ones((L, d), cfg.dtype),
            "norm_ffn": jnp.ones((L, d), cfg.dtype),
            "q_a": stack(lambda k: dense_init(k, d, m.q_lora_rank, cfg.dtype), keys[2]),
            "q_a_norm": jnp.ones((L, m.q_lora_rank), cfg.dtype),
            "q_b": stack(
                lambda k: dense_init(
                    k, m.q_lora_rank,
                    cfg.n_heads * (m.d_head_nope + m.d_head_rope), cfg.dtype
                ),
                keys[3],
            ),
            "kv_a": stack(
                lambda k: dense_init(
                    k, d, m.kv_lora_rank + m.d_head_rope, cfg.dtype
                ),
                keys[4],
            ),
            "kv_a_norm": jnp.ones((L, m.kv_lora_rank), cfg.dtype),
            "kv_b": stack(
                lambda k: dense_init(
                    k, m.kv_lora_rank,
                    cfg.n_heads * (m.d_head_nope + m.d_head_v), cfg.dtype
                ),
                keys[5],
            ),
            "o": stack(
                lambda k: dense_init(k, cfg.n_heads * m.d_head_v, d, cfg.dtype),
                keys[6],
            ),
        }
    else:
        layers = {
            "norm_attn": jnp.ones((L, d), cfg.dtype),
            "norm_ffn": jnp.ones((L, d), cfg.dtype),
            "wq": stack(lambda k: dense_init(k, d, cfg.n_heads * hd, cfg.dtype), keys[2]),
            "wk": stack(lambda k: dense_init(k, d, cfg.n_kv_heads * hd, cfg.dtype), keys[3]),
            "wv": stack(lambda k: dense_init(k, d, cfg.n_kv_heads * hd, cfg.dtype), keys[4]),
            "wo": stack(lambda k: dense_init(k, cfg.n_heads * hd, d, cfg.dtype), keys[5]),
        }
    if cfg.moe is not None:
        e, ff = cfg.moe.padded_experts, cfg.moe.d_ff_expert

        def expert_stack(k, fan_in, fan_out):
            if L == 0:
                return jnp.zeros((0, e, fan_in, fan_out), cfg.dtype)
            ks = jax.random.split(k, L)
            return jax.vmap(
                lambda kk: jax.vmap(
                    lambda k3: dense_init(k3, fan_in, fan_out, cfg.dtype)
                )(jax.random.split(kk, e))
            )(ks)

        layers.update({
            "router": stack(lambda k: dense_init(k, d, e, cfg.dtype), keys[7]),  # e = padded
            "w_gate": expert_stack(keys[8], d, ff),                  # (L,E,d,ff)
            "w_up": expert_stack(keys[9], d, ff),
            "w_down": jnp.swapaxes(expert_stack(keys[10], d, ff), -1, -2),
        })
        if cfg.moe.n_shared:
            sff = ff * cfg.moe.n_shared
            layers.update({
                "shared_gate": stack(lambda k: dense_init(k, d, sff, cfg.dtype), keys[11]),
                "shared_up": stack(lambda k: dense_init(k, d, sff, cfg.dtype), keys[12]),
                "shared_down": stack(lambda k: dense_init(k, sff, d, cfg.dtype), keys[13]),
            })
    else:
        layers.update({
            "w_gate": stack(lambda k: dense_init(k, d, cfg.d_ff, cfg.dtype), keys[7]),
            "w_up": stack(lambda k: dense_init(k, d, cfg.d_ff, cfg.dtype), keys[8]),
            "w_down": stack(lambda k: dense_init(k, cfg.d_ff, d, cfg.dtype), keys[9]),
        })
    params["layers"] = layers
    return params


# ------------------------------------------------------------- attention
def _mask_for(l: int, lk: int, q_pos: jax.Array, window: Optional[int]):
    """(l, lk) bool mask from absolute query positions (traced OK)."""
    k_pos = jnp.arange(lk)
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def _chunked_softmax_attn(cfg, q_list, k_list, v_ctx, q_pos, scale):
    """Masked softmax attention with optional query chunking.

    ``q_list``/``k_list`` are matching lists of (query, key) tensor pairs
    whose score contributions are summed — one pair for GQA
    ((b,l,h,e)·(b,m,h,e)), two for MLA (nope-latent + rope).  ``v_ctx`` is
    (b, m, h, e) or (b, m, r).  Scores for a chunk are (b, h, c, m) fp32 —
    chunking caps the live score buffer at c·m instead of l·m, which is
    what lets 32k-token cells fit HBM (§Perf hillclimb 2 v5).  On TPU the
    Pallas flash kernel replaces this for serving; this path keeps the
    backward pass free for training.
    """

    def score(qc, q_pos_c):
        sc = None
        for qq, kk in zip(qc, k_list):
            contract = "bchx,bmhx->bhcm" if kk.ndim == 4 else "bchx,bmx->bhcm"
            term = jnp.einsum(contract, qq, kk,
                              preferred_element_type=jnp.float32)
            sc = term if sc is None else sc + term
        sc = sc * scale
        lk = k_list[0].shape[1]
        mask = _mask_for(qc[0].shape[1], lk, q_pos_c, cfg.window)
        sc = jnp.where(mask[None, None], sc, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        contract = "bhcm,bmhx->bchx" if v_ctx.ndim == 4 else "bhcm,bmx->bchx"
        return jnp.einsum(
            contract, p, v_ctx.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    l = q_list[0].shape[1]
    chunk = cfg.attn_chunk
    if not chunk or l <= chunk or l % chunk:
        return score(q_list, q_pos)

    n_c = l // chunk

    # remat the chunk: without it the scan's backward saves every chunk's
    # fp32 softmax — stacked across chunks that is the full (b,h,l,m)
    # score tensor again (~26GB/device at 4k×1M-token train), defeating
    # the chunking.  Recompute-in-backward caps live scores at one chunk.
    score_ckpt = jax.checkpoint(score)

    def body(carry, xs):
        qs, qp = xs
        return carry, score_ckpt(list(qs), qp)

    qs_chunked = tuple(
        q.reshape(q.shape[0], n_c, chunk, *q.shape[2:]).swapaxes(0, 1)
        for q in q_list
    )
    qp_chunked = q_pos.reshape(n_c, chunk)
    _, out = jax.lax.scan(body, None, (qs_chunked, qp_chunked))
    # out: (n_c, b, chunk, h, x) → (b, l, h, x)
    out = out.swapaxes(0, 1).reshape(out.shape[1], l, *out.shape[3:])
    return out


def _gqa_attention(
    cfg: TransformerConfig,
    lp: PyTree,
    x: jax.Array,                 # (B, L, d)
    q_pos: jax.Array,             # (L,) absolute positions (traced)
    cache: Optional[jax.Array],   # (2, B, S, hkv, hd) fixed buffer or None
    cache_pos,                    # scalar: where to write this block
):
    b, l, d = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = shard_hint(
        jnp.einsum("bld,dh->blh", x, lp["wq"]).reshape(b, l, hq, hd),
        BATCH, None, TP, None,
    )
    k = shard_hint(
        jnp.einsum("bld,dh->blh", x, lp["wk"]).reshape(b, l, hkv, hd),
        BATCH, None, TP, None,
    )
    v = shard_hint(
        jnp.einsum("bld,dh->blh", x, lp["wv"]).reshape(b, l, hkv, hd),
        BATCH, None, TP, None,
    )
    q = apply_rope(q.swapaxes(1, 2), q_pos[None, None, :], cfg.rope_theta).swapaxes(1, 2)
    k = apply_rope(k.swapaxes(1, 2), q_pos[None, None, :], cfg.rope_theta).swapaxes(1, 2)
    if cache is not None:
        kc = jax.lax.dynamic_update_slice(cache[0], k, (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache[1], v, (0, cache_pos, 0, 0))
        k_full, v_full = kc, vc
        new_cache = jnp.stack([kc, vc], axis=0)
    else:
        k_full, v_full = k, v
        new_cache = None
    group = hq // hkv
    kr = jnp.repeat(k_full, group, axis=2)
    vr = jnp.repeat(v_full, group, axis=2)
    # sequence-parallel keys: heads rarely divide the TP axis (24 vs 16),
    # so shard the KEY/VALUE sequence axis instead — scores become
    # (b, h, c, m/TP) and softmax runs distributed over the key shards.
    kr = shard_hint(kr, BATCH, TP, None, None)
    vr = shard_hint(vr, BATCH, TP, None, None)
    scale = 1.0 / (hd ** 0.5)
    o = _chunked_softmax_attn(
        cfg, [q], [kr], vr, q_pos, scale
    ).astype(x.dtype).reshape(b, l, hq * hd)
    return jnp.einsum("blh,hd->bld", o, lp["wo"]), new_cache


def _mla_attention(
    cfg: TransformerConfig,
    lp: PyTree,
    x: jax.Array,
    q_pos: jax.Array,
    cache: Optional[jax.Array],   # (B, S, kv_rank + d_rope) or None
    cache_pos,
):
    m = cfg.mla or MLAConfig()
    b, l, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.d_head_nope, m.d_head_rope, m.d_head_v
    qa = rms_norm(jnp.einsum("bld,dr->blr", x, lp["q_a"]), lp["q_a_norm"])
    qb = jnp.einsum("blr,rh->blh", qa, lp["q_b"]).reshape(b, l, h, dn + dr)
    q_nope, q_rope = qb[..., :dn], qb[..., dn:]
    q_rope = apply_rope(
        q_rope.swapaxes(1, 2), q_pos[None, None, :], cfg.rope_theta
    ).swapaxes(1, 2)
    kva = jnp.einsum("bld,dr->blr", x, lp["kv_a"])
    c_kv = rms_norm(kva[..., : m.kv_lora_rank], lp["kv_a_norm"])
    k_rope = apply_rope(
        kva[..., m.kv_lora_rank:][:, None], q_pos[None, None, :],
        cfg.rope_theta,
    )[:, 0]                                           # (B, L, dr)
    latent = jnp.concatenate([c_kv, k_rope], axis=-1)
    if cache is not None:
        latent_full = jax.lax.dynamic_update_slice(
            cache, latent, (0, cache_pos, 0)
        )
        new_cache = latent_full
    else:
        latent_full = latent
        new_cache = None
    lk = latent_full.shape[1]
    c_full = latent_full[..., : m.kv_lora_rank]
    krope_full = latent_full[..., m.kv_lora_rank:]
    scale = 1.0 / ((dn + dr) ** 0.5)

    if cfg.mla_absorbed and cache is not None:
        # DeepSeek-V2 "absorbed" serving path: fold W_uk into the query and
        # W_uv into the output so attention runs in the rank-r latent
        # space.  The naive path below decompresses per-head K/V for ALL
        # cache positions on every step — a (b, lk, h, dn+dv) intermediate
        # and 2·b·lk·r·h·(dn+dv) FLOPs per token; absorbed needs neither
        # (§Perf hillclimb 3).
        kvb_w = lp["kv_b"].reshape(m.kv_lora_rank, h, dn + dv)
        w_uk, w_uv = kvb_w[..., :dn], kvb_w[..., dn:]
        q_abs = jnp.einsum("blhe,rhe->blhr", q_nope, w_uk)   # (b,l,h,r)
        ctx = _chunked_softmax_attn(
            cfg, [q_abs, q_rope], [c_full, krope_full], c_full, q_pos, scale
        ).astype(x.dtype)                                    # (b,l,h,r)
        o = jnp.einsum("blhr,rhe->blhe", ctx, w_uv).reshape(b, l, h * dv)
        return jnp.einsum("blh,hd->bld", o, lp["o"]), new_cache

    kvb = jnp.einsum("bmr,rh->bmh", c_full, lp["kv_b"]).reshape(
        b, lk, h, dn + dv
    )
    k_nope, v_lat = kvb[..., :dn], kvb[..., dn:]
    o = _chunked_softmax_attn(
        cfg, [q_nope, q_rope], [k_nope, krope_full], v_lat, q_pos, scale
    ).astype(x.dtype).reshape(b, l, h * dv)
    return jnp.einsum("blh,hd->bld", o, lp["o"]), new_cache


# ------------------------------------------------------------------ MoE
def _moe_ffn(cfg: TransformerConfig, lp: PyTree, x: jax.Array) -> jax.Array:
    """GShard-style grouped capacity dispatch, all einsums.

    Tokens are split into groups of ``group_size`` before the one-hot
    dispatch (GShard's G axis): a flat dispatch matmul over T global
    tokens costs 1.25·T²·k·d FLOPs — quadratic in T, ~500× the expert
    FLOPs at T=1M — while grouped dispatch costs 1.25·T·g·k·d, a small
    constant factor of the expert compute for g≈1k.  The group axis also
    carries the data-parallel sharding; experts shard over the model axis
    (EP) with an all-to-all materializing (g, e, c, d) blocks.
    """
    moe = cfg.moe
    b, l, d = x.shape
    t = b * l
    g_sz = min(moe.group_size, t)
    n_g = t // g_sz
    assert n_g * g_sz == t, f"tokens {t} not divisible by group {g_sz}"
    xt = shard_hint(x.reshape(n_g, g_sz, d), BATCH, None, None)
    logits = jnp.einsum("gtd,de->gte", xt, lp["router"]).astype(jnp.float32)
    e = moe.padded_experts
    if e != moe.num_experts:   # mask padded experts out of routing
        dead = jnp.arange(e) >= moe.num_experts
        logits = jnp.where(dead[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, moe.top_k)       # (g, t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )
    # capacity is a property of the REAL expert count — padding must not
    # change which tokens are dropped
    cap = max(1, int(moe.capacity_factor * g_sz * moe.top_k
                     / moe.num_experts))
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)       # (g, t, k, e)
    flat = onehot.reshape(n_g, g_sz * moe.top_k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                          # (g, t*k, e)
    pos = (pos * flat).sum(axis=-1).reshape(n_g, g_sz, moe.top_k)
    keep = pos < cap
    disp = (
        jax.nn.one_hot(gate_idx, e, dtype=x.dtype)
        * keep[..., None].astype(x.dtype)
    )                                                           # (g, t, k, e)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype)            # (g, t, k, c)
    # contraction over k via explicit batched dot_general: an einsum here
    # can lower to a broadcast (g,t,k,e,c) intermediate — 17 GB/device at
    # this cell's shapes (measured) — instead of a tiny batched GEMM.
    gt = n_g * g_sz

    def _k_contract(a, b):                                      # (gt,k,e)x(gt,k,c)
        out = jax.lax.dot_general(
            a.reshape(gt, moe.top_k, e),
            b.reshape(gt, moe.top_k, cap),
            (((1,), (1,)), ((0,), (0,))),
        )
        return out.reshape(n_g, g_sz, e, cap)

    dispatch = shard_hint(
        _k_contract(disp, pos_oh),                              # (g, t, e, c)
        BATCH, None, TP, None,
    )
    combine = shard_hint(
        _k_contract(disp * gate_vals.astype(x.dtype)[..., None], pos_oh),
        BATCH, None, TP, None,
    )
    x_e = shard_hint(
        jnp.einsum("gtec,gtd->gecd", dispatch, xt),             # (g, e, c, d)
        BATCH, TP, None, None,
    )
    hg = jnp.einsum("gecd,edf->gecf", x_e, lp["w_gate"])
    hu = jnp.einsum("gecd,edf->gecf", x_e, lp["w_up"])
    h = jax.nn.silu(hg.astype(jnp.float32)).astype(x.dtype) * hu
    y_e = shard_hint(
        jnp.einsum("gecf,efd->gecd", h, lp["w_down"]),
        BATCH, TP, None, None,
    )
    out = shard_hint(
        jnp.einsum("gtec,gecd->gtd", combine, y_e), BATCH, None, None
    )
    if moe.n_shared:
        sg = jnp.einsum("gtd,df->gtf", xt, lp["shared_gate"])
        su = jnp.einsum("gtd,df->gtf", xt, lp["shared_up"])
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        out = out + jnp.einsum("gtf,fd->gtd", sh, lp["shared_down"])
    return out.reshape(b, l, d)


def _dense_ffn(lp: PyTree, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bld,df->blf", x, lp["w_gate"])
    u = jnp.einsum("bld,df->blf", x, lp["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("blf,fd->bld", h, lp["w_down"])


# ---------------------------------------------------------------- forward
def _layer(cfg: TransformerConfig, lp: PyTree, x, q_pos, cache, cache_pos):
    x = shard_hint(x, BATCH, None, None)
    attn_fn = _mla_attention if cfg.attention == "mla" else _gqa_attention
    h, new_cache = attn_fn(
        cfg, lp, rms_norm(x, lp["norm_attn"]), q_pos, cache, cache_pos
    )
    x = shard_hint(x + h, BATCH, None, None)
    ffn_in = rms_norm(x, lp["norm_ffn"])
    ffn = _moe_ffn(cfg, lp, ffn_in) if cfg.moe is not None else _dense_ffn(lp, ffn_in)
    return shard_hint(x + ffn, BATCH, None, None), new_cache


def forward(
    cfg: TransformerConfig,
    params: PyTree,
    tokens: jax.Array,                       # (B, L)
    *,
    caches: Optional[PyTree] = None,         # stacked fixed buffers or None
    cache_pos=0,                             # write offset == query offset
) -> Tuple[jax.Array, Optional[PyTree]]:
    b, l = tokens.shape
    x = shard_hint(params["embed"][tokens], BATCH, None, None)
    q_pos = jnp.arange(l) + cache_pos

    if caches is None:
        layer_fn = _layer
        if cfg.remat:
            layer_fn = jax.checkpoint(
                _layer, static_argnums=(0,),
                policy=jax.checkpoint_policies.nothing_saveable,
            )

        def body(carry, lp):
            h, _ = layer_fn(cfg, lp, carry, q_pos, None, 0)
            return h, None

        x, _ = jax.lax.scan(body, x, params["layers"])
        caches_out = None
    else:
        def body(carry, scanned):
            lp, cache = scanned
            h, cache_out = _layer(cfg, lp, carry, q_pos, cache, cache_pos)
            return h, cache_out

        x, caches_out = jax.lax.scan(body, x, (params["layers"], caches))

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bld,dv->blv", x, params["lm_head"])
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return logits, caches_out


# --------------------------------------------------------------- entry points
def hidden_states(cfg: TransformerConfig, params: PyTree, tokens: jax.Array):
    """Forward pass up to the final norm — no unembedding."""
    b, l = tokens.shape
    x = shard_hint(params["embed"][tokens], BATCH, None, None)
    q_pos = jnp.arange(l)

    layer_fn = _layer
    if cfg.remat:
        layer_fn = jax.checkpoint(
            _layer, static_argnums=(0,),
            policy=jax.checkpoint_policies.nothing_saveable,
        )

    def body(carry, lp):
        h, _ = layer_fn(cfg, lp, carry, q_pos, None, 0)
        return h, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return rms_norm(x, params["final_norm"])


def chunked_ce_loss(cfg, params, tokens, labels):
    """CE computed chunk-by-chunk over tokens: logits for a chunk are
    projected, reduced, and (being rematted) never stored for backward —
    the peak live logit buffer is (ce_chunk, V) instead of (B·L, V)."""
    x = hidden_states(cfg, params, tokens)
    b, l, d = x.shape
    t = b * l
    xt = x.reshape(t, d)
    yt = labels.reshape(t)
    chunk = cfg.ce_chunk
    if not chunk or t <= chunk or t % chunk:
        logits = jnp.einsum("td,dv->tv", xt, params["lm_head"])
        if cfg.padded_vocab != cfg.vocab:
            pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
            logits = jnp.where(pad[None, :], -1e30, logits)
        return softmax_cross_entropy(logits, yt)

    n_c = t // chunk
    xc = xt.reshape(n_c, chunk, d)
    yc = yt.reshape(n_c, chunk)

    @jax.checkpoint
    def chunk_ce(args):
        xs, ys = args
        logits = jnp.einsum(
            "td,dv->tv", xs, params["lm_head"]
        ).astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab:
            pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
            logits = jnp.where(pad[None, :], -1e30, logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ys[:, None], axis=-1)[:, 0]
        return jnp.sum(logz - gold)

    def body(acc, args):
        return acc + chunk_ce(args), None

    total, _ = jax.lax.scan(body, jnp.asarray(0.0, jnp.float32), (xc, yc))
    return total / t


def loss_fn(cfg, params, tokens, labels):
    if cfg.ce_chunk:
        return chunked_ce_loss(cfg, params, tokens, labels)
    logits, _ = forward(cfg, params, tokens)
    return softmax_cross_entropy(logits, labels)


def make_train_step(cfg: TransformerConfig, optimizer):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch["tokens"], batch["labels"])
        )(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def make_prefill(cfg: TransformerConfig):
    """Full-sequence forward + cache build (prefill_32k cells)."""

    def prefill(params, tokens, caches):
        logits, caches = forward(cfg, params, tokens, caches=caches,
                                 cache_pos=0)
        return logits[:, -1], caches

    return prefill


def make_decode_step(cfg: TransformerConfig):
    """One-token decode against stacked fixed-length caches.

    GQA cache: (L, 2, B, S, hkv, hd); MLA: (L, B, S, kv_rank+d_rope).
    ``cache_len`` is a traced scalar — one compiled program serves every
    position.
    """

    def decode_step(params, caches, token, cache_len):
        logits, new_caches = forward(
            cfg, params, token, caches=caches, cache_pos=cache_len
        )
        return logits[:, -1], new_caches

    return decode_step


def init_cache(cfg: TransformerConfig, batch: int, length: int, dtype=None):
    dtype = dtype or cfg.dtype
    if cfg.attention == "mla":
        m = cfg.mla or MLAConfig()
        return jnp.zeros(
            (cfg.n_layers, batch, length, m.kv_lora_rank + m.d_head_rope),
            dtype,
        )
    return jnp.zeros(
        (cfg.n_layers, 2, batch, length, cfg.n_kv_heads, cfg.head_dim), dtype
    )


def cache_spec(cfg: TransformerConfig, batch: int, length: int, dtype=None):
    """ShapeDtypeStruct stand-in for the cache (dry-run input spec)."""
    dtype = dtype or cfg.dtype
    if cfg.attention == "mla":
        m = cfg.mla or MLAConfig()
        return jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, length, m.kv_lora_rank + m.d_head_rope),
            dtype,
        )
    return jax.ShapeDtypeStruct(
        (cfg.n_layers, 2, batch, length, cfg.n_kv_heads, cfg.head_dim), dtype
    )
