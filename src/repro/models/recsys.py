"""Wide & Deep recsys arch (Cheng et al. 2016).

Embedding tables are the hot path: 40 sparse fields, one row-offset stacked
table (lookup = ``embedding_bag`` kernel; JAX has no native EmbeddingBag —
``jnp.take`` + segment-reduce / the Pallas kernel IS the implementation).

Shapes served:
  * train_batch / serve_*: (B, n_sparse) categorical ids + (B, n_dense)
    floats → CTR logit (wide linear ⊕ deep MLP, concat interaction).
  * retrieval_cand: one query embedding against 10⁶ candidate vectors —
    a single (1, D)×(D, C) matmul, NOT a loop.

Sharding: table rows over the ``model`` axis (vocab-sharded), batch over
``data``×``pod``; the per-device lookup hits only local rows and partial
results are summed (see launch/shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, embed_init, mlp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class WideDeepConfig:
    name: str
    n_sparse: int = 40
    n_dense: int = 13
    embed_dim: int = 32
    vocab_per_field: int = 100_000
    mlp_dims: Tuple[int, ...] = (1024, 512, 256)
    dtype: Any = jnp.float32

    @property
    def total_vocab(self) -> int:
        return self.n_sparse * self.vocab_per_field

    @property
    def deep_in(self) -> int:
        return self.n_sparse * self.embed_dim + self.n_dense

    def param_count(self) -> int:
        total = self.total_vocab * self.embed_dim + self.total_vocab  # tables
        dims = (self.deep_in,) + self.mlp_dims + (1,)
        for a, b in zip(dims[:-1], dims[1:]):
            total += a * b + b
        total += self.n_dense + 1
        return total


def widedeep_init(cfg: WideDeepConfig, key) -> PyTree:
    ks = jax.random.split(key, 4 + len(cfg.mlp_dims) + 1)
    dims = (cfg.deep_in,) + cfg.mlp_dims + (1,)
    return {
        # deep embedding table, all fields stacked with row offsets
        "table": embed_init(ks[0], cfg.total_vocab, cfg.embed_dim, cfg.dtype),
        # wide: one scalar weight per categorical value (linear over one-hot)
        "wide_table": jnp.zeros((cfg.total_vocab,), cfg.dtype),
        "wide_dense": jnp.zeros((cfg.n_dense,), cfg.dtype),
        "bias": jnp.zeros((), cfg.dtype),
        "mlp_w": [
            dense_init(k, a, b, cfg.dtype)
            for k, a, b in zip(ks[1:], dims[:-1], dims[1:])
        ],
        "mlp_b": [jnp.zeros((b,), cfg.dtype) for b in dims[1:]],
    }


def _offset_ids(cfg: WideDeepConfig, sparse_ids: jax.Array) -> jax.Array:
    """(B, n_sparse) per-field ids → global rows in the stacked table."""
    offsets = (
        jnp.arange(cfg.n_sparse, dtype=sparse_ids.dtype) * cfg.vocab_per_field
    )
    return sparse_ids + offsets[None, :]


def widedeep_forward(
    cfg: WideDeepConfig, params, sparse_ids: jax.Array, dense_feats: jax.Array
) -> jax.Array:
    """CTR logits (B,).  sparse_ids (B, n_sparse), dense (B, n_dense)."""
    rows = _offset_ids(cfg, sparse_ids)                   # (B, F)
    emb = params["table"][rows]                           # (B, F, D) gather
    deep_in = jnp.concatenate(
        [emb.reshape(emb.shape[0], -1), dense_feats], axis=-1
    )
    deep = mlp(deep_in, params["mlp_w"], params["mlp_b"], act=jax.nn.relu)
    wide = (
        params["wide_table"][rows].sum(axis=-1)
        + jnp.einsum("bd,d->b", dense_feats, params["wide_dense"])
    )
    return deep[..., 0] + wide + params["bias"]


def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits32 = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits32, 0.0)
        - logits32 * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits32)))
    )


def make_train_step(cfg: WideDeepConfig, optimizer):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits = widedeep_forward(
                cfg, p, batch["sparse"], batch["dense"]
            )
            return bce_loss(logits, batch["labels"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def make_serve(cfg: WideDeepConfig):
    def serve(params, sparse_ids, dense_feats):
        return jax.nn.sigmoid(
            widedeep_forward(cfg, params, sparse_ids, dense_feats)
        )

    return serve


# ------------------------------------------------------------- retrieval
def user_tower(cfg: WideDeepConfig, params, sparse_ids, dense_feats):
    """Query embedding = last deep hidden layer (dim mlp_dims[-1])."""
    rows = _offset_ids(cfg, sparse_ids)
    emb = params["table"][rows]
    deep_in = jnp.concatenate(
        [emb.reshape(emb.shape[0], -1), dense_feats], axis=-1
    )
    h = deep_in
    for w, b in zip(params["mlp_w"][:-1], params["mlp_b"][:-1]):
        h = jax.nn.relu(jnp.einsum("bd,df->bf", h, w) + b)
    return h                                               # (B, mlp_dims[-1])


def make_retrieval_scorer(cfg: WideDeepConfig):
    """Score ONE query against C candidate vectors with a single matmul."""

    def score(params, sparse_ids, dense_feats, candidates):
        # sparse_ids (1, F); candidates (C, mlp_dims[-1])
        q = user_tower(cfg, params, sparse_ids, dense_feats)   # (1, D)
        return jnp.einsum("bd,cd->bc", q, candidates)[0]       # (C,)

    return score
