from repro.models import gnn, recsys, transformer

__all__ = ["gnn", "recsys", "transformer"]
