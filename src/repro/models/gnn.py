"""GNN arch pool: GCN, GAT, DimeNet, MeshGraphNet.

All four run in the SpMM / gather-scatter regime over the shared graph
substrate (``repro.graph``): message passing is ``segment_sum`` over an
edge-index scatter — exactly the same primitive the LP core uses, which is
why these archs share kernels with the paper's technique (DESIGN.md §5).

Two execution modes:
  * full-graph (cora / ogb_products cells): edge lists over all nodes;
  * sampled minibatch (minibatch_lg cell): fanout blocks from
    ``repro.graph.NeighborSampler`` (GraphSAGE-style hop aggregation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.graph.segment import (
    scatter_spmm,
    segment_mean,
    segment_softmax,
    segment_sum,
)
from repro.models.common import dense_init, layer_norm, mlp

PyTree = Any


# ===================================================================== GCN
@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 16
    d_feat: int = 1433
    n_classes: int = 7
    dtype: Any = jnp.float32


def gcn_init(cfg: GCNConfig, key) -> PyTree:
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, len(dims) - 1)
    return {
        "w": [dense_init(k, a, b, cfg.dtype) for k, a, b in
              zip(keys, dims[:-1], dims[1:])],
        "b": [jnp.zeros((b,), cfg.dtype) for b in dims[1:]],
    }


def gcn_forward(cfg: GCNConfig, params, feats, src, dst, w, num_nodes):
    """feats (N,F); (src,dst,w) = sym-normalized adjacency w/ self loops."""
    h = feats
    for i, (W, b) in enumerate(zip(params["w"], params["b"])):
        h = scatter_spmm(src, dst, w, h, num_nodes)      # Ã h
        h = jnp.einsum("nf,fg->ng", h, W) + b
        if i < len(params["w"]) - 1:
            h = jax.nn.relu(h)
    return h


# ===================================================================== GAT
@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_feat: int = 1433
    n_classes: int = 7
    dtype: Any = jnp.float32


def gat_init(cfg: GATConfig, key) -> PyTree:
    keys = jax.random.split(key, 2 * cfg.n_layers)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        layers.append({
            "w": dense_init(keys[2 * i], d_in, heads * d_out, cfg.dtype),
            "a_src": 0.1 * dense_init(keys[2 * i + 1], heads, d_out, cfg.dtype),
            "a_dst": 0.1 * dense_init(keys[2 * i + 1], heads, d_out, cfg.dtype),
        })
        d_in = heads * d_out if not last else d_out
    return {"layers": layers}


def gat_forward(cfg: GATConfig, params, feats, src, dst, num_nodes):
    """SDDMM (edge scores) → segment-softmax → SpMM, per layer."""
    h = feats
    n_layers = len(params["layers"])
    for i, lp in enumerate(params["layers"]):
        last = i == n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        hw = jnp.einsum("nf,fg->ng", h, lp["w"]).reshape(-1, heads, d_out)
        e_src = jnp.einsum("nhd,hd->nh", hw, lp["a_src"])   # (N, H)
        e_dst = jnp.einsum("nhd,hd->nh", hw, lp["a_dst"])
        scores = jax.nn.leaky_relu(
            e_src[src] + e_dst[dst], negative_slope=0.2
        )                                                    # (E, H)
        alpha = jax.vmap(
            lambda s: segment_softmax(s, dst, num_nodes), in_axes=1, out_axes=1
        )(scores)                                            # (E, H)
        msgs = alpha[:, :, None] * hw[src]                   # (E, H, D)
        agg = segment_sum(
            msgs.reshape(msgs.shape[0], heads * d_out), dst, num_nodes
        ).reshape(-1, heads, d_out)
        h = agg.reshape(-1, heads * d_out)
        if not last:
            h = jax.nn.elu(h)
        else:
            h = agg.mean(axis=1) if heads > 1 else h.reshape(-1, d_out)
    return h


# ================================================================= DimeNet
@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    n_species: int = 16
    cutoff: float = 5.0
    out_dim: int = 1
    dtype: Any = jnp.float32


def _rbf(d: jax.Array, n_radial: int, cutoff: float) -> jax.Array:
    """DimeNet radial basis: sin(nπ d/c)/d with smooth cutoff envelope."""
    d = jnp.maximum(d, 1e-6)[:, None]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)[None, :]
    u = d / cutoff
    env = 1.0 - 6.0 * u**5 + 15.0 * u**4 - 10.0 * u**3   # C² envelope
    return env * jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * u) / d


def _sbf(d: jax.Array, angle: jax.Array, n_spherical: int, n_radial: int,
         cutoff: float) -> jax.Array:
    """Angular×radial basis (Chebyshev angular × sine radial).

    The original uses spherical Bessel × Legendre; scipy is unavailable
    offline, so we use cos(l·θ) angular modes with the same radial sine
    family — same tensor shape (n_spherical·n_radial), same decay structure
    (noted in DESIGN.md §8 assumption log).
    """
    u = (jnp.maximum(d, 1e-6) / cutoff)[:, None]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)[None, :]
    radial = jnp.sin(n * jnp.pi * u) / (u * cutoff)          # (T, R)
    ls = jnp.arange(n_spherical, dtype=jnp.float32)[None, :]
    angular = jnp.cos(ls * angle[:, None])                   # (T, S)
    out = angular[:, :, None] * radial[:, None, :]           # (T, S, R)
    return out.reshape(d.shape[0], n_spherical * n_radial)


def dimenet_init(cfg: DimeNetConfig, key) -> PyTree:
    ks = jax.random.split(key, 12 + 6 * cfg.n_blocks)
    h, nb = cfg.d_hidden, cfg.n_bilinear
    sph = cfg.n_spherical * cfg.n_radial
    params = {
        "embed_z": 0.1 * dense_init(ks[0], cfg.n_species, h, cfg.dtype),
        "rbf_proj": dense_init(ks[1], cfg.n_radial, h, cfg.dtype),
        "msg_mlp_w": dense_init(ks[2], 3 * h, h, cfg.dtype),
        "msg_mlp_b": jnp.zeros((h,), cfg.dtype),
        "blocks": [],
        "out_w1": dense_init(ks[3], h, h, cfg.dtype),
        "out_w2": dense_init(ks[4], h, cfg.out_dim, cfg.dtype),
    }
    blocks = []
    for i in range(cfg.n_blocks):
        k0 = 5 + 6 * i
        blocks.append({
            "w_src": dense_init(ks[k0], h, h, cfg.dtype),
            "w_kj": dense_init(ks[k0 + 1], h, nb, cfg.dtype),
            "sbf_proj": dense_init(ks[k0 + 2], sph, nb, cfg.dtype),
            "bilinear": 0.1 * jax.random.normal(
                ks[k0 + 3], (nb, nb, h), jnp.float32
            ).astype(cfg.dtype),
            "w_out": dense_init(ks[k0 + 4], h, h, cfg.dtype),
            "w_res": dense_init(ks[k0 + 5], h, h, cfg.dtype),
        })
    params["blocks"] = blocks
    return params


def dimenet_forward(
    cfg: DimeNetConfig,
    params,
    z: jax.Array,           # (N,) species ids
    pos: jax.Array,         # (N, 3)
    edge_src: jax.Array,    # (E,) j  (message j→i)
    edge_dst: jax.Array,    # (E,) i
    tri_kj: jax.Array,      # (T,) edge index of k→j
    tri_ji: jax.Array,      # (T,) edge index of j→i
    tri_mask: jax.Array,    # (T,) bool (padding)
    graph_ids: jax.Array,   # (N,) graph id per node (batched molecules)
    num_graphs: int,
):
    num_nodes = z.shape[0]
    vec = pos[edge_dst] - pos[edge_src]                   # (E, 3)
    dist = jnp.sqrt(jnp.sum(vec**2, axis=-1) + 1e-12)
    rbf = _rbf(dist, cfg.n_radial, cfg.cutoff)            # (E, R)
    # angle between edge kj and ji at the shared node j
    v1 = -vec[tri_kj]
    v2 = vec[tri_ji]
    cosang = jnp.sum(v1 * v2, axis=-1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-9
    )
    angle = jnp.arccos(jnp.clip(cosang, -1.0 + 1e-7, 1.0 - 1e-7))
    sbf = _sbf(dist[tri_ji], angle, cfg.n_spherical, cfg.n_radial, cfg.cutoff)

    hz = params["embed_z"][z]                             # (N, H)
    m = jax.nn.silu(
        jnp.einsum("ef,fg->eg",
                   jnp.concatenate(
                       [hz[edge_src], hz[edge_dst],
                        jnp.einsum("er,rh->eh", rbf, params["rbf_proj"])],
                       axis=-1),
                   params["msg_mlp_w"]) + params["msg_mlp_b"]
    )                                                     # (E, H)

    e = edge_src.shape[0]
    for blk in params["blocks"]:
        # directional message passing: m_ji ← f(m_ji, Σ_k sbf ⊙ bilinear(m_kj))
        m_kj = jnp.einsum("eh,hb->eb", m, blk["w_kj"])[tri_kj]   # (T, nb)
        sb = jnp.einsum("ts,sb->tb", sbf, blk["sbf_proj"])       # (T, nb)
        inter = jnp.einsum(
            "tb,tc,bch->th", m_kj, sb, blk["bilinear"]
        )                                                        # (T, H)
        inter = inter * tri_mask[:, None]
        agg = segment_sum(inter, tri_ji, e)                      # (E, H)
        upd = jax.nn.silu(
            jnp.einsum("eh,hg->eg", m, blk["w_src"]) + agg
        )
        m = m + jax.nn.silu(jnp.einsum("eh,hg->eg", upd, blk["w_res"]))

    # per-node readout: sum incoming messages, then per-graph sum
    node_out = segment_sum(m, edge_dst, num_nodes)
    node_out = jax.nn.silu(jnp.einsum("nh,hg->ng", node_out, params["out_w1"]))
    node_energy = jnp.einsum("nh,ho->no", node_out, params["out_w2"])
    return segment_sum(node_energy, graph_ids, num_graphs)       # (G, out)


def build_triplets(
    src, dst, num_nodes: int, max_triplets: Optional[int] = None
):
    """Host-side triplet index construction: for each edge (j→i) and each
    k∈N(j)\\{i}: (edge k→j, edge j→i).  Returns padded int32 arrays."""
    import numpy as np

    src = np.asarray(src)
    dst = np.asarray(dst)
    e = len(src)
    in_edges: List[List[int]] = [[] for _ in range(num_nodes)]
    for eid in range(e):
        in_edges[dst[eid]].append(eid)
    kj, ji = [], []
    for eid in range(e):
        j = src[eid]
        for kj_eid in in_edges[j]:
            if src[kj_eid] == dst[eid]:
                continue  # exclude backtracking k == i
            kj.append(kj_eid)
            ji.append(eid)
    t = len(kj)
    cap = t if max_triplets is None else max_triplets
    kj_a = np.zeros(cap, np.int32)
    ji_a = np.zeros(cap, np.int32)
    mask = np.zeros(cap, bool)
    n = min(t, cap)
    kj_a[:n] = kj[:n]
    ji_a[:n] = ji[:n]
    mask[:n] = True
    return kj_a, ji_a, mask


# ============================================================ MeshGraphNet
@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 8
    d_edge_in: int = 4
    d_out: int = 3
    dtype: Any = jnp.float32


def _mgn_mlp_init(key, d_in, d_hidden, d_out, n_layers, dtype):
    dims = [d_in] + [d_hidden] * (n_layers - 1) + [d_out]
    ks = jax.random.split(key, len(dims))
    return {
        "w": [dense_init(k, a, b, dtype) for k, a, b in
              zip(ks, dims[:-1], dims[1:])],
        "b": [jnp.zeros((b,), dtype) for b in dims[1:]],
        "ln_g": jnp.ones((d_out,), dtype),
        "ln_b": jnp.zeros((d_out,), dtype),
    }


def _mgn_mlp(p, x, norm=True):
    h = mlp(x, p["w"], p["b"], act=jax.nn.relu)
    if norm:
        h = layer_norm(h, p["ln_g"], p["ln_b"])
    return h


def mgn_init(cfg: MGNConfig, key) -> PyTree:
    ks = jax.random.split(key, 3 + 2 * cfg.n_layers)
    h = cfg.d_hidden
    return {
        "node_enc": _mgn_mlp_init(ks[0], cfg.d_node_in, h, h, cfg.mlp_layers, cfg.dtype),
        "edge_enc": _mgn_mlp_init(ks[1], cfg.d_edge_in, h, h, cfg.mlp_layers, cfg.dtype),
        "blocks": [
            {
                "edge": _mgn_mlp_init(ks[2 + 2 * i], 3 * h, h, h, cfg.mlp_layers, cfg.dtype),
                "node": _mgn_mlp_init(ks[3 + 2 * i], 2 * h, h, h, cfg.mlp_layers, cfg.dtype),
            }
            for i in range(cfg.n_layers)
        ],
        "decoder": _mgn_mlp_init(ks[-1], h, h, cfg.d_out, cfg.mlp_layers, cfg.dtype),
    }


def mgn_forward(cfg: MGNConfig, params, node_feat, edge_feat, src, dst,
                num_nodes):
    h = _mgn_mlp(params["node_enc"], node_feat)
    e = _mgn_mlp(params["edge_enc"], edge_feat)
    for blk in params["blocks"]:
        e_in = jnp.concatenate([e, h[src], h[dst]], axis=-1)
        e = e + _mgn_mlp(blk["edge"], e_in)                  # edge update
        agg = segment_sum(e, dst, num_nodes)                 # sum aggregator
        h = h + _mgn_mlp(blk["node"], jnp.concatenate([h, agg], axis=-1))
    return _mgn_mlp(params["decoder"], h, norm=False)


# ================================================ sampled-minibatch (SAGE)
@dataclasses.dataclass(frozen=True)
class SageConfig:
    name: str
    d_feat: int
    d_hidden: int
    n_classes: int
    fanouts: Tuple[int, ...] = (15, 10)
    dtype: Any = jnp.float32


def sage_init(cfg: SageConfig, key) -> PyTree:
    n_hops = len(cfg.fanouts)
    ks = jax.random.split(key, 2 * n_hops + 2)
    return {
        "w_in": dense_init(ks[0], cfg.d_feat, cfg.d_hidden, cfg.dtype),
        "w_nbr": [dense_init(ks[1 + 2 * i], cfg.d_hidden, cfg.d_hidden, cfg.dtype)
                  for i in range(n_hops)],
        "w_self": [dense_init(ks[2 + 2 * i], cfg.d_hidden, cfg.d_hidden, cfg.dtype)
                   for i in range(n_hops)],
        "w_out": dense_init(ks[-1], cfg.d_hidden, cfg.n_classes, cfg.dtype),
    }


def sage_block_forward(cfg: SageConfig, params, feats, hops):
    """GraphSAGE-style hop aggregation over sampled fanout blocks — the
    ``minibatch_lg`` execution mode of the message-passing archs (mean
    aggregator; GCN's sym-norm becomes the sampled-mean estimator).

    hops[k] = (frontier_idx (B_k,), nbr_idx (B_k, fanout), mask) with local
    indices into ``feats``; hop 0 expands the seed batch.  Deepest hop is
    processed first so each layer reads the previous depth's output.
    """
    h = jax.nn.relu(jnp.einsum("uf,fh->uh", feats, params["w_in"]))
    for (frontier, nbr, mask), w, ws in zip(
        reversed(list(hops)), params["w_nbr"], params["w_self"]
    ):
        neigh = h[nbr]                                       # (B, f, H)
        m = mask[..., None].astype(h.dtype)
        mean = (neigh * m).sum(axis=1) / jnp.maximum(m.sum(axis=1), 1.0)
        out = jax.nn.relu(
            jnp.einsum("bd,dg->bg", mean, w)
            + jnp.einsum("bd,dg->bg", h[frontier], ws)
        )
        h = h.at[frontier].set(out)
    seeds = hops[0][0]
    return jnp.einsum("bd,dc->bc", h[seeds], params["w_out"])
