"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448,
multi-head latent attention (MLA).  [hf:openbmb/MiniCPM3-4B]
"""
import os

import jax.numpy as jnp

from repro.configs.cells import lm_cell
from repro.configs.registry import ArchSpec
from repro.models.transformer import MLAConfig, TransformerConfig

FULL = TransformerConfig(
    name="minicpm3-4b",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448,
    attention="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  d_head_nope=64, d_head_rope=32, d_head_v=64),
    # §Perf A/B switch: REPRO_MLA_ABSORBED=0 measures the naive
    # (decompress-per-step) serving path the hillclimb starts from.
    mla_absorbed=os.environ.get("REPRO_MLA_ABSORBED", "1") != "0",
)

REDUCED = TransformerConfig(
    name="minicpm3-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=128, attention="mla",
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  d_head_nope=16, d_head_rope=8, d_head_v=16),
    dtype=jnp.float32,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="minicpm3-4b", family="lm",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lambda s: lm_cell("minicpm3-4b", FULL, s),
        make_probe_cell=lambda s, t: lm_cell(
            "minicpm3-4b", __import__("dataclasses").replace(FULL, n_layers=t), s
        ),
        source="hf:openbmb/MiniCPM3-4B; hf",
    )
