"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (GQA kv=16) d_ff=1408,
vocab=163840, MoE 64 experts top-6 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B]
"""
import jax.numpy as jnp

from repro.configs.cells import lm_cell
from repro.configs.registry import ArchSpec
from repro.models.transformer import MoEConfig, TransformerConfig

FULL = TransformerConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=163840, d_head=128,
    # group/capacity tuned per the granite hillclimb transfer (dispatch
    # FLOPs/token ∝ group_size; experts divide the EP axis natively here)
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  capacity_factor=1.0, group_size=256),
)

REDUCED = TransformerConfig(
    name="moonshot-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=128, d_head=16,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32),
    dtype=jnp.float32,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="moonshot-v1-16b-a3b", family="lm",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lambda s: lm_cell("moonshot-v1-16b-a3b", FULL, s),
        make_probe_cell=lambda s, t: lm_cell(
            "moonshot-v1-16b-a3b", __import__("dataclasses").replace(FULL, n_layers=t), s
        ),
        source="hf:moonshotai/Moonlight-16B-A3B; hf",
    )
