"""Cell builders: (architecture × input shape) → step fn + input specs.

A *cell* is one dry-run unit: a step function to lower and the
ShapeDtypeStruct stand-ins for every input (params, optimizer state and
batch) — no device allocation, the shannon/kernels pattern.

Step kinds:
  ``train``   — loss + grad + AdamW update   (lowers ``train_step``)
  ``prefill`` — full-sequence forward + cache build
  ``decode``  — one-token step with a KV cache (``serve_step``)
  ``serve``   — forward-only scoring (recsys)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tfm
from repro.models.common import softmax_cross_entropy
from repro.optim import adamw

PyTree = Any
F32 = jnp.float32
BF16 = jnp.bfloat16
I32 = jnp.int32


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def pad512(x: int) -> int:
    """Round up to a shard-friendly multiple (pjit input dims must divide
    the mesh axes; a real input pipeline pads its arrays the same way)."""
    return ((int(x) + 511) // 512) * 512


@dataclasses.dataclass
class Cell:
    """One (arch × shape) dry-run unit."""

    arch: str
    shape: str
    kind: str                                  # train|prefill|decode|serve
    step_fn: Callable                          # positional-arg step function
    input_specs: Tuple[Any, ...]               # ShapeDtypeStructs, positional
    donate: Tuple[int, ...] = ()
    skip_reason: Optional[str] = None          # set => cell is a noted skip
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _param_specs(init_fn) -> PyTree:
    """Parameter ShapeDtypeStructs without allocating (eval_shape)."""
    return jax.eval_shape(init_fn, jax.random.PRNGKey(0))


def _opt_specs(param_specs: PyTree) -> PyTree:
    opt = adamw(1e-4)
    return jax.eval_shape(lambda p: opt.init(p), param_specs)


# ===================================================================== LM
def lm_cell(arch: str, cfg: tfm.TransformerConfig, shape_name: str) -> Cell:
    opt = adamw(3e-4)
    if shape_name == "train_4k":
        seq, batch = 4096, 256
        step = tfm.make_train_step(cfg, opt)
        p = _param_specs(lambda k: tfm.init_params(cfg, k))
        o = _opt_specs(p)
        batch_specs = {
            "tokens": sds((batch, seq), I32),
            "labels": sds((batch, seq), I32),
        }
        return Cell(
            arch=arch, shape=shape_name, kind="train",
            step_fn=step, input_specs=(p, o, batch_specs), donate=(0, 1),
            meta={"tokens": batch * seq,
                  "model_flops": 6 * cfg.active_param_count() * batch * seq,
                  "scan_trip": cfg.n_layers},
        )
    if shape_name == "prefill_32k":
        seq, batch = 32768, 32
        step = tfm.make_prefill(cfg)
        p = _param_specs(lambda k: tfm.init_params(cfg, k))
        cache = tfm.cache_spec(cfg, batch, seq)
        return Cell(
            arch=arch, shape=shape_name, kind="prefill",
            step_fn=step,
            input_specs=(p, sds((batch, seq), I32), cache), donate=(2,),
            meta={"tokens": batch * seq,
                  "model_flops": 2 * cfg.active_param_count() * batch * seq,
                  "scan_trip": cfg.n_layers},
        )
    if shape_name in ("decode_32k", "long_500k"):
        if shape_name == "decode_32k":
            ctx, batch = 32768, 128
        else:
            ctx, batch = 524288, 1
            if not cfg.sub_quadratic:
                return Cell(
                    arch=arch, shape=shape_name, kind="decode",
                    step_fn=lambda *a: None, input_specs=(),
                    skip_reason=(
                        "full quadratic attention (no SWA/linear variant); "
                        "524k-token serve is out of contract for this arch "
                        "— see DESIGN.md §Arch-applicability"
                    ),
                )
        # SWA archs keep a ring cache of `window`; full-attn keep `ctx`.
        cache_len = min(ctx, cfg.window) if cfg.window else ctx
        step = tfm.make_decode_step(cfg)
        p = _param_specs(lambda k: tfm.init_params(cfg, k))
        cache = tfm.cache_spec(cfg, batch, cache_len)
        return Cell(
            arch=arch, shape=shape_name, kind="decode",
            step_fn=step,
            input_specs=(
                p, cache, sds((batch, 1), I32), sds((), I32)
            ),
            donate=(1,),
            meta={"tokens": batch,
                  "model_flops": 2 * cfg.active_param_count() * batch,
                  "cache_len": cache_len, "scan_trip": cfg.n_layers},
        )
    raise KeyError(f"unknown LM shape {shape_name}")


# ==================================================================== GNN
GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                          n_classes=7),
    "minibatch_lg": dict(n_nodes=232_965, n_edges=114_615_892,
                         batch_nodes=1024, fanouts=(15, 10), d_feat=602,
                         n_classes=41),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                         n_classes=47),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=16,
                     n_classes=1),
}


def _gnn_train_step(loss_fn, opt):
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step


def _masked_ce(logits, labels, mask):
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[:, None], axis=-1)[:, 0]
    ce = (logz - gold) * mask
    return ce.sum() / jnp.maximum(mask.sum(), 1.0)


def _union_sizes(sh) -> Tuple[int, int]:
    """Padded union-subgraph sizes for sampled minibatch cells."""
    b = sh["batch_nodes"]
    f1, f2 = sh["fanouts"]
    nodes = b * (1 + f1 + f1 * f2)
    edges = b * f1 + b * f1 * f2
    return nodes, edges


def gnn_cell(arch: str, model_cfg, shape_name: str) -> Cell:
    sh = GNN_SHAPES[shape_name]
    opt = adamw(1e-3)
    kind_cfg = type(model_cfg).__name__

    if kind_cfg in ("GCNConfig", "GATConfig"):
        return _spmm_family_cell(arch, model_cfg, shape_name, sh, opt)
    if kind_cfg == "DimeNetConfig":
        return _dimenet_cell(arch, model_cfg, shape_name, sh, opt)
    if kind_cfg == "MGNConfig":
        return _mgn_cell(arch, model_cfg, shape_name, sh, opt)
    raise TypeError(kind_cfg)


def _spmm_family_cell(arch, cfg0, shape_name, sh, opt) -> Cell:
    is_gat = type(cfg0).__name__ == "GATConfig"

    if shape_name == "minibatch_lg":
        # sampled-fanout execution (GraphSAGE-mode of the SpMM family)
        scfg = gnn_mod.SageConfig(
            name=cfg0.name, d_feat=sh["d_feat"],
            d_hidden=max(cfg0.d_hidden * (cfg0.n_heads if is_gat else 1), 64),
            n_classes=sh["n_classes"], fanouts=tuple(sh["fanouts"]),
        )
        nodes, _ = _union_sizes(sh)
        b = sh["batch_nodes"]
        f1, f2 = sh["fanouts"]

        def loss_fn(params, batch):
            logits = gnn_mod.sage_block_forward(
                scfg, params, batch["feats"], [
                    (batch["h0_f"], batch["h0_n"], batch["h0_m"]),
                    (batch["h1_f"], batch["h1_n"], batch["h1_m"]),
                ],
            )
            return _masked_ce(logits, batch["labels"],
                              jnp.ones(logits.shape[0], F32))

        p = _param_specs(lambda k: gnn_mod.sage_init(scfg, k))
        o = _opt_specs(p)
        batch_specs = {
            "feats": sds((nodes, sh["d_feat"]), F32),
            "h0_f": sds((b,), I32), "h0_n": sds((b, f1), I32),
            "h0_m": sds((b, f1), jnp.bool_),
            "h1_f": sds((b * f1,), I32), "h1_n": sds((b * f1, f2), I32),
            "h1_m": sds((b * f1, f2), jnp.bool_),
            "labels": sds((b,), I32),
        }
        return Cell(
            arch=arch, shape=shape_name, kind="train",
            step_fn=_gnn_train_step(loss_fn, opt),
            input_specs=(p, o, batch_specs), donate=(0, 1),
            meta={"mode": "sampled", "nodes": nodes},
        )

    # full-graph (or batched molecule union graph) edge-list execution
    if shape_name == "molecule":
        n = pad512(sh["n_nodes"] * sh["batch"])
        e = pad512(sh["n_edges"] * sh["batch"] * 2)   # symmetrized
        n_out, d_feat = sh["n_classes"], sh["d_feat"]
        num_graphs = sh["batch"]
    else:
        n, e = pad512(sh["n_nodes"]), pad512(sh["n_edges"])
        n_out, d_feat = sh["n_classes"], sh["d_feat"]
        num_graphs = 0

    cfg = dataclasses.replace(cfg0, d_feat=d_feat, n_classes=n_out)

    def loss_fn(params, batch):
        if is_gat:
            logits = gnn_mod.gat_forward(
                cfg, params, batch["feats"], batch["src"], batch["dst"], n
            )
        else:
            logits = gnn_mod.gcn_forward(
                cfg, params, batch["feats"], batch["src"], batch["dst"],
                batch["w"], n
            )
        if num_graphs:
            from repro.graph.segment import segment_mean
            pooled = segment_mean(logits, batch["graph_ids"], num_graphs)
            return jnp.mean((pooled[:, 0] - batch["targets"]) ** 2)
        return _masked_ce(logits, batch["labels"], batch["label_mask"])

    init = (gnn_mod.gat_init if is_gat else gnn_mod.gcn_init)
    p = _param_specs(lambda k: init(cfg, k))
    o = _opt_specs(p)
    batch_specs = {
        "feats": sds((n, d_feat), F32),
        "src": sds((e,), I32),
        "dst": sds((e,), I32),
    }
    if not is_gat:
        batch_specs["w"] = sds((e,), F32)
    if num_graphs:
        batch_specs["graph_ids"] = sds((n,), I32)
        batch_specs["targets"] = sds((num_graphs,), F32)
    else:
        batch_specs["labels"] = sds((n,), I32)
        batch_specs["label_mask"] = sds((n,), F32)
    return Cell(
        arch=arch, shape=shape_name, kind="train",
        step_fn=_gnn_train_step(loss_fn, opt),
        input_specs=(p, o, batch_specs), donate=(0, 1),
        meta={"nodes": n, "edges": e},
    )


def _dimenet_cell(arch, cfg, shape_name, sh, opt) -> Cell:
    # geometry sizes per shape; triplets are capped (noted in DESIGN.md §8)
    if shape_name == "molecule":
        g = sh["batch"]
        n = pad512(sh["n_nodes"] * g)
        e = pad512(sh["n_edges"] * g * 2)
        t = 4 * e
    elif shape_name == "minibatch_lg":
        n, e = _union_sizes(sh)
        n, e = pad512(n), pad512(e)
        g = sh["batch_nodes"]
        t = 2 * e
    else:
        n, e = pad512(sh["n_nodes"]), pad512(sh["n_edges"])
        g = 1
        t = 2 * e

    def loss_fn(params, batch):
        energy = gnn_mod.dimenet_forward(
            cfg, params, batch["z"], batch["pos"], batch["src"],
            batch["dst"], batch["tri_kj"], batch["tri_ji"],
            batch["tri_mask"], batch["graph_ids"], g,
        )
        return jnp.mean((energy[:, 0] - batch["targets"]) ** 2)

    p = _param_specs(lambda k: gnn_mod.dimenet_init(cfg, k))
    o = _opt_specs(p)
    batch_specs = {
        "z": sds((n,), I32),
        "pos": sds((n, 3), F32),
        "src": sds((e,), I32),
        "dst": sds((e,), I32),
        "tri_kj": sds((t,), I32),
        "tri_ji": sds((t,), I32),
        "tri_mask": sds((t,), F32),
        "graph_ids": sds((n,), I32),
        "targets": sds((g,), F32),
    }
    return Cell(
        arch=arch, shape=shape_name, kind="train",
        step_fn=_gnn_train_step(loss_fn, opt),
        input_specs=(p, o, batch_specs), donate=(0, 1),
        meta={"nodes": n, "edges": e, "triplets": t},
    )


def _mgn_cell(arch, cfg, shape_name, sh, opt) -> Cell:
    if shape_name == "molecule":
        n = pad512(sh["n_nodes"] * sh["batch"])
        e = pad512(sh["n_edges"] * sh["batch"] * 2)
    elif shape_name == "minibatch_lg":
        n, e = _union_sizes(sh)
        n, e = pad512(n), pad512(e)
    else:
        n, e = pad512(sh["n_nodes"]), pad512(sh["n_edges"])

    def loss_fn(params, batch):
        pred = gnn_mod.mgn_forward(
            cfg, params, batch["node_feat"], batch["edge_feat"],
            batch["src"], batch["dst"], n,
        )
        return jnp.mean((pred - batch["targets"]) ** 2)

    p = _param_specs(lambda k: gnn_mod.mgn_init(cfg, k))
    o = _opt_specs(p)
    batch_specs = {
        "node_feat": sds((n, cfg.d_node_in), F32),
        "edge_feat": sds((e, cfg.d_edge_in), F32),
        "src": sds((e,), I32),
        "dst": sds((e,), I32),
        "targets": sds((n, cfg.d_out), F32),
    }
    return Cell(
        arch=arch, shape=shape_name, kind="train",
        step_fn=_gnn_train_step(loss_fn, opt),
        input_specs=(p, o, batch_specs), donate=(0, 1),
        meta={"nodes": n, "edges": e},
    )


# ================================================================= recsys
RECSYS_SHAPES = {
    "train_batch": dict(batch=65_536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262_144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, kind="serve"),
}


def recsys_cell(arch: str, cfg: recsys_mod.WideDeepConfig,
                shape_name: str) -> Cell:
    sh = RECSYS_SHAPES[shape_name]
    b = sh["batch"]
    p = _param_specs(lambda k: recsys_mod.widedeep_init(cfg, k))
    if shape_name == "train_batch":
        opt = adamw(1e-3)
        o = _opt_specs(p)
        step = recsys_mod.make_train_step(cfg, opt)
        batch_specs = {
            "sparse": sds((b, cfg.n_sparse), I32),
            "dense": sds((b, cfg.n_dense), F32),
            "labels": sds((b,), F32),
        }
        return Cell(
            arch=arch, shape=shape_name, kind="train",
            step_fn=step, input_specs=(p, o, batch_specs), donate=(0, 1),
            meta={"examples": b},
        )
    if shape_name == "retrieval_cand":
        step = recsys_mod.make_retrieval_scorer(cfg)
        cand = sds((pad512(sh["n_candidates"]), cfg.mlp_dims[-1]), F32)
        return Cell(
            arch=arch, shape=shape_name, kind="serve",
            step_fn=step,
            input_specs=(
                p, sds((b, cfg.n_sparse), I32), sds((b, cfg.n_dense), F32),
                cand,
            ),
            meta={"candidates": sh["n_candidates"]},
        )
    step = recsys_mod.make_serve(cfg)
    return Cell(
        arch=arch, shape=shape_name, kind="serve",
        step_fn=step,
        input_specs=(
            p, sds((b, cfg.n_sparse), I32), sds((b, cfg.n_dense), F32)
        ),
        meta={"examples": b},
    )
