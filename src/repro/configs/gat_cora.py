"""gat-cora [gnn] — 2 layers, d_hidden=8, 8 heads, attention aggregator.
[arXiv:1710.10903]
"""
from repro.configs.cells import gnn_cell
from repro.configs.registry import ArchSpec
from repro.models.gnn import GATConfig

FULL = GATConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
                 d_feat=1433, n_classes=7)
REDUCED = GATConfig(name="gat-smoke", n_layers=2, d_hidden=4, n_heads=2,
                    d_feat=32, n_classes=4)
SHAPES = ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"]


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="gat-cora", family="gnn",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lambda s: gnn_cell("gat-cora", FULL, s),
        source="arXiv:1710.10903; paper",
    )
