"""gcn-cora [gnn] — 2 layers, d_hidden=16, mean/sym-norm aggregator.
[arXiv:1609.02907]
"""
from repro.configs.cells import gnn_cell
from repro.configs.registry import ArchSpec
from repro.models.gnn import GCNConfig

FULL = GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16,
                 d_feat=1433, n_classes=7)
REDUCED = GCNConfig(name="gcn-smoke", n_layers=2, d_hidden=8,
                    d_feat=32, n_classes=4)
SHAPES = ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"]


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="gcn-cora", family="gnn",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lambda s: gnn_cell("gcn-cora", FULL, s),
        source="arXiv:1609.02907; paper",
    )
