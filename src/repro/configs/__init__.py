from repro.configs.cells import Cell
from repro.configs.registry import ARCH_IDS, all_cells, get_arch, list_archs

__all__ = ["ARCH_IDS", "Cell", "all_cells", "get_arch", "list_archs"]
