"""dimenet [gnn] — 6 blocks, d_hidden=128, n_bilinear=8, n_spherical=7,
n_radial=6; triplet-gather regime.  [arXiv:2003.03123]
"""
from repro.configs.cells import gnn_cell
from repro.configs.registry import ArchSpec
from repro.models.gnn import DimeNetConfig

FULL = DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                     n_bilinear=8, n_spherical=7, n_radial=6)
REDUCED = DimeNetConfig(name="dimenet-smoke", n_blocks=2, d_hidden=32,
                        n_bilinear=4, n_spherical=3, n_radial=3)
SHAPES = ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"]


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dimenet", family="gnn",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lambda s: gnn_cell("dimenet", FULL, s),
        source="arXiv:2003.03123; unverified",
    )
