"""Architecture registry: ``--arch <id>`` → configs, shapes, cells."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.configs.cells import Cell

ARCH_IDS = [
    # LM-family (5)
    "granite-moe-3b-a800m",
    "moonshot-v1-16b-a3b",
    "h2o-danube-1.8b",
    "stablelm-1.6b",
    "minicpm3-4b",
    # GNN (4)
    "gat-cora",
    "gcn-cora",
    "dimenet",
    "meshgraphnet",
    # recsys (1)
    "wide-deep",
    # the paper's own technique as an arch (extra, not in the 40-cell grid)
    "dhlp-bio",
]

_MODULES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "stablelm-1.6b": "stablelm_1_6b",
    "minicpm3-4b": "minicpm3_4b",
    "gat-cora": "gat_cora",
    "gcn-cora": "gcn_cora",
    "dimenet": "dimenet",
    "meshgraphnet": "meshgraphnet",
    "wide-deep": "wide_deep",
    "dhlp-bio": "dhlp_bio",
}


@dataclasses.dataclass
class ArchSpec:
    arch_id: str
    family: str                    # "lm" | "gnn" | "recsys" | "lp"
    full_config: Any
    reduced_config: Any
    shapes: List[str]
    make_cell: Callable[[str], Cell]
    source: str = ""               # citation tag from the assignment
    # For scan-over-layers cells: build the same cell with `trip` layers /
    # rounds.  The dry-run compiles trip=1 and trip=2 probes so the
    # roofline can recover exact per-layer FLOPs/bytes (XLA cost analysis
    # counts a while body once): f(L) = f(1) + (L-1)·(f(2)-f(1)).
    make_probe_cell: Optional[Callable[[str, int], Cell]] = None


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {', '.join(ARCH_IDS)}"
        )
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.spec()


def list_archs() -> List[str]:
    return list(ARCH_IDS)


def all_cells(include_extra: bool = False) -> List[Tuple[str, str]]:
    """The 40 assigned (arch × shape) cells (+ dhlp-bio extras if asked)."""
    out = []
    for a in ARCH_IDS:
        if a == "dhlp-bio" and not include_extra:
            continue
        spec = get_arch(a)
        for s in spec.shapes:
            out.append((a, s))
    return out
