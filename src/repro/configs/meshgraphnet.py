"""meshgraphnet [gnn] — 15 layers, d_hidden=128, sum aggregator,
2-layer MLPs (encode-process-decode).  [arXiv:2010.03409]
"""
from repro.configs.cells import gnn_cell
from repro.configs.registry import ArchSpec
from repro.models.gnn import MGNConfig

FULL = MGNConfig(name="meshgraphnet", n_layers=15, d_hidden=128,
                 mlp_layers=2, d_node_in=8, d_edge_in=4, d_out=3)
REDUCED = MGNConfig(name="mgn-smoke", n_layers=3, d_hidden=32,
                    mlp_layers=2, d_node_in=8, d_edge_in=4, d_out=3)
SHAPES = ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"]


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="meshgraphnet", family="gnn",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lambda s: gnn_cell("meshgraphnet", FULL, s),
        source="arXiv:2010.03409; unverified",
    )
