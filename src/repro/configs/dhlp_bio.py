"""dhlp-bio — the paper's own technique as a selectable arch.

Cells lower a fixed-round fused DHLP-2 propagation program (10 rounds per
step; the driver loops steps until σ-convergence) over edge/seed shardings.
Shapes follow the paper's scaling experiments (Tables 5-6: 1M → 20M edges)
plus a beyond-paper 500M-edge point sized for the production mesh.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.configs.cells import Cell, sds
from repro.configs.registry import ArchSpec
from repro.graph.segment import scatter_spmm


@dataclasses.dataclass(frozen=True)
class DHLPBioConfig:
    name: str = "dhlp-bio"
    alpha: float = 0.5
    rounds_per_step: int = 10
    seed_chunk: int = 4096


FULL = DHLPBioConfig()
REDUCED = DHLPBioConfig(name="dhlp-bio-smoke", rounds_per_step=2,
                        seed_chunk=8)

# |E| → (N nodes, S seed-chunk); N from the paper's edge-density model
LP_SHAPES = {
    "scale_1m": dict(num_edges=1_000_000, num_nodes=53_000, seeds=4096),
    "scale_20m": dict(num_edges=20_000_000, num_nodes=240_000, seeds=4096),
    "scale_500m": dict(num_edges=500_000_000, num_nodes=1_200_000,
                       seeds=4096),
}
SHAPES = list(LP_SHAPES)


def make_lp_step(cfg: DHLPBioConfig):
    beta2 = (1.0 - cfg.alpha) ** 2

    def step(src, dst, w, Y, F):
        def body(_, F):
            out = beta2 * Y.astype(jnp.float32) + scatter_spmm(
                src, dst, w, F, Y.shape[0]
            ).astype(jnp.float32)
            return out.astype(F.dtype)

        return jax.lax.fori_loop(0, cfg.rounds_per_step, body, F)

    return step


def lp_cell(shape_name: str, rounds: int = None) -> Cell:
    sh = LP_SHAPES[shape_name]
    e, n, s = sh["num_edges"], sh["num_nodes"], sh["seeds"]
    cfg = FULL if rounds is None else dataclasses.replace(
        FULL, rounds_per_step=rounds
    )
    # §Perf A/B switch (hillclimb 1): REPRO_LP_DTYPE=bf16 stores labels
    # and edge weights in bf16 (fp32 accumulation inside scatter_spmm).
    dt = (jnp.bfloat16 if os.environ.get("REPRO_LP_DTYPE") == "bf16"
          else jnp.float32)
    return Cell(
        arch="dhlp-bio", shape=shape_name, kind="serve",
        step_fn=make_lp_step(cfg),
        input_specs=(
            sds((e,), jnp.int32), sds((e,), jnp.int32),
            sds((e,), dt),
            sds((n, s), dt), sds((n, s), dt),
        ),
        donate=(4,),
        meta={"edges": e, "nodes": n, "seeds": s,
              "rounds": FULL.rounds_per_step,
              "scan_trip": FULL.rounds_per_step},
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dhlp-bio", family="lp",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lp_cell,
        make_probe_cell=lambda s, t: lp_cell(s, rounds=t),
        source="this paper (DHLP-1/2)",
    )
