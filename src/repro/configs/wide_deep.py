"""wide-deep [recsys] — 40 sparse fields, embed_dim=32, MLP 1024-512-256,
concat interaction.  [arXiv:1606.07792]
"""
from repro.configs.cells import recsys_cell
from repro.configs.registry import ArchSpec
from repro.models.recsys import WideDeepConfig

FULL = WideDeepConfig(name="wide-deep", n_sparse=40, n_dense=13,
                      embed_dim=32, vocab_per_field=1_000_000,
                      mlp_dims=(1024, 512, 256))
REDUCED = WideDeepConfig(name="wide-deep-smoke", n_sparse=8, n_dense=4,
                         embed_dim=8, vocab_per_field=128,
                         mlp_dims=(32, 16))
SHAPES = ["train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="wide-deep", family="recsys",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lambda s: recsys_cell("wide-deep", FULL, s),
        source="arXiv:1606.07792; paper",
    )
