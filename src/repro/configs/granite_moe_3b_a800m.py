"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512,
vocab=49155, MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]

Note: the assignment header says "MoE 40e top-8" while the trailing comment
says 32 experts; we implement the explicit field (40e).
"""
import os

import jax.numpy as jnp

from repro.configs.cells import lm_cell
from repro.configs.registry import ArchSpec
from repro.models.transformer import MoEConfig, TransformerConfig

FULL = TransformerConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=0, vocab=49155,
    # §Perf A/B switches (hillclimb 2): dispatch-group size and capacity
    moe=MoEConfig(
        num_experts=40, top_k=8, d_ff_expert=512,
        # tuned by the §Perf hillclimb (EXPERIMENTS.md): capacity 1.0 and
        # 256-token groups cut the train_4k roofline bound 17.1 → 10.4 s
        capacity_factor=float(os.environ.get("REPRO_MOE_CAPACITY", "1.0")),
        group_size=int(os.environ.get("REPRO_MOE_GROUP", "256")),
        pad_experts_to=int(os.environ.get("REPRO_MOE_PAD", "48")),
    ),
)

REDUCED = TransformerConfig(
    name="granite-moe-3b-a800m-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=0, vocab=128,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32),
    dtype=jnp.float32,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="granite-moe-3b-a800m", family="lm",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lambda s: lm_cell("granite-moe-3b-a800m", FULL, s),
        make_probe_cell=lambda s, t: lm_cell(
            "granite-moe-3b-a800m", __import__("dataclasses").replace(FULL, n_layers=t), s
        ),
        source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    )
