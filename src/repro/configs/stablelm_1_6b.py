"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32 = MHA)
d_ff=5632, vocab=100352.  [hf:stabilityai/stablelm-2-1_6b]
"""
import jax.numpy as jnp

from repro.configs.cells import lm_cell
from repro.configs.registry import ArchSpec
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="stablelm-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab=100352,
)

REDUCED = TransformerConfig(
    name="stablelm-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=128, dtype=jnp.float32,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="stablelm-1.6b", family="lm",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lambda s: lm_cell("stablelm-1.6b", FULL, s),
        make_probe_cell=lambda s, t: lm_cell(
            "stablelm-1.6b", __import__("dataclasses").replace(FULL, n_layers=t), s
        ),
        source="hf:stabilityai/stablelm-2-1_6b; unverified",
    )
