"""h2o-danube-1.8b [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912,
vocab=32000, llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]
"""
import jax.numpy as jnp

from repro.configs.cells import lm_cell
from repro.configs.registry import ArchSpec
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="h2o-danube-1.8b",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000, window=4096,
)

REDUCED = TransformerConfig(
    name="danube-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=128, window=16, dtype=jnp.float32,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="h2o-danube-1.8b", family="lm",
        full_config=FULL, reduced_config=REDUCED, shapes=SHAPES,
        make_cell=lambda s: lm_cell("h2o-danube-1.8b", FULL, s),
        make_probe_cell=lambda s, t: lm_cell(
            "h2o-danube-1.8b", __import__("dataclasses").replace(FULL, n_layers=t), s
        ),
        source="arXiv:2401.16818; hf",
    )
