"""Optimizers in pure JAX (no optax in this environment).

Functional interface mirroring the usual gradient-transform style:

    opt = adamw(lr_schedule, weight_decay=0.1)
    state = opt.init(params)
    params, state = opt.update(grads, state, params)

State lives in fp32 regardless of param dtype (master-weights policy for
bf16 training); the update casts back to the param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree        # first moment (or momentum)
    nu: Optional[PyTree]  # second moment (None for SGD)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], Tuple[PyTree, OptState]]


def _zeros_like_f32(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), gnorm


def adamw(
    lr: Schedule | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    lr_fn: Schedule = (lambda s: jnp.asarray(lr, jnp.float32)) if not callable(lr) else lr

    def init(params: PyTree) -> OptState:
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=_zeros_like_f32(params),
            nu=_zeros_like_f32(params),
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_n = b1 * m + (1.0 - b1) * g32
            v_n = b2 * v + (1.0 - b2) * jnp.square(g32)
            mhat = m_n / b1c
            vhat = v_n / b2c
            delta = mhat / (jnp.sqrt(vhat) + eps)
            delta = delta + weight_decay * p.astype(jnp.float32)
            p_n = p.astype(jnp.float32) - lr_t * delta
            return p_n.astype(p.dtype), m_n, v_n

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, mu=new_m, nu=new_v)

    return Optimizer(init=init, update=update)


def sgd_momentum(
    lr: Schedule | float, *, momentum: float = 0.9, weight_decay: float = 0.0
) -> Optimizer:
    lr_fn: Schedule = (lambda s: jnp.asarray(lr, jnp.float32)) if not callable(lr) else lr

    def init(params: PyTree) -> OptState:
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=_zeros_like_f32(params),
            nu=None,
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            g32 = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m_n = momentum * m + g32
            p_n = p.astype(jnp.float32) - lr_t * m_n
            return p_n.astype(p.dtype), m_n

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (
            tdef.unflatten([o[0] for o in out]),
            OptState(step=step, mu=tdef.unflatten([o[1] for o in out]), nu=None),
        )

    return Optimizer(init=init, update=update)
