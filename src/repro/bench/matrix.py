"""LP-core backend matrix: one table where kernel and sharding wins show.

Runs the same propagation problem across every backend the engine
registry knows (``repro.engine``) — dense XLA, blocked-CSR sparse, legacy
COO segment-sum, the shard_map distributed engine at 1/2/4 (virtual)
devices (8 on the full pass), and the fused blocked-CSR Pallas ``kernel``
path — and emits one record per cell with identical timing discipline,
plus a fixed-point agreement check against the dense engine
(strict-gated: a backend that silently diverges fails CI even if it got
faster).  The sweep iterates the registry, so registering a new backend
grows the table without touching this file.

Sharded cells need ``jax.device_count() >= k``; ``benchmarks/run.py``
fabricates host devices via ``XLA_FLAGS`` before importing jax.  Cells
that cannot run on this host — or whose (alg, momentum) the backend does
not support — are skipped LOUDLY (a ``skipped`` line, never a silent hole
in the table).

Momentum cells (heavy-ball, beyond-paper) run on every
momentum-capable backend and share the momentum-off dense reference:
fixed-seed heavy ball keeps the fixed point, so ``agree_dense`` doubles
as the acceleration-correctness check (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.bench.schema import BenchRecord
from repro.bench.timing import derived_throughput, time_callable

AGREEMENT_TOL = 5e-3
# Heavy-ball coefficient for the momentum-on cells.  The case-study
# operator's spectral radius is modest (α=0.5), so the sweet spot is small
# — 0.1 cuts rounds ~15% where 0.5 over-accelerates and doubles them.
MOMENTUM = 0.1


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One column of the matrix; ``kind`` is an engine-registry key."""

    name: str
    kind: str  # dense | sparse | sharded | kernel
    devices: int = 1

    def available(self, device_count: int) -> bool:
        if self.kind == "sharded":
            return device_count >= self.devices
        return True


def lp_backend_specs(*, full: bool = False) -> Tuple[BackendSpec, ...]:
    """Iterate the engine registry into matrix columns.

    ``sharded`` fans out into per-device-count columns (1/2/4, plus 8 on
    the full pass — the ROADMAP's dhlp1 × sharded8 point); every other
    registered backend is one column under its registry key.
    """
    from repro.engine import available_backends

    specs: List[BackendSpec] = []
    for name in available_backends():
        if name == "sharded":
            for k in (1, 2, 4, 8) if full else (1, 2, 4):
                specs.append(BackendSpec(f"sharded{k}", "sharded", devices=k))
        else:
            specs.append(BackendSpec(name, name))
    return tuple(specs)


def expand_matrix(
    backends: Sequence[BackendSpec],
    param_sets: Sequence[Dict[str, object]],
    *,
    device_count: Optional[int] = None,
) -> Tuple[List[Tuple[BackendSpec, Dict[str, object]]], List[BackendSpec]]:
    """Cross backends × params, splitting off unavailable backends.

    Returns ``(cells, skipped)`` — callers must surface ``skipped``.
    """
    if device_count is None:
        import jax

        device_count = jax.device_count()
    runnable = [b for b in backends if b.available(device_count)]
    skipped = [b for b in backends if not b.available(device_count)]
    cells = [(b, dict(p)) for b in runnable for p in param_sets]
    return cells, skipped


def _make_solve(spec: BackendSpec, cfg, norm, Y) -> Callable[[], object]:
    """Bind a no-arg solve closure for one matrix cell."""
    from repro.engine import make_engine

    kw = {"devices": spec.devices} if spec.kind == "sharded" else {}
    engine = make_engine(spec.kind, cfg, **kw)
    return lambda: engine.run(norm, seeds=Y)


def _cell_skip_reason(spec: BackendSpec, alg: str, momentum: float):
    """Why a (backend, params) cell cannot run, or None."""
    from repro.engine import get_backend_class

    cls = get_backend_class(spec.kind)
    if alg not in cls.supports_algs:
        return f"no {alg} path for backend {spec.name}"
    if momentum and not cls.supports_momentum:
        return f"backend {spec.name} has no momentum loop"
    return None


def lp_matrix_records(fast: bool = True) -> List[BenchRecord]:
    """The ``lp_matrix`` suite: every registered backend, same network."""
    from repro.core.solver import HeteroLP, LPConfig
    from repro.data.drugnet import DrugNetSpec, make_drugnet

    if fast:
        # sub-ms cells on a shared 1-core runner: more repeats per cell so
        # the median sits below the scheduler-noise tail (the compare gate
        # diffs medians)
        spec_net = DrugNetSpec(n_drug=48, n_disease=32, n_target=24, n_clusters=6)
        n_seeds, repeats = 16, 5
        algs = ("dhlp2",)
    else:
        spec_net = DrugNetSpec(n_drug=96, n_disease=64, n_target=48, n_clusters=8)
        n_seeds, repeats = 64, 3
        algs = ("dhlp1", "dhlp2")

    dn = make_drugnet(spec_net)
    norm = dn.network.normalize()
    n = norm.num_nodes
    edges = dn.network.num_edges
    Y = np.eye(n, dtype=np.float32)[:, :n_seeds]

    # momentum on/off × alg; momentum only accelerates the fused DHLP-2
    # round, so the on-cells pair with dhlp2
    param_sets: List[Dict[str, object]] = [{"alg": a, "momentum": 0.0} for a in algs]
    if "dhlp2" in algs:
        param_sets.append({"alg": "dhlp2", "momentum": MOMENTUM})
    cells, skipped = expand_matrix(lp_backend_specs(full=not fast), param_sets)
    records: List[BenchRecord] = []
    for b in skipped:
        print(
            f"lp_matrix: skipped backend {b.name} "
            f"(needs {b.devices} devices)",
            flush=True,
        )

    # dense reference fixed points, one per alg (fixed-seed mode: every
    # backend AND the momentum cells must land on the same answer)
    reference: Dict[str, np.ndarray] = {}
    for alg in algs:
        cfg = LPConfig(alg=alg, sigma=1e-4, seed_mode="fixed")
        reference[alg] = HeteroLP(cfg).run(norm, seeds=Y).F

    for spec, params in cells:
        alg = str(params["alg"])
        momentum = float(params["momentum"])
        reason = _cell_skip_reason(spec, alg, momentum)
        mom_tag = "_mom" if momentum else ""
        name = f"{alg}{mom_tag}_{spec.name}"
        if reason is not None:
            print(f"lp_matrix: skipped {name} ({reason})", flush=True)
            continue
        cfg = LPConfig(alg=alg, sigma=1e-4, seed_mode="fixed", momentum=momentum)
        solve = _make_solve(spec, cfg, norm, Y)
        res = solve()  # warmup: compile + first run
        stats = time_callable(solve, warmup=0, repeats=repeats)
        diff = float(np.max(np.abs(res.F - reference[alg])))
        derived = derived_throughput(stats, edges=edges, supersteps=res.supersteps)
        derived.update(
            {
                "outer_iters": float(res.outer_iters),
                "supersteps": float(res.supersteps),
                "agree_dense": 1.0 if diff <= AGREEMENT_TOL else 0.0,
                "max_abs_diff_vs_dense": diff,
            }
        )
        records.append(
            BenchRecord(
                suite="lp_matrix",
                name=name,
                backend=spec.name,
                params={
                    "alg": alg,
                    "momentum": momentum,
                    "nodes": n,
                    "edges": int(edges),
                    "seeds": n_seeds,
                    "sigma": 1e-4,
                    "devices": spec.devices,
                },
                stats=stats.to_dict(),
                derived=derived,
                strict=["outer_iters", "supersteps", "agree_dense"],
            )
        )
    return records


# --------------------------------------------------------------------------
# Scenario × backend cross (DESIGN.md §12.4)
# --------------------------------------------------------------------------
# Each cell solves one scenario's planted-edge recovery problem on one
# registry backend: wall time plus three strict correctness metrics —
# recovery AUC against the planted truth, fixed-point agreement vs the
# cell row's reference backend, and the iteration count.  The fast pass
# covers small instances of the non-bio scenarios (the CI gate's
# coverage); the full pass adds the nominal-scale cells including the
# >=1M-edge powerlaw row.


def _scenario_rows(fast: bool):
    """(scenario, scale, backends) rows; backends[0] is the agreement
    reference — dense where the (N, N) operator is feasible, blocked-CSR
    sparse on the million-edge row (dense there would swamp CI hosts)."""
    if fast:
        return (
            ("bipartite", 0.35, ("dense", "sparse")),
            ("kpartite5", 0.35, ("dense", "sparse")),
            ("kpartite_heterophilic", 0.35, ("dense", "sparse")),
            ("powerlaw", 0.02, ("dense", "sparse")),
        )
    return (
        ("bipartite", 1.0, ("dense", "sparse")),
        ("kpartite5", 1.0, ("dense", "sparse", "kernel")),
        ("kpartite_heterophilic", 1.0, ("dense", "sparse", "kernel")),
        ("powerlaw", 1.0, ("sparse",)),
        ("streaming", 1.0, ("dense", "sparse")),
    )


def scenario_matrix_records(fast: bool = True) -> List[BenchRecord]:
    """The ``scenario_matrix`` suite: named workloads × registry backends.

    Each cell is one RunSpec resolved by a Session (DESIGN.md §13) — the
    bundle is generated once per row (disk-cached at heavyweight sizes)
    and injected, the backend resolves through the session, and the
    timed closure runs the session's eval engine so prepare() caching
    matches what ``python -m repro run`` would do.
    """
    import repro.scenarios as sc
    from repro.api import EvalSpec, NetworkSpec, RunSpec, Session, SolveSpec

    max_entities = 16 if fast else 24
    repeats = 3
    records: List[BenchRecord] = []
    for scenario, scale, backends in _scenario_rows(fast):
        net_spec = NetworkSpec(kind="scenario", name=scenario, scale=scale, seed=0)
        bundle = sc.generate(scenario, scale=scale, seed=0)
        net = bundle.network
        problem = sc.make_recovery_problem(
            bundle, holdout_frac=0.1, max_entities=max_entities, seed=0
        )
        edges = net.num_edges
        F_ref = None
        for backend in backends:
            session = Session(
                RunSpec(
                    network=net_spec,
                    solve=SolveSpec(sigma=1e-4, seed_mode="fixed", backend=backend),
                    eval=EvalSpec(max_entities=max_entities),
                ),
                bundle=bundle,
            )
            engine = session.eval_engine

            def solve(engine=engine):
                return engine.run(problem.masked_net, seeds=problem.Y)

            res = solve()  # warmup: compile + first run
            stats = time_callable(solve, warmup=0, repeats=repeats)
            derived = derived_throughput(
                stats, edges=edges, supersteps=res.supersteps
            )
            derived.update(problem.metrics(res.F))
            derived["outer_iters"] = float(res.outer_iters)
            if F_ref is None:
                F_ref = res.F
                derived["agree_ref"] = 1.0  # the reference itself
            else:
                diff = float(np.max(np.abs(res.F - F_ref)))
                derived["agree_ref"] = (
                    1.0 if diff <= AGREEMENT_TOL else 0.0
                )
                derived["max_abs_diff_vs_ref"] = diff
            records.append(
                BenchRecord(
                    suite="scenario_matrix",
                    name=f"{scenario}_{backend}",
                    backend=backend,
                    params={
                        "scenario": scenario,
                        "scale": scale,
                        "types": net.num_types,
                        "nodes": net.num_nodes,
                        "edges": int(edges),
                        "seeds": int(problem.Y.shape[1]),
                        "reference": backends[0],
                        "sigma": 1e-4,
                    },
                    stats=stats.to_dict(),
                    derived=derived,
                    strict=["outer_iters", "agree_ref", "recovery_auc"],
                )
            )
    return records


def register() -> None:
    """Register the matrix suites (import-time side effects kept out of
    module import so schema/compare tests stay jax-free)."""
    from repro.bench.registry import register_suite

    register_suite(
        "lp_matrix",
        description="LP core across every engine-registry backend",
    )(lp_matrix_records)
    register_suite(
        "scenario_matrix",
        description="scenario registry × engine backends with planted-"
        "truth recovery",
    )(scenario_matrix_records)
