"""LP-core backend matrix: one table where kernel and sharding wins show.

Runs the same propagation problem across every engine the repo has —
dense XLA, sparse COO segment-sum, the shard_map distributed engine at
1/2/4 (virtual) devices, and the Pallas ``lp_round_op`` kernel path — and
emits one record per cell with identical timing discipline, plus a
fixed-point agreement check against the dense engine (strict-gated: a
backend that silently diverges fails CI even if it got faster).

Sharded cells need ``jax.device_count() >= k``; ``benchmarks/run.py``
fabricates host devices via ``XLA_FLAGS`` before importing jax.  Cells
that cannot run on this host are skipped LOUDLY (a ``skipped`` line, never
a silent hole in the table).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.bench.schema import BenchRecord
from repro.bench.timing import derived_throughput, time_callable

AGREEMENT_TOL = 5e-3


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One column of the matrix."""

    name: str
    kind: str  # dense | sparse_coo | sharded | pallas
    devices: int = 1

    def available(self, device_count: int) -> bool:
        if self.kind == "sharded":
            return device_count >= self.devices
        return True


LP_BACKENDS: Tuple[BackendSpec, ...] = (
    BackendSpec("dense", "dense"),
    BackendSpec("sparse_coo", "sparse_coo"),
    BackendSpec("sharded1", "sharded", devices=1),
    BackendSpec("sharded2", "sharded", devices=2),
    BackendSpec("sharded4", "sharded", devices=4),
    BackendSpec("pallas", "pallas"),
)


def expand_matrix(
    backends: Sequence[BackendSpec],
    param_sets: Sequence[Dict[str, object]],
    *,
    device_count: Optional[int] = None,
) -> Tuple[List[Tuple[BackendSpec, Dict[str, object]]], List[BackendSpec]]:
    """Cross backends × params, splitting off unavailable backends.

    Returns ``(cells, skipped)`` — callers must surface ``skipped``.
    """
    if device_count is None:
        import jax

        device_count = jax.device_count()
    runnable = [b for b in backends if b.available(device_count)]
    skipped = [b for b in backends if not b.available(device_count)]
    cells = [(b, dict(p)) for b in runnable for p in param_sets]
    return cells, skipped


def _make_solve(spec: BackendSpec, cfg, norm, Y) -> Callable[[], object]:
    """Bind a no-arg solve closure for one matrix cell."""
    from repro.core.solver import HeteroLP
    from repro.core.sparse import SparseHeteroLP

    if spec.kind == "dense":
        solver = HeteroLP(dataclasses.replace(cfg, use_kernel=False))
        return lambda: solver.run(norm, seeds=Y)
    if spec.kind == "pallas":
        solver = HeteroLP(dataclasses.replace(cfg, fused=True, use_kernel=True))
        return lambda: solver.run(norm, seeds=Y)
    if spec.kind == "sparse_coo":
        solver = SparseHeteroLP(cfg)
        return lambda: solver.run(norm, seeds=Y, pad_mult=256)
    if spec.kind == "sharded":
        from repro.parallel.hints import make_mesh_compat
        from repro.parallel.lp_sharded import ShardedHeteroLP

        mesh = make_mesh_compat((1, spec.devices), ("data", "model"))
        solver = ShardedHeteroLP(cfg)
        return lambda: solver.run(norm, mesh, seeds=Y)
    raise ValueError(f"unknown backend kind {spec.kind!r}")


def lp_matrix_records(fast: bool = True) -> List[BenchRecord]:
    """The ``lp_matrix`` suite: every backend on the same drug network."""
    from repro.core.solver import LPConfig
    from repro.data.drugnet import DrugNetSpec, make_drugnet

    if fast:
        spec_net = DrugNetSpec(n_drug=48, n_disease=32, n_target=24, n_clusters=6)
        n_seeds, repeats = 16, 2
        algs = ("dhlp2",)
    else:
        spec_net = DrugNetSpec(n_drug=96, n_disease=64, n_target=48, n_clusters=8)
        n_seeds, repeats = 64, 3
        algs = ("dhlp1", "dhlp2")

    dn = make_drugnet(spec_net)
    norm = dn.network.normalize()
    n = norm.num_nodes
    edges = dn.network.num_edges
    Y = np.eye(n, dtype=np.float32)[:, :n_seeds]

    param_sets = [{"alg": a} for a in algs]
    cells, skipped = expand_matrix(LP_BACKENDS, param_sets)
    records: List[BenchRecord] = []
    for b in skipped:
        print(
            f"lp_matrix: skipped backend {b.name} "
            f"(needs {b.devices} devices)",
            flush=True,
        )

    # dense reference fixed points, one per alg (fixed-seed mode: every
    # backend must land on the same answer)
    from repro.core.solver import HeteroLP

    reference: Dict[str, np.ndarray] = {}
    for alg in algs:
        cfg = LPConfig(alg=alg, sigma=1e-4, seed_mode="fixed")
        reference[alg] = HeteroLP(cfg).run(norm, seeds=Y).F

    for spec, params in cells:
        alg = str(params["alg"])
        if spec.kind == "pallas" and alg != "dhlp2":
            # only the fused DHLP-2 round has a kernel path; recording a
            # dense-path run under backend="pallas" would be a silent lie
            print(
                f"lp_matrix: skipped {alg}_{spec.name} "
                f"(no kernel path for {alg})",
                flush=True,
            )
            continue
        cfg = LPConfig(alg=alg, sigma=1e-4, seed_mode="fixed")
        solve = _make_solve(spec, cfg, norm, Y)
        res = solve()  # warmup: compile + first run
        stats = time_callable(solve, warmup=0, repeats=repeats)
        diff = float(np.max(np.abs(res.F - reference[alg])))
        derived = derived_throughput(stats, edges=edges, supersteps=res.supersteps)
        derived.update(
            {
                "outer_iters": float(res.outer_iters),
                "supersteps": float(res.supersteps),
                "agree_dense": 1.0 if diff <= AGREEMENT_TOL else 0.0,
                "max_abs_diff_vs_dense": diff,
            }
        )
        records.append(
            BenchRecord(
                suite="lp_matrix",
                name=f"{alg}_{spec.name}",
                backend=spec.name,
                params={
                    "alg": alg,
                    "nodes": n,
                    "edges": int(edges),
                    "seeds": n_seeds,
                    "sigma": 1e-4,
                    "devices": spec.devices,
                },
                stats=stats.to_dict(),
                derived=derived,
                strict=["outer_iters", "supersteps", "agree_dense"],
            )
        )
    return records


def register() -> None:
    """Register the lp_matrix suite (import-time side effects kept out of
    module import so schema/compare tests stay jax-free)."""
    from repro.bench.registry import register_suite

    register_suite(
        "lp_matrix",
        description="LP core across dense/sparse/sharded/pallas backends",
    )(lp_matrix_records)
