"""Timing discipline shared by every benchmark.

One path to a wall-time number: warmup calls (compile/trace excluded),
``repeats`` measured calls, device sync via ``jax.block_until_ready`` on
whatever the callable returns, and robust order statistics (median/p10/p90)
instead of a single noisy sample.  The clock is injectable so tests can
assert the statistics deterministically.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence


def _default_sync(value):
    """Block on device work if the value is (a pytree of) jax arrays."""
    try:
        import jax

        return jax.block_until_ready(value)
    except Exception:  # pragma: no cover - jax absent or non-array value
        return value


def _quantile(sorted_xs: Sequence[float], q: float) -> float:
    """Linear-interpolation quantile on an already-sorted sample."""
    if not sorted_xs:
        raise ValueError("empty sample")
    if len(sorted_xs) == 1:
        return float(sorted_xs[0])
    pos = q * (len(sorted_xs) - 1)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    frac = pos - lo
    return float(sorted_xs[lo] * (1.0 - frac) + sorted_xs[hi] * frac)


@dataclasses.dataclass(frozen=True)
class TimingStats:
    """Order statistics (seconds) over ``repeats`` measured calls."""

    repeats: int
    warmup: int
    median_s: float
    p10_s: float
    p90_s: float
    mean_s: float
    min_s: float
    max_s: float

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, float]) -> "TimingStats":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})


def stats_from_samples(samples: Iterable[float], *, warmup: int = 0) -> TimingStats:
    """Build :class:`TimingStats` from pre-measured durations (seconds)."""
    xs = sorted(float(s) for s in samples)
    if not xs:
        raise ValueError("stats_from_samples needs at least one sample")
    return TimingStats(
        repeats=len(xs),
        warmup=warmup,
        median_s=_quantile(xs, 0.5),
        p10_s=_quantile(xs, 0.1),
        p90_s=_quantile(xs, 0.9),
        mean_s=sum(xs) / len(xs),
        min_s=xs[0],
        max_s=xs[-1],
    )


def time_callable(
    fn: Callable[[], object],
    *,
    warmup: int = 1,
    repeats: int = 3,
    clock: Optional[Callable[[], float]] = None,
    sync: Optional[Callable[[object], object]] = None,
) -> TimingStats:
    """Time ``fn()`` with warmup, repeats, and device synchronization.

    ``clock`` defaults to ``time.perf_counter`` and is injectable for
    deterministic tests; ``sync`` (default ``jax.block_until_ready``) is
    applied to the return value inside the timed region so asynchronous
    dispatch does not leak out of the measurement.
    """
    if repeats < 1:
        raise ValueError("repeats must be >= 1")
    clock = clock or time.perf_counter
    sync = sync or _default_sync
    for _ in range(max(0, warmup)):
        sync(fn())
    samples: List[float] = []
    for _ in range(repeats):
        t0 = clock()
        sync(fn())
        samples.append(clock() - t0)
    return stats_from_samples(samples, warmup=max(0, warmup))


def derived_throughput(
    stats: TimingStats,
    *,
    edges: Optional[int] = None,
    supersteps: Optional[int] = None,
    queries: Optional[int] = None,
    flops: Optional[int] = None,
) -> Dict[str, float]:
    """Derive throughput metrics from the median wall time.

    ``edges`` is per-superstep work: edges/s is edge *traversals* per
    second (edges × supersteps / t) when supersteps is known, matching the
    paper's messages-per-superstep accounting.
    """
    t = max(stats.median_s, 1e-12)
    out: Dict[str, float] = {}
    if edges is not None:
        traversals = edges * (supersteps if supersteps else 1)
        out["edges_per_s"] = traversals / t
    if supersteps is not None:
        out["supersteps_per_s"] = supersteps / t
    if queries is not None:
        out["qps"] = queries / t
    if flops is not None:
        out["gflops"] = flops / t / 1e9
    return out
