"""Suite registry: every benchmark registers itself as a named,
parameterized case so one driver can run any subset with one timing and
reporting discipline.

A suite function has signature ``fn(fast: bool) -> List[BenchRecord]``.
Benchmark modules under ``benchmarks/`` call :func:`register_suite` at
import time; ``benchmarks/run.py`` imports them, then drives the registry.
"""
from __future__ import annotations

import dataclasses
import traceback
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.bench.report import BenchReport
from repro.bench.schema import BenchRecord

SuiteFn = Callable[[bool], List[BenchRecord]]

_REGISTRY: Dict[str, "BenchSuite"] = {}


@dataclasses.dataclass(frozen=True)
class BenchSuite:
    name: str
    fn: SuiteFn
    description: str = ""
    tags: Tuple[str, ...] = ()


def register_suite(
    name: str,
    *,
    description: str = "",
    tags: Sequence[str] = (),
) -> Callable[[SuiteFn], SuiteFn]:
    """Decorator: ``@register_suite("table7_sigma")`` on a suite function."""

    def deco(fn: SuiteFn) -> SuiteFn:
        if name in _REGISTRY and _REGISTRY[name].fn is not fn:
            raise ValueError(f"suite {name!r} already registered")
        _REGISTRY[name] = BenchSuite(
            name=name, fn=fn, description=description, tags=tuple(tags)
        )
        return fn

    return deco


def get_suite(name: str) -> BenchSuite:
    if name not in _REGISTRY:
        known = ", ".join(sorted(_REGISTRY)) or "<none>"
        raise KeyError(f"unknown suite {name!r}; registered: {known}")
    return _REGISTRY[name]


def all_suites() -> List[BenchSuite]:
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def run_suites(
    report: BenchReport,
    *,
    only: Optional[Sequence[str]] = None,
    fast: bool = True,
    echo: Optional[Callable[[str], None]] = None,
) -> int:
    """Run registered suites into ``report``; returns the failure count.

    A suite that raises is recorded via ``report.add_error`` and does NOT
    abort the remaining suites — but the nonzero return propagates to the
    driver's exit code (no swallowed failures).
    """
    names = list(only) if only else [s.name for s in all_suites()]
    failures = 0
    for name in names:
        suite = get_suite(name)
        try:
            records = suite.fn(fast)
            # inside the try: a suite emitting a duplicate record key is a
            # suite bug and must not abort the remaining suites
            for rec in records:
                report.add(rec)
                if echo:
                    from repro.bench.report import legacy_csv_line

                    echo(legacy_csv_line(rec))
                if rec.error is not None:
                    failures += 1
        except Exception as e:  # noqa: BLE001 - isolate suites, fail driver
            failures += 1
            report.add_error(name, f"{type(e).__name__}: {e}")
            if echo:
                echo(f"{name}: ERROR {type(e).__name__}: {e}")
            traceback.print_exc()
    return failures
