"""Baseline comparator — the CI perf gate.

    python -m repro.bench.compare --baseline benchmarks/baseline.json \
        --candidate BENCH_ci.json --tolerance 0.30

Gate policy (DESIGN.md §10):

* correctness-derived metrics (each record's ``strict`` list: iteration
  counts, accuracy, backend agreement) hard-fail on any mismatch beyond
  ``--strict-tolerance`` — these are environment-independent;
* wall-time (``stats.median_s``) fails beyond ``--tolerance`` ONLY when
  the baseline and candidate environment fingerprints match — a baseline
  recorded on different hardware cannot gate wall times, so mismatched
  environments downgrade timing regressions to warnings;
* a baseline record missing from the candidate is a coverage regression
  and fails; candidate-only records are reported as new.

Exit codes: 0 pass, 1 regression, 2 baseline missing/unreadable (0 with
``--allow-missing``, so the gate bootstraps before a baseline lands).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Dict, List, Mapping, Optional

from repro.bench.report import env_fingerprint, load_report, repo_root
from repro.bench.schema import SchemaError, record_key


@dataclasses.dataclass
class Finding:
    key: str
    kind: str  # strict | timing | missing | error
    metric: str
    baseline: Optional[float]
    candidate: Optional[float]
    detail: str = ""


@dataclasses.dataclass
class CompareResult:
    regressions: List[Finding] = dataclasses.field(default_factory=list)
    warnings: List[Finding] = dataclasses.field(default_factory=list)
    improvements: List[Finding] = dataclasses.field(default_factory=list)
    new_keys: List[str] = dataclasses.field(default_factory=list)
    compared: int = 0
    env_match: bool = True

    @property
    def ok(self) -> bool:
        return not self.regressions

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        return d


def _rel_exceeds(candidate: float, baseline: float, tol: float) -> bool:
    """candidate regressed past baseline by more than tol (relative)."""
    scale = max(abs(baseline), 1e-12)
    return (candidate - baseline) / scale > tol


def compare_reports(
    baseline: Mapping[str, object],
    candidate: Mapping[str, object],
    *,
    tolerance: float = 0.30,
    strict_tolerance: float = 0.05,
) -> CompareResult:
    res = CompareResult()
    base_env = env_fingerprint(dict(baseline["environment"]))
    cand_env = env_fingerprint(dict(candidate["environment"]))
    res.env_match = base_env == cand_env
    base_recs = {record_key(r): r for r in baseline["records"]}
    cand_recs = {record_key(r): r for r in candidate["records"]}
    res.new_keys = sorted(set(cand_recs) - set(base_recs))

    for key in sorted(base_recs):
        brec = base_recs[key]
        crec = cand_recs.get(key)
        if crec is None:
            detail = "present in baseline, absent from candidate"
            res.regressions.append(
                Finding(key, "missing", "record", None, None, detail)
            )
            continue
        if crec.get("error") is not None:
            detail = f"candidate errored: {crec['error']}"
            res.regressions.append(
                Finding(key, "error", "record", None, None, detail)
            )
            continue
        res.compared += 1

        for metric in brec.get("strict", []):
            b = float(brec["derived"][metric])
            c = float(crec.get("derived", {}).get(metric, float("nan")))
            scale = max(abs(b), 1.0)
            if not (abs(c - b) / scale <= strict_tolerance):
                detail = f"|delta|/max(|base|,1) > {strict_tolerance}"
                res.regressions.append(
                    Finding(key, "strict", metric, b, c, detail)
                )

        b_t = float(brec["stats"]["median_s"])
        c_t = float(crec["stats"]["median_s"])
        if _rel_exceeds(c_t, b_t, tolerance):
            rel = (c_t - b_t) / max(b_t, 1e-12)
            detail = f"+{rel:.0%} vs tolerance {tolerance:.0%}"
            finding = Finding(key, "timing", "median_s", b_t, c_t, detail)
            if res.env_match:
                res.regressions.append(finding)
            else:
                finding.detail += " (environment mismatch: warning only)"
                res.warnings.append(finding)
        elif _rel_exceeds(b_t, c_t, tolerance):
            detail = "faster than baseline; consider refreshing it"
            res.improvements.append(
                Finding(key, "timing", "median_s", b_t, c_t, detail)
            )
    return res


def _print_result(res: CompareResult, out=None) -> None:
    out = out if out is not None else sys.stdout  # late-bound: tests capture

    def show(title: str, findings: List[Finding]) -> None:
        if not findings:
            return
        print(f"{title}:", file=out)
        for f in findings:
            b = "-" if f.baseline is None else f"{f.baseline:.6g}"
            c = "-" if f.candidate is None else f"{f.candidate:.6g}"
            line = f"  [{f.kind}] {f.key} {f.metric}: {b} -> {c}  {f.detail}"
            print(line, file=out)

    show("REGRESSIONS", res.regressions)
    show("warnings", res.warnings)
    show("improvements", res.improvements)
    if res.new_keys:
        print(f"new records (not in baseline): {len(res.new_keys)}", file=out)
    verdict = "PASS" if res.ok else "FAIL"
    print(
        f"compare: {verdict} — {res.compared} records compared, "
        f"{len(res.regressions)} regressions, {len(res.warnings)} warnings, "
        f"{len(res.improvements)} improvements "
        f"(env_match={res.env_match})",
        file=out,
    )


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.bench.compare",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument(
        "--candidate",
        default=None,
        help="default: BENCH_ci.json at the repo root",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.30,
        help="relative wall-time regression allowance",
    )
    ap.add_argument(
        "--strict-tolerance",
        type=float,
        default=0.05,
        help="allowance for correctness-derived metrics",
    )
    ap.add_argument(
        "--allow-missing",
        action="store_true",
        help="exit 0 when the baseline file does not exist",
    )
    ap.add_argument(
        "--json",
        default=None,
        help="also write the comparison summary here",
    )
    args = ap.parse_args(argv)

    candidate_path = args.candidate or os.path.join(repo_root(), "BENCH_ci.json")
    try:
        baseline = load_report(args.baseline)
    except FileNotFoundError:
        msg = f"baseline not found: {args.baseline}"
        if args.allow_missing:
            print(f"compare: PASS (no gate) — {msg}")
            return 0
        print(f"compare: ERROR — {msg}", file=sys.stderr)
        return 2
    except (json.JSONDecodeError, SchemaError, OSError) as e:
        # corrupt/invalid baseline is "unreadable", not "regression" —
        # and never waived by --allow-missing (it needs a human)
        print(
            f"compare: ERROR — unreadable baseline {args.baseline}: {e}",
            file=sys.stderr,
        )
        return 2
    try:
        candidate = load_report(candidate_path)
    except FileNotFoundError:
        print(
            f"compare: ERROR — candidate not found: {candidate_path}",
            file=sys.stderr,
        )
        return 2
    except (json.JSONDecodeError, SchemaError, OSError) as e:
        print(
            f"compare: ERROR — unreadable candidate {candidate_path}: {e}",
            file=sys.stderr,
        )
        return 2

    res = compare_reports(
        baseline,
        candidate,
        tolerance=args.tolerance,
        strict_tolerance=args.strict_tolerance,
    )
    _print_result(res)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res.to_dict(), f, indent=2)
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
