"""Unified benchmark harness: timing discipline, one versioned record
schema, machine-readable ``BENCH_<label>.json`` reports, a suite registry,
the LP backend matrix, and a baseline comparator for the CI perf gate.

Every benchmark in ``benchmarks/`` registers a suite here and emits
:class:`BenchRecord` rows; ``benchmarks/run.py`` is a thin driver that runs
the registered suites, writes the report, and exits nonzero on errors.
``python -m repro.bench.compare`` diffs a report against the committed
``benchmarks/baseline.json`` (DESIGN.md §10).
"""
from repro.bench.registry import BenchSuite, all_suites, get_suite, register_suite
from repro.bench.report import BenchReport, environment_info, load_report
from repro.bench.schema import (
    SCHEMA_VERSION,
    BenchRecord,
    SchemaError,
    record_key,
    validate_record,
    validate_report,
)
from repro.bench.timing import TimingStats, stats_from_samples, time_callable

__all__ = [
    "SCHEMA_VERSION",
    "BenchRecord",
    "BenchReport",
    "BenchSuite",
    "SchemaError",
    "TimingStats",
    "all_suites",
    "environment_info",
    "get_suite",
    "load_report",
    "record_key",
    "register_suite",
    "stats_from_samples",
    "time_callable",
    "validate_record",
    "validate_report",
]
