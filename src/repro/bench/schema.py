"""Versioned record/report schema for ``BENCH_*.json``.

One record per (suite, case, backend) with a stable key so trajectories
can be compared across PRs.  ``strict`` names the derived metrics that are
correctness-derived (iteration counts, accuracy, agreement-vs-dense) and
therefore hard-gate in ``repro.bench.compare`` regardless of how noisy the
runner's wall clock is (DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence

SCHEMA_VERSION = 1

_STATS_FIELDS = (
    "repeats",
    "warmup",
    "median_s",
    "p10_s",
    "p90_s",
    "mean_s",
    "min_s",
    "max_s",
)

_ENV_FIELDS = ("platform", "machine", "backend", "device_kind", "device_count")


class SchemaError(ValueError):
    """A BENCH record/report does not conform to the schema."""


@dataclasses.dataclass
class BenchRecord:
    """One benchmark measurement.

    ``derived`` holds metric-name → float (throughput AND correctness
    metrics); ``strict`` lists the subset of derived keys that must match
    the baseline within the strict tolerance.  ``telemetry`` optionally
    embeds an obs summary digest (DESIGN.md §14.5) — purely informational
    and never compared by ``repro.bench.compare``.
    """

    suite: str
    name: str
    backend: str
    params: Dict[str, object] = dataclasses.field(default_factory=dict)
    stats: Dict[str, float] = dataclasses.field(default_factory=dict)
    derived: Dict[str, float] = dataclasses.field(default_factory=dict)
    strict: List[str] = dataclasses.field(default_factory=list)
    error: Optional[str] = None
    telemetry: Optional[Dict[str, object]] = None

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        if self.error is None:
            d.pop("error")
        if self.telemetry is None:
            d.pop("telemetry")
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "BenchRecord":
        validate_record(d)
        return cls(
            suite=str(d["suite"]),
            name=str(d["name"]),
            backend=str(d["backend"]),
            params=dict(d.get("params", {})),
            stats=dict(d.get("stats", {})),
            derived=dict(d.get("derived", {})),
            strict=list(d.get("strict", [])),
            error=d.get("error"),
            telemetry=(
                dict(d["telemetry"]) if d.get("telemetry") is not None else None
            ),
        )


def record_key(record: Mapping[str, object]) -> str:
    """Stable identity of a measurement across runs: suite/name@backend."""
    if isinstance(record, BenchRecord):
        record = record.to_dict()
    return f"{record['suite']}/{record['name']}@{record['backend']}"


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise SchemaError(msg)


def validate_record(d: Mapping[str, object]) -> None:
    """Raise :class:`SchemaError` unless ``d`` is a valid record dict."""
    if isinstance(d, BenchRecord):
        d = d.to_dict()
    _require(isinstance(d, Mapping), f"record must be a mapping, got {type(d)}")
    for field in ("suite", "name", "backend"):
        _require(
            isinstance(d.get(field), str) and d[field] != "",
            f"record.{field} must be a non-empty string",
        )
    _require(
        isinstance(d.get("params", {}), Mapping),
        "record.params must be a mapping",
    )
    stats = d.get("stats", {})
    _require(isinstance(stats, Mapping), "record.stats must be a mapping")
    if stats:
        for f in _STATS_FIELDS:
            _require(
                isinstance(stats.get(f), (int, float)),
                f"record.stats.{f} must be a number",
            )
        _require(stats["repeats"] >= 1, "record.stats.repeats must be >= 1")
        _require(
            stats["min_s"] <= stats["median_s"] <= stats["max_s"],
            "record.stats median must lie within [min, max]",
        )
    derived = d.get("derived", {})
    _require(isinstance(derived, Mapping), "record.derived must be a mapping")
    for k, v in derived.items():
        _require(isinstance(k, str), "record.derived keys must be strings")
        _require(
            isinstance(v, (int, float, bool)),
            f"record.derived[{k!r}] must be numeric",
        )
    strict = d.get("strict", [])
    _require(
        isinstance(strict, Sequence) and not isinstance(strict, (str, bytes)),
        "record.strict must be a list",
    )
    for k in strict:
        _require(
            k in derived,
            f"record.strict key {k!r} has no matching derived metric",
        )
    err = d.get("error")
    _require(err is None or isinstance(err, str), "record.error must be a string")
    tel = d.get("telemetry")
    _require(
        tel is None or isinstance(tel, Mapping),
        "record.telemetry must be a mapping when present",
    )
    _require(
        bool(stats) or err is not None,
        "record must carry stats unless it is an error record",
    )


def validate_report(d: Mapping[str, object]) -> None:
    """Raise :class:`SchemaError` unless ``d`` is a valid report dict."""
    _require(isinstance(d, Mapping), "report must be a mapping")
    _require(
        d.get("schema_version") == SCHEMA_VERSION,
        f"report.schema_version must be {SCHEMA_VERSION}, "
        f"got {d.get('schema_version')!r}",
    )
    _require(
        isinstance(d.get("label"), str) and d["label"] != "",
        "report.label must be a non-empty string",
    )
    _require(
        isinstance(d.get("created_unix"), (int, float)),
        "report.created_unix must be a number",
    )
    env = d.get("environment")
    _require(isinstance(env, Mapping), "report.environment must be a mapping")
    for f in _ENV_FIELDS:
        _require(f in env, f"report.environment.{f} missing")
    records = d.get("records")
    _require(isinstance(records, list), "report.records must be a list")
    seen = set()
    for rec in records:
        validate_record(rec)
        key = record_key(rec)
        _require(key not in seen, f"duplicate record key {key!r}")
        seen.add(key)
