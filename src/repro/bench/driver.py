"""Shared bench-pass driver: one implementation behind every entry point.

``benchmarks/run.py`` (the legacy CLI), ``python -m repro run`` with a
``bench`` section, and ``Session.bench()`` all execute a benchmark pass
through :func:`run_bench` — same suite registration, same report
writing, same failure semantics — so the perf-tracking subsystem
(DESIGN.md §10) has exactly one driver path to trust.

Suite modules live under ``benchmarks/`` at the repo root (they are
workload definitions, not library code); :func:`import_suite_modules`
makes the repo root importable when the caller has not already done so.
"""

from __future__ import annotations

import dataclasses
import os
import sys
from typing import Callable, List, Optional


class BenchSetupError(RuntimeError):
    """The pass cannot run as requested (e.g. too few devices)."""


@dataclasses.dataclass
class BenchOutcome:
    """What a pass produced: suites run, records written, failures."""

    label: str
    suites: List[str]
    records: int
    failures: int
    paths: List[str]


def import_suite_modules() -> None:
    """Import every ``benchmarks/*`` suite module (registration is an
    import-time side effect) plus the two in-package matrix suites."""
    import repro.bench.matrix as bench_matrix

    try:
        import benchmarks.fig34_parallelism  # noqa: F401
    except ImportError:
        # invoked from outside the repo root: benchmarks/ sits three
        # levels above this file (src/repro/bench/driver.py)
        repo = os.path.dirname(
            os.path.dirname(
                os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
            )
        )
        if repo not in sys.path:
            sys.path.insert(0, repo)
        import benchmarks.fig34_parallelism  # noqa: F401
    import benchmarks.kernel_variants  # noqa: F401
    import benchmarks.kernels_bench  # noqa: F401
    import benchmarks.lp_on_graph  # noqa: F401
    import benchmarks.roofline as bench_roofline
    import benchmarks.serve_bench  # noqa: F401
    import benchmarks.table2_cv  # noqa: F401
    import benchmarks.table34_deleted  # noqa: F401
    import benchmarks.table56_scaling  # noqa: F401
    import benchmarks.table7_sigma  # noqa: F401

    bench_matrix.register()
    bench_roofline.register()


def run_bench(
    *,
    fast: bool = True,
    only: Optional[List[str]] = None,
    label: Optional[str] = None,
    write: bool = True,
    echo: Optional[Callable[[str], None]] = None,
) -> BenchOutcome:
    """Run the registered suites; write ``BENCH_<label>.json`` + results/.

    Raises :class:`BenchSetupError` when the full pass lacks the 8
    devices its sharded8 cells need (the device count is locked at jax
    init — see ``benchmarks/run.py`` for the XLA_FLAGS peek).
    """
    import jax

    if not fast and jax.device_count() < 8:
        raise BenchSetupError(
            "a full bench pass needs 8 devices but jax initialized with "
            f"{jax.device_count()} — set XLA_FLAGS="
            "--xla_force_host_platform_device_count=8 before any jax "
            "import (the CLI drivers peek argv and do this for you)"
        )

    from repro.bench import BenchReport
    from repro.bench.registry import run_suites

    import_suite_modules()

    resolved = label or ("ci" if fast else "full")
    report = BenchReport(resolved)
    if echo:
        echo("name,us_per_call,derived")
    failures = run_suites(report, only=only, fast=fast, echo=echo)
    paths: List[str] = []
    if write:
        paths = report.write()
        if echo:
            for p in paths:
                echo(f"wrote {p}")
    return BenchOutcome(
        label=resolved,
        suites=report.suites,
        records=len(report.records),
        failures=failures,
        paths=paths,
    )
