"""Report assembly and ``BENCH_<label>.json`` / ``results/`` writing."""
from __future__ import annotations

import json
import os
import platform
import sys
import time
from typing import Dict, List, Optional, Union

from repro.bench.schema import (
    SCHEMA_VERSION,
    BenchRecord,
    record_key,
    validate_report,
)


def environment_info() -> Dict[str, object]:
    """Fingerprint of the machine the run happened on.

    ``repro.bench.compare`` only applies the *timing* gate when the
    baseline and candidate fingerprints match — correctness-derived
    metrics gate unconditionally (DESIGN.md §10).
    """
    info: Dict[str, object] = {
        "platform": sys.platform,
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count() or 0,
        # operator-declared runner class (e.g. "gh-ubuntu-large"); CPU
        # platform/machine alone cannot distinguish a laptop from a CI
        # runner, and wall times must not gate across host classes
        "host_class": os.environ.get("BENCH_HOST_CLASS", "unspecified"),
        "backend": "unknown",
        "device_kind": "unknown",
        "device_count": 0,
    }
    try:
        import jax

        info["backend"] = jax.default_backend()
        devices = jax.devices()
        info["device_kind"] = devices[0].device_kind if devices else "none"
        info["device_count"] = len(devices)
        info["jax_version"] = jax.__version__
    except Exception as e:  # pragma: no cover - jax always present in repo
        info["error"] = f"jax unavailable: {e}"
    return info


def env_fingerprint(env: Dict[str, object]) -> tuple:
    """The subset of the environment that makes wall times comparable.

    ``cpu_count`` and ``host_class`` are included because on CPU backends
    platform/machine/device_kind are identical across almost all linux
    x86_64 hosts — without a host-class axis the timing gate would fire
    against baselines recorded on different hardware.
    """
    keys = ("platform", "machine", "backend", "device_kind", "cpu_count", "host_class")
    return tuple(env.get(k) for k in keys)


def repo_root(start: Optional[str] = None) -> str:
    """Walk up from ``start`` (default cwd) to the enclosing git root."""
    d = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.isdir(os.path.join(d, ".git")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start or os.getcwd())
        d = parent


class BenchReport:
    """Accumulates records across suites and writes the two artifacts:

    * ``BENCH_<label>.json`` at the repo root — the machine-readable
      trajectory point CI uploads and ``compare`` gates on;
    * ``results/<label>_<timestamp>.json`` — an append-only per-run copy.
    """

    def __init__(
        self,
        label: str,
        *,
        environment: Optional[Dict[str, object]] = None,
        created_unix: Optional[float] = None,
    ):
        self.label = label
        self.environment = environment or environment_info()
        self.created_unix = (
            time.time() if created_unix is None else float(created_unix)
        )
        self.records: List[BenchRecord] = []
        self.errors: List[Dict[str, str]] = []

    def add(self, record: BenchRecord) -> None:
        key = record_key(record)
        if any(record_key(r) == key for r in self.records):
            raise ValueError(f"duplicate record key {key!r}")
        self.records.append(record)

    def extend(self, records) -> None:
        for r in records:
            self.add(r)

    def add_error(self, suite: str, error: str) -> None:
        """A suite that failed to produce records (driver exits nonzero)."""
        self.errors.append({"suite": suite, "error": error})

    @property
    def suites(self) -> List[str]:
        return sorted({r.suite for r in self.records})

    def to_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {
            "schema_version": SCHEMA_VERSION,
            "label": self.label,
            "created_unix": self.created_unix,
            "environment": self.environment,
            "records": [r.to_dict() for r in self.records],
        }
        if self.errors:
            d["errors"] = list(self.errors)
        return d

    def write(
        self,
        root: Optional[str] = None,
        *,
        results_dir: Optional[str] = None,
        validate: bool = True,
    ) -> List[str]:
        """Write both artifacts; returns the paths written."""
        doc = self.to_dict()
        if validate:
            validate_report(doc)
        root = root or repo_root()
        paths = [os.path.join(root, f"BENCH_{self.label}.json")]
        results_dir = results_dir or os.path.join(root, "results")
        os.makedirs(results_dir, exist_ok=True)
        stamp = time.strftime("%Y%m%d-%H%M%S", time.gmtime(self.created_unix))
        paths.append(os.path.join(results_dir, f"{self.label}_{stamp}.json"))
        blob = json.dumps(doc, indent=2, sort_keys=False)
        for p in paths:
            with open(p, "w") as f:
                f.write(blob + "\n")
        return paths


def telemetry_digest(telemetry) -> Optional[Dict[str, object]]:
    """Compress a :class:`repro.obs.Telemetry` (or a pre-built summary
    dict) into the compact form embedded in BENCH records.

    Keeps the scalar roll-ups (counters, phase durations, histogram
    percentiles) and drops the raw series — BENCH files are diffed and
    committed, so per-tick gauge series stay in the run's
    ``telemetry/`` artifacts only (DESIGN.md §14.5).  Returns ``None``
    for a disabled telemetry object so callers can assign the record
    field unconditionally.
    """
    if telemetry is None:
        return None
    summary = telemetry if isinstance(telemetry, dict) else None
    if summary is None:
        if not getattr(telemetry, "enabled", False):
            return None
        summary = telemetry.summary()
    digest: Dict[str, object] = {}
    for key in ("level", "counters", "phases", "latency", "cache", "queue", "batch"):
        if summary.get(key):
            digest[key] = summary[key]
    conv = summary.get("convergence")
    if conv:
        digest["convergence"] = {
            k: conv.get(k)
            for k in ("supersteps", "first_residual", "last_residual")
        }
    return digest or None


def attach_telemetry(records, telemetry) -> List[BenchRecord]:
    """Embed one shared telemetry digest into every record of a suite."""
    digest = telemetry_digest(telemetry)
    if digest is not None:
        for r in records:
            r.telemetry = dict(digest)
    return list(records)


def load_report(path: str, *, validate: bool = True) -> Dict[str, object]:
    with open(path) as f:
        doc = json.load(f)
    if validate:
        validate_report(doc)
    return doc


def legacy_csv_line(record: Union[BenchRecord, Dict[str, object]]) -> str:
    """The seed scripts' ``name,us_per_call,derived`` stdout format, kept
    so eyeballing a run still works while JSON is the machine interface."""
    if isinstance(record, BenchRecord):
        record = record.to_dict()
    if record.get("error") is not None:
        return f"{record['suite']}/{record['name']},0,error={record['error'][:60]}"
    us = record["stats"]["median_s"] * 1e6
    derived = ";".join(
        f"{k}={v:.6g}" for k, v in sorted(record.get("derived", {}).items())
    )
    return f"{record['suite']}/{record['name']},{us:.0f},{derived}"
