"""LP serving driver — the paper's end-to-end workflow (Fig. 2 steps A-G).

Builds (or generates) the heterogeneous drug/disease/target network,
normalizes it, runs DHLP-1 or DHLP-2 to σ-convergence on the selected
engine backend, and emits the three outputs: predicted interaction
matrices, updated similarity matrices, and per-entity ranked candidates.

  PYTHONPATH=src python -m repro.launch.solve --alg dhlp2 --sigma 1e-3 \
      --drugs 223 --diseases 150 --targets 95 --top-k 20
  PYTHONPATH=src python -m repro.launch.solve --backend sharded --devices 2
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--alg", choices=["dhlp1", "dhlp2"], default="dhlp2")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--sigma", type=float, default=1e-3)
    ap.add_argument("--mode", choices=["batched", "sequential"],
                    default="batched")
    ap.add_argument("--backend", "--engine", dest="backend", default="dense",
                    help="engine-registry backend "
                         "(dense/sparse/sparse_coo/kernel/sharded/auto)")
    ap.add_argument("--devices", type=int, default=None,
                    help="edge-shard count for --backend sharded")
    ap.add_argument("--drugs", type=int, default=223)
    ap.add_argument("--diseases", type=int, default=150)
    ap.add_argument("--targets", type=int, default=95)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top-k", type=int, default=20)
    ap.add_argument("--entity", type=int, default=0,
                    help="drug id whose target ranking is printed")
    ap.add_argument("--out", default=None, help="write outputs npz here")
    args = ap.parse_args()

    from repro.core import LPConfig, extract_outputs
    from repro.data.drugnet import DrugNetSpec, make_drugnet
    from repro.engine import UnknownBackendError, make_engine, resolve_backend

    dn = make_drugnet(DrugNetSpec(
        n_drug=args.drugs, n_disease=args.diseases, n_target=args.targets,
        seed=args.seed,
    ))
    net = dn.network
    norm = net.normalize()
    print(f"[solve] network: {net.sizes} nodes/type, {net.num_edges} edges")

    cfg = LPConfig(
        alg=args.alg, alpha=args.alpha, sigma=args.sigma, mode=args.mode,
    )
    try:
        backend = resolve_backend(
            args.backend, num_nodes=net.num_nodes, config=cfg
        )
    except UnknownBackendError as e:
        ap.error(str(e))
    kw = {"devices": args.devices} if backend == "sharded" else {}
    engine = make_engine(backend, cfg, **kw)
    print(f"[solve] backend: {backend}")
    t0 = time.time()
    res = engine.run(norm)
    dt = time.time() - t0
    print(
        f"[solve] {args.alg} converged={res.converged} "
        f"outer={res.outer_iters} inner={res.inner_iters} "
        f"supersteps={res.supersteps} in {dt:.2f}s"
    )

    out = extract_outputs(res.F, norm)
    names = dn.pair_names
    for pair, name in names.items():
        m = out.interactions[pair]
        print(f"[solve] {name}: {m.shape}, mean score {m.mean():.4g}")

    top = out.ranked_candidates((0, 2), args.entity, args.top_k)
    print(f"[solve] top-{args.top_k} targets for drug {args.entity}: "
          f"{top.tolist()}")

    if args.out:
        np.savez_compressed(
            args.out,
            drug_disease=out.interactions[(0, 1)],
            drug_target=out.interactions[(0, 2)],
            disease_target=out.interactions[(1, 2)],
            sim_drug=out.similarities[0],
            sim_disease=out.similarities[1],
            sim_target=out.similarities[2],
        )
        print(f"[solve] outputs written to {args.out}")


if __name__ == "__main__":
    main()
