"""DEPRECATED entry point — delegates to the unified driver.

``python -m repro.launch.solve`` built the case-study network, ran
DHLP-1/2 to σ-convergence, and printed the three outputs.  That workflow
is now one declarative RunSpec executed by ``python -m repro run``
(DESIGN.md §13); this module forwards its legacy flag surface to the
``repro solve`` shim (same flags, same prints, byte-identical rankings)
and warns.

  PYTHONPATH=src python -m repro run --alg dhlp2 --sigma 1e-3 --top-k 20
  PYTHONPATH=src python -m repro run --backend sharded --devices 2
"""

from __future__ import annotations

import sys

from repro.launch.cli import solve_main


def main() -> None:
    sys.exit(solve_main(sys.argv[1:]))


if __name__ == "__main__":
    main()
