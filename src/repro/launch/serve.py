"""Online serving driver — long-lived query engine over the drug network.

Where ``repro.launch.solve`` is one-shot (build, solve, print, exit), this
driver stands up the ``repro/serve`` stack — micro-batching scheduler,
column LRU with warm starts, incremental GraphDelta updates — and plays a
synthetic query workload against it, reporting QPS and latency
percentiles.

  PYTHONPATH=src python -m repro.launch.serve --requests 200
  PYTHONPATH=src python -m repro.launch.serve --requests 2000 \
      --engine sparse --zipf 1.2 --deltas 3 --max-batch 128
"""
from __future__ import annotations

import argparse
import collections
import time

import numpy as np


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--alg", choices=["dhlp1", "dhlp2"], default="dhlp2")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--sigma", type=float, default=1e-3)
    ap.add_argument(
        "--engine",
        choices=["dense", "sparse", "sparse_coo", "kernel", "sharded",
                 "auto"],
        default="dense",
        help="engine-registry backend (sharded uses the host's devices)",
    )
    ap.add_argument(
        "--refresh-rounds", type=int, default=0,
        help="fused LP rounds to advance stale hints after each delta",
    )
    ap.add_argument("--drugs", type=int, default=223)
    ap.add_argument("--diseases", type=int, default=150)
    ap.add_argument("--targets", type=int, default=95)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top-k", type=int, default=20)
    ap.add_argument("--requests", type=int, default=200,
                    help="number of queries to play")
    ap.add_argument("--zipf", type=float, default=1.3,
                    help="popularity skew; higher = more repeat queries")
    ap.add_argument("--deltas", type=int, default=0,
                    help="graph edits interleaved through the workload")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--queue-depth", type=int, default=1024)
    ap.add_argument("--cache-columns", type=int, default=4096)
    ap.add_argument("--no-warm-start", action="store_true")
    return ap


def main() -> None:
    ap = build_parser()
    args = ap.parse_args()
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.zipf <= 1.0:
        ap.error("--zipf must be > 1 (numpy zipf exponent)")

    from repro.core import GraphDelta, LPConfig
    from repro.data.drugnet import DrugNetSpec, make_drugnet
    from repro.serve import LPServeEngine, QuerySpec, ServeConfig
    from repro.serve.types import percentiles

    dn = make_drugnet(DrugNetSpec(
        n_drug=args.drugs, n_disease=args.diseases, n_target=args.targets,
        seed=args.seed,
    ))
    net = dn.network
    print(f"[serve] network: {net.sizes} nodes/type, {net.num_edges} edges")

    cfg = ServeConfig(
        lp=LPConfig(alg=args.alg, alpha=args.alpha, sigma=args.sigma,
                    seed_mode="fixed"),
        engine=args.engine,
        cache_columns=args.cache_columns,
        warm_start=not args.no_warm_start,
        refresh_rounds=args.refresh_rounds,
        max_batch=args.max_batch,
        max_wait_s=args.max_wait_ms / 1e3,
        queue_depth=args.queue_depth,
    )
    engine = LPServeEngine(net, cfg)
    engine.start()

    # workload: zipf-popular drugs querying their target candidates,
    # drug→target being the paper's headline repositioning direction
    rng = np.random.default_rng(args.seed)
    n_drug = net.sizes[0]
    ranks = rng.permutation(n_drug)
    draws = np.minimum(rng.zipf(args.zipf, size=args.requests), n_drug) - 1
    entities = ranks[draws]
    delta_at = (
        set(np.linspace(0, args.requests, args.deltas + 2, dtype=int)[1:-1])
        if args.deltas
        else set()
    )

    futures = []
    t0 = time.monotonic()
    for i, ent in enumerate(entities):
        if i in delta_at:
            # a fresh drug-target association lands online
            d = int(rng.integers(n_drug))
            t = int(rng.integers(net.sizes[2]))
            v = engine.apply_delta(GraphDelta(assoc=[((0, 2), d, t, 1.0)]))
            print(f"[serve] delta @req {i}: +assoc drug {d} → target {t} "
                  f"(version {v})")
        futures.append(engine.submit(
            QuerySpec(entity=int(ent), target_type=2, top_k=args.top_k)
        ))
    results = [f.result(timeout=600) for f in futures]
    wall = time.monotonic() - t0
    engine.stop()

    lats = [r.latency_s for r in results]
    pcts = percentiles(lats)
    by_source = collections.Counter(r.source for r in results)
    rounds_by = collections.defaultdict(list)
    for r in results:
        rounds_by[r.source].append(r.rounds)
    print(f"[serve] {len(results)} queries in {wall:.2f}s "
          f"→ {len(results) / wall:.1f} QPS")
    print(f"[serve] latency p50={pcts['p50'] * 1e3:.2f}ms "
          f"p95={pcts['p95'] * 1e3:.2f}ms p99={pcts['p99'] * 1e3:.2f}ms")
    for src in ("cache", "warm", "cold"):
        if by_source[src]:
            mr = float(np.mean(rounds_by[src]))
            print(f"[serve]   {src:5s}: {by_source[src]:5d} queries, "
                  f"mean {mr:.1f} LP rounds")
    st = engine.batcher.stats
    cs = engine.columns.stats
    print(f"[serve] batches={st.batches} mean_batch={st.mean_batch_size:.1f} "
          f"rejected={st.rejected}")
    print(f"[serve] cache: hit_rate={cs.hit_rate:.2%} "
          f"evictions={cs.evictions} demoted={cs.invalidations}")
    r0 = results[0]
    print(f"[serve] sample: drug {r0.spec.entity} top-{len(r0.candidates)} "
          f"targets {r0.candidates.tolist()}")


if __name__ == "__main__":
    main()
