"""DEPRECATED entry point — delegates to the unified driver.

``python -m repro.launch.serve`` stood up the online query engine and
played a synthetic zipf workload against it.  That workflow is now a
RunSpec with a ``serve`` section executed by ``python -m repro run``
(DESIGN.md §13); this module forwards its legacy flag surface to the
``repro serve`` shim and warns.

  PYTHONPATH=src python -m repro run --serve --requests 200
  PYTHONPATH=src python -m repro run --serve --requests 2000 \
      --backend sparse --zipf 1.2 --deltas 3 --max-batch 128
"""

from __future__ import annotations

import sys

from repro.launch.cli import serve_main


def main() -> None:
    sys.exit(serve_main(sys.argv[1:]))


if __name__ == "__main__":
    main()
