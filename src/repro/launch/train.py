"""Guarded training loop: any arch × synthetic data × fault tolerance.

:func:`run_training` is the driver — it executes a
:class:`~repro.api.spec.TrainSpec`: build the arch's config (reduced by
default on CPU — ``full=True`` on a real pod), construct the train step,
restore the latest checkpoint if present, then run steps with:

  * periodic (optionally async) checkpoints,
  * retry/restore on transient failures (``StepGuard``),
  * straggler watch (EWMA step times),
  * optional injected faults (``inject_fault``) for recovery drills.

``Session.train()`` calls it for specs with a ``train`` section; the
module entry point is a deprecated shim that builds the equivalent
train-only RunSpec:

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20

prefer:

  PYTHONPATH=src python -m repro run --spec train.json
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def build_lm_job(arch: str, cfg, batch: int, seq: int):
    from repro.data.lm import LMDataConfig, sample_batch
    from repro.models import transformer as tfm
    from repro.optim import adamw, linear_warmup_cosine

    opt = adamw(linear_warmup_cosine(3e-4, 20, 2000))
    step_fn = jax.jit(tfm.make_train_step(cfg, opt), donate_argnums=(0, 1))
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    dcfg = LMDataConfig(vocab=cfg.vocab, batch=batch, seq_len=seq)

    def next_batch(step: int) -> Dict[str, Any]:
        return {k: jnp.asarray(v) for k, v in sample_batch(dcfg, step).items()}

    return params, state, step_fn, next_batch


def build_gnn_job(arch: str, spec):
    from repro.configs.cells import gnn_cell
    from repro.data.graphs import planted_partition_graph
    from repro.models import gnn as gnn_mod
    from repro.optim import adamw

    cfg = spec.reduced_config
    opt = adamw(1e-2)
    data = planted_partition_graph(
        n_nodes=512, n_edges=2048, n_classes=getattr(cfg, "n_classes", 4),
        d_feat=getattr(cfg, "d_feat", 32), seed=0,
    )
    e = data.edges
    from repro.core import symmetric_normalize
    from repro.graph.structures import EdgeList

    A = symmetric_normalize(e.to_dense())
    el = EdgeList.from_dense(A)
    batch = {
        "feats": jnp.asarray(data.feats),
        "src": jnp.asarray(el.src),
        "dst": jnp.asarray(el.dst),
        "w": jnp.asarray(el.weights()),
        "labels": jnp.asarray(data.labels),
        "label_mask": jnp.asarray(data.train_mask.astype(np.float32)),
    }
    is_gat = type(cfg).__name__ == "GATConfig"

    def loss_fn(params, b):
        if is_gat:
            logits = gnn_mod.gat_forward(
                cfg, params, b["feats"], b["src"], b["dst"], 512
            )
        else:
            logits = gnn_mod.gcn_forward(
                cfg, params, b["feats"], b["src"], b["dst"], b["w"], 512
            )
        logits32 = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits32, axis=-1)
        gold = jnp.take_along_axis(
            logits32, b["labels"][:, None], axis=-1
        )[:, 0]
        return ((logz - gold) * b["label_mask"]).sum() / b["label_mask"].sum()

    def step(params, opt_state, b):
        loss, grads = jax.value_and_grad(loss_fn)(params, b)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    init = gnn_mod.gat_init if is_gat else gnn_mod.gcn_init
    params = init(cfg, jax.random.PRNGKey(0))
    return params, opt.init(params), jax.jit(step), lambda s: batch


def build_recsys_job(arch: str, spec, batch: int):
    from repro.data.recsys import CTRDataConfig, sample_ctr_batch
    from repro.models import recsys as rec
    from repro.optim import adamw

    cfg = spec.reduced_config
    opt = adamw(1e-3)
    step_fn = jax.jit(rec.make_train_step(cfg, opt))
    params = rec.widedeep_init(cfg, jax.random.PRNGKey(0))
    dcfg = CTRDataConfig(
        n_sparse=cfg.n_sparse, n_dense=cfg.n_dense,
        vocab_per_field=cfg.vocab_per_field,
    )

    def next_batch(step: int):
        return {
            k: jnp.asarray(v)
            for k, v in sample_ctr_batch(dcfg, batch, step).items()
        }

    return params, opt.init(params), step_fn, next_batch


def run_training(spec, *, echo=print) -> Dict[str, Any]:
    """Execute a :class:`~repro.api.spec.TrainSpec`; returns loop stats.

    ``echo`` receives the progress lines (``Session.train`` forwards the
    run-level echo).  Raises :class:`~repro.api.spec.SpecError` for
    lp-family archs — those converge via the ``solve`` section, not SGD.
    """
    from repro.api.spec import SpecError
    from repro.configs import get_arch
    from repro.ft import FailureInjector, StepGuard, StragglerWatch

    arch = get_arch(spec.arch)
    if arch.family == "lm":
        cfg = arch.full_config if spec.full else arch.reduced_config
        params, state, step_fn, next_batch = build_lm_job(
            spec.arch, cfg, spec.batch, spec.seq
        )
    elif arch.family == "gnn":
        params, state, step_fn, next_batch = build_gnn_job(spec.arch, arch)
    elif arch.family == "recsys":
        params, state, step_fn, next_batch = build_recsys_job(
            spec.arch, arch, spec.batch
        )
    else:
        raise SpecError(
            f"train.arch: family {arch.family!r} trains via the solve "
            "section (launch/solve.py) instead"
        )

    ckpt = None
    start_step = 0
    resumed = False
    if spec.ckpt_dir:
        from repro.checkpoint import CheckpointManager

        ckpt = CheckpointManager(
            spec.ckpt_dir, keep_last=3, async_write=spec.ckpt_async
        )
        restored_step, restored = ckpt.restore_latest((params, state))
        if restored is not None:
            params, state = restored
            start_step = restored_step + 1
            resumed = True
            echo(f"[train] resumed from step {restored_step}")

    injector = FailureInjector(fail_at=tuple(spec.inject_fault))
    watch = StragglerWatch()

    # restore-replay closure for StepGuard
    snapshot = {"step": start_step, "params": params, "state": state}

    def restore():
        if ckpt is not None:
            s, restored = ckpt.restore_latest(
                (snapshot["params"], snapshot["state"])
            )
            if restored is not None:
                snapshot["params"], snapshot["state"] = restored
                snapshot["step"] = s + 1
                echo(f"[train] restored from checkpoint step {s}")
        return snapshot["step"], (snapshot["params"], snapshot["state"])

    guard = StepGuard(max_retries=2, restore_fn=restore)

    step = start_step
    losses = []
    while step < spec.steps:
        batch = next_batch(step)
        t0 = time.time()

        def run_one():
            injector.maybe_fail(step)
            return step_fn(snapshot["params"], snapshot["state"], batch)

        p, s, loss = guard.run(run_one)
        snapshot["params"], snapshot["state"] = p, s
        loss = float(loss)
        losses.append(loss)
        dt = time.time() - t0
        slow = watch.observe(dt)
        if step % spec.log_every == 0 or step == spec.steps - 1:
            echo(
                f"[train] step {step} loss {loss:.4f} "
                f"({dt*1e3:.0f} ms{' SLOW' if slow else ''})"
            )
        if ckpt is not None and (step + 1) % spec.ckpt_every == 0:
            ckpt.save(step, (snapshot["params"], snapshot["state"]),
                      metadata={"loss": loss})
        step += 1
        snapshot["step"] = step

    if ckpt is not None:
        ckpt.save(spec.steps - 1, (snapshot["params"], snapshot["state"]))
        ckpt.wait()
    if losses:
        echo(
            f"[train] done: first loss {losses[0]:.4f} → last "
            f"{losses[-1]:.4f}; retries={guard.retries} "
            f"restores={guard.restores} slow_steps={watch.slow_steps}"
        )
    return {
        "arch": spec.arch,
        "family": arch.family,
        "steps": len(losses),
        "first_loss": losses[0] if losses else float("nan"),
        "last_loss": losses[-1] if losses else float("nan"),
        "retries": guard.retries,
        "restores": guard.restores,
        "slow_steps": watch.slow_steps,
        "resumed": resumed,
    }


def main() -> None:
    """Deprecated CLI shim: builds the equivalent train-only RunSpec and
    runs it through ``Session.train()`` (no results/ writes)."""
    import warnings

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the FULL config (pod-scale; default: reduced)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-async", action="store_true")
    ap.add_argument("--inject-fault", type=int, nargs="*", default=[])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    warnings.warn(
        "python -m repro.launch.train is a shim; use a RunSpec with a "
        "'train' section (python -m repro run) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.api import RunSpec, Session, SpecError, TrainSpec

    try:
        spec = RunSpec(
            train=TrainSpec(
                arch=args.arch,
                steps=args.steps,
                batch=args.batch,
                seq=args.seq,
                full=args.full,
                ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every,
                ckpt_async=args.ckpt_async,
                inject_fault=tuple(args.inject_fault),
                log_every=args.log_every,
            )
        )
        art = Session(spec).train(echo=lambda msg: print(msg, flush=True))
    except SpecError as e:
        print(f"[train] {e}")
        raise SystemExit(2)
    print(f"[train] artifact: {art.kind} run_id={art.run_id}")


if __name__ == "__main__":
    main()
