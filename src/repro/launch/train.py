"""Training driver: any ``--arch`` × synthetic data × fault tolerance.

The production path: build the arch's config (reduced by default on CPU —
pass ``--full`` on a real pod), construct the train step, restore the
latest checkpoint if present, then run steps with:

  * periodic (optionally async) checkpoints,
  * retry/restore on transient failures (``StepGuard``),
  * straggler watch (EWMA step times),
  * optional injected faults (``--inject-fault N``) for recovery drills.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20
  PYTHONPATH=src python -m repro.launch.train --arch wide-deep --steps 100
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def build_lm_job(arch: str, cfg, batch: int, seq: int):
    from repro.data.lm import LMDataConfig, sample_batch
    from repro.models import transformer as tfm
    from repro.optim import adamw, linear_warmup_cosine

    opt = adamw(linear_warmup_cosine(3e-4, 20, 2000))
    step_fn = jax.jit(tfm.make_train_step(cfg, opt), donate_argnums=(0, 1))
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    dcfg = LMDataConfig(vocab=cfg.vocab, batch=batch, seq_len=seq)

    def next_batch(step: int) -> Dict[str, Any]:
        return {k: jnp.asarray(v) for k, v in sample_batch(dcfg, step).items()}

    return params, state, step_fn, next_batch


def build_gnn_job(arch: str, spec):
    from repro.configs.cells import gnn_cell
    from repro.data.graphs import planted_partition_graph
    from repro.models import gnn as gnn_mod
    from repro.optim import adamw

    cfg = spec.reduced_config
    opt = adamw(1e-2)
    data = planted_partition_graph(
        n_nodes=512, n_edges=2048, n_classes=getattr(cfg, "n_classes", 4),
        d_feat=getattr(cfg, "d_feat", 32), seed=0,
    )
    e = data.edges
    from repro.core import symmetric_normalize
    from repro.graph.structures import EdgeList

    A = symmetric_normalize(e.to_dense())
    el = EdgeList.from_dense(A)
    batch = {
        "feats": jnp.asarray(data.feats),
        "src": jnp.asarray(el.src),
        "dst": jnp.asarray(el.dst),
        "w": jnp.asarray(el.weights()),
        "labels": jnp.asarray(data.labels),
        "label_mask": jnp.asarray(data.train_mask.astype(np.float32)),
    }
    is_gat = type(cfg).__name__ == "GATConfig"

    def loss_fn(params, b):
        if is_gat:
            logits = gnn_mod.gat_forward(
                cfg, params, b["feats"], b["src"], b["dst"], 512
            )
        else:
            logits = gnn_mod.gcn_forward(
                cfg, params, b["feats"], b["src"], b["dst"], b["w"], 512
            )
        logits32 = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits32, axis=-1)
        gold = jnp.take_along_axis(
            logits32, b["labels"][:, None], axis=-1
        )[:, 0]
        return ((logz - gold) * b["label_mask"]).sum() / b["label_mask"].sum()

    def step(params, opt_state, b):
        loss, grads = jax.value_and_grad(loss_fn)(params, b)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    init = gnn_mod.gat_init if is_gat else gnn_mod.gcn_init
    params = init(cfg, jax.random.PRNGKey(0))
    return params, opt.init(params), jax.jit(step), lambda s: batch


def build_recsys_job(arch: str, spec, batch: int):
    from repro.data.recsys import CTRDataConfig, sample_ctr_batch
    from repro.models import recsys as rec
    from repro.optim import adamw

    cfg = spec.reduced_config
    opt = adamw(1e-3)
    step_fn = jax.jit(rec.make_train_step(cfg, opt))
    params = rec.widedeep_init(cfg, jax.random.PRNGKey(0))
    dcfg = CTRDataConfig(
        n_sparse=cfg.n_sparse, n_dense=cfg.n_dense,
        vocab_per_field=cfg.vocab_per_field,
    )

    def next_batch(step: int):
        return {
            k: jnp.asarray(v)
            for k, v in sample_ctr_batch(dcfg, batch, step).items()
        }

    return params, opt.init(params), step_fn, next_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the FULL config (pod-scale; default: reduced)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-async", action="store_true")
    ap.add_argument("--inject-fault", type=int, nargs="*", default=[])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.ft import FailureInjector, StepGuard, StragglerWatch

    spec = get_arch(args.arch)
    if spec.family == "lm":
        cfg = spec.full_config if args.full else spec.reduced_config
        params, state, step_fn, next_batch = build_lm_job(
            args.arch, cfg, args.batch, args.seq
        )
    elif spec.family == "gnn":
        params, state, step_fn, next_batch = build_gnn_job(args.arch, spec)
    elif spec.family == "recsys":
        params, state, step_fn, next_batch = build_recsys_job(
            args.arch, spec, args.batch
        )
    else:
        raise SystemExit(
            f"family {spec.family!r} trains via launch/solve.py instead"
        )

    ckpt = None
    start_step = 0
    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager

        ckpt = CheckpointManager(
            args.ckpt_dir, keep_last=3, async_write=args.ckpt_async
        )
        restored_step, restored = ckpt.restore_latest((params, state))
        if restored is not None:
            params, state = restored
            start_step = restored_step + 1
            print(f"[train] resumed from step {restored_step}")

    injector = FailureInjector(fail_at=tuple(args.inject_fault))
    watch = StragglerWatch()

    # restore-replay closure for StepGuard
    snapshot = {"step": start_step, "params": params, "state": state}

    def restore():
        if ckpt is not None:
            s, restored = ckpt.restore_latest(
                (snapshot["params"], snapshot["state"])
            )
            if restored is not None:
                snapshot["params"], snapshot["state"] = restored
                snapshot["step"] = s + 1
                print(f"[train] restored from checkpoint step {s}")
        return snapshot["step"], (snapshot["params"], snapshot["state"])

    guard = StepGuard(max_retries=2, restore_fn=restore)

    step = start_step
    losses = []
    while step < args.steps:
        batch = next_batch(step)
        t0 = time.time()

        def run_one():
            injector.maybe_fail(step)
            return step_fn(snapshot["params"], snapshot["state"], batch)

        p, s, loss = guard.run(run_one)
        snapshot["params"], snapshot["state"] = p, s
        loss = float(loss)
        losses.append(loss)
        dt = time.time() - t0
        slow = watch.observe(dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"[train] step {step} loss {loss:.4f} "
                f"({dt*1e3:.0f} ms{' SLOW' if slow else ''})",
                flush=True,
            )
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step, (snapshot["params"], snapshot["state"]),
                      metadata={"loss": loss})
        step += 1
        snapshot["step"] = step

    if ckpt is not None:
        ckpt.save(args.steps - 1, (snapshot["params"], snapshot["state"]))
        ckpt.wait()
    print(
        f"[train] done: first loss {losses[0]:.4f} → last {losses[-1]:.4f}; "
        f"retries={guard.retries} restores={guard.restores} "
        f"slow_steps={watch.slow_steps}"
    )


if __name__ == "__main__":
    main()
