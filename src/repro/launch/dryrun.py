import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:
  1. builds the step function and ShapeDtypeStruct input specs (no
     allocation — the FULL configs are exercised only here),
  2. jits with the family's NamedShardings on the production mesh
     (16×16 single-pod, 2×16×16 multi-pod),
  3. ``.lower().compile()`` — any sharding mismatch, OOM-at-compile or
     unsupported collective is a bug in the system,
  4. records memory_analysis / cost_analysis / a collective-bytes census
     of the HLO into a JSONL file that benchmarks/roofline.py consumes.

The sweep is a RunSpec stage now (``{"dryrun": {...}}`` →
``Session.dryrun``, DESIGN.md §13/§14): the census lands under
``results/<run_id>/telemetry/dryrun.jsonl`` in the telemetry artifact
format.  ``main`` below is a deprecation shim over that path — it keeps
the old flags and mirrors the JSONL to ``--out`` for existing roofline
invocations.

Usage (deprecated shim):
  python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k \
      --mesh single --out results/dryrun.jsonl
  python -m repro.launch.dryrun --all --mesh both

Spec-driven equivalent:
  python -m repro run --spec '{"dryrun": {"archs": ["stablelm-1.6b"]}}'
"""
import argparse
import re
import time
import traceback
from typing import Any, Dict

import jax


_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type, incl. tuple types."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_BLOCK_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")


def collective_census(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Count collective ops and sum their result-shape bytes.

    Census is split into two buckets: ops in top-level/entry computations
    vs ops inside while-loop body computations ("..body.." names).  The
    roofline multiplies the loop bucket by the known trip count (scan over
    layers / LP rounds) — XLA's static text contains each body once.
    """
    out: Dict[str, Dict[str, float]] = {}
    for k in _COLLECTIVES:
        out[k] = {"count": 0, "bytes": 0, "loop_count": 0, "loop_bytes": 0}
    in_loop_block = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _BLOCK_RE.match(line)
        if m:
            name = m.group(2) or ""
            in_loop_block = ("body" in name) or ("while" in name)
            continue
        for cname in _COLLECTIVES:
            if f" {cname}(" in stripped or f"{cname}-start(" in stripped:
                lhs = stripped.split("=", 1)
                type_str = lhs[1] if len(lhs) > 1 else stripped
                type_str = type_str.strip().split("(", 1)[0]
                b = _shape_bytes(type_str)
                if in_loop_block:
                    out[cname]["loop_count"] += 1
                    out[cname]["loop_bytes"] += b
                else:
                    out[cname]["count"] += 1
                    out[cname]["bytes"] += b
                break
    return out


def run_cell(arch: str, shape: str, mesh_kind: str) -> Dict[str, Any]:
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shardings import shardings_for

    spec = get_arch(arch)
    cell = spec.make_cell(shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "family": spec.family, "kind": cell.kind, "meta": cell.meta,
    }
    if cell.skip_reason:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell.skip_reason
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    in_sh = shardings_for(mesh, spec.family, cell)

    from repro.parallel.hints import set_ambient_mesh
    set_ambient_mesh(mesh)
    t0 = time.time()
    try:
        with mesh:
            jitted = jax.jit(
                cell.step_fn,
                in_shardings=in_sh,
                donate_argnums=cell.donate,
            )
            lowered = jitted.lower(*cell.input_specs)
            t_lower = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t1
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        return rec

    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in (
                "temp_size_in_bytes", "argument_size_in_bytes",
                "output_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # noqa: BLE001
        rec["memory"] = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["cost"] = {
            k: float(v)
            for k, v in cost.items()
            if k in ("flops", "bytes accessed", "transcendentals",
                     "bytes accessed output", "optimal_seconds")
            or k.startswith("bytes accessed")
        }
    except Exception as e:  # noqa: BLE001
        rec["cost"] = {"error": str(e)}

    try:
        hlo = compiled.as_text()
        rec["collectives"] = collective_census(hlo)
        rec["hlo_bytes"] = len(hlo)
    except Exception as e:  # noqa: BLE001
        rec["collectives"] = {"error": str(e)}

    # scan-cost probes: XLA counts a while body ONCE regardless of the
    # trip count, so trip=0 (no layers) and trip=1 separate top-level cost
    # from one body execution: f(L) = f(0) + L·(f(1) − f(0)).
    if cell.meta.get("scan_trip") and spec.make_probe_cell is not None:
        rec["probe"] = {}
        for trip in (0, 1):
            try:
                pc = spec.make_probe_cell(shape, trip)
                with mesh:
                    pcomp = jax.jit(
                        pc.step_fn, in_shardings=in_sh,
                        donate_argnums=pc.donate,
                    ).lower(*pc.input_specs).compile()
                pcost = pcomp.cost_analysis()
                if isinstance(pcost, (list, tuple)):
                    pcost = pcost[0]
                rec["probe"][str(trip)] = {
                    "flops": float(pcost.get("flops", 0.0)),
                    "bytes": float(pcost.get("bytes accessed", 0.0)),
                }
            except Exception as e:  # noqa: BLE001
                rec["probe"][str(trip)] = {"error": str(e)}
    set_ambient_mesh(None)
    return rec


def main() -> None:
    """Deprecated CLI shim: builds the equivalent dryrun-only RunSpec and
    runs it through :class:`repro.api.session.Session`."""
    import shutil
    import warnings

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch × shape) cell")
    ap.add_argument("--include-extra", action="store_true",
                    help="also run the dhlp-bio LP cells")
    ap.add_argument("--out", default="results/dryrun.jsonl",
                    help="mirror the census JSONL here (legacy path)")
    ap.add_argument("--skip-done", action="store_true",
                    help="(deprecated) ignored — the spec-driven sweep "
                         "always runs every configured cell")
    ap.add_argument("--results-root", default="results")
    args = ap.parse_args()

    warnings.warn(
        "python -m repro.launch.dryrun is a shim; use a RunSpec with a "
        "'dryrun' section (python -m repro run) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    if args.skip_done:
        print("[dryrun] --skip-done is deprecated and ignored")
    if not args.all and not args.arch:
        ap.error("--arch required unless --all")

    from repro.api import DryrunSpec, RunSpec, Session

    spec = RunSpec(
        dryrun=DryrunSpec(
            archs=(args.arch,) if args.arch else None,
            shapes=(args.shape,) if args.shape else None,
            mesh=args.mesh,
            include_extra=args.include_extra,
        )
    )
    session = Session(spec, results_root=args.results_root)
    artifacts = session.run()
    census = os.path.join(session.run_dir, "telemetry", "dryrun.jsonl")
    if args.out and os.path.exists(census):
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        shutil.copyfile(census, args.out)
        print(f"[dryrun] census mirrored to {args.out}")
    summary = next(a for a in artifacts if a.kind == "dryrun").summary()
    print(f"[dryrun] {summary['cells']} cells: {summary['statuses']}")


if __name__ == "__main__":
    main()
