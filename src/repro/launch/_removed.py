"""Removal stubs for the retired ``repro.launch.*`` entry points.

The deprecation shims (``python -m repro.launch.solve`` etc.) carried
the pre-RunSpec flag surfaces through one migration window; that window
has closed.  Each retired module now calls :func:`removed_main`, which
prints the migration hint and exits non-zero — loudly, instead of
silently drifting from the unified driver's behavior.

The positional subcommands (``python -m repro solve|serve|scenario|
bench``) keep the legacy flag surfaces and remain supported.
"""

from __future__ import annotations

import sys


def removal_message(name: str) -> str:
    return (
        f"repro.launch.{name} has been removed - use "
        f"`python -m repro run` (DESIGN.md §13) or the "
        f"`python -m repro {name}` subcommand, which keeps the old "
        f"flag surface"
    )


def removed_main(name: str) -> None:
    print(removal_message(name), file=sys.stderr)
    raise SystemExit(2)
