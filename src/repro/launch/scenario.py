"""REMOVED entry point — see :mod:`repro.launch._removed`.

``python -m repro.launch.scenario`` was a deprecation shim over the unified
driver; the migration window has closed.  Use ``python -m repro run``
(RunSpec, DESIGN.md §13) or ``python -m repro scenario`` (legacy flags).
"""

from __future__ import annotations

from repro.launch._removed import removed_main


def main() -> None:
    removed_main("scenario")


if __name__ == "__main__":
    main()
