"""Scenario driver — list/generate/solve named workloads (DESIGN.md §12).

The scenario registry is the workload-side twin of the engine-backend
registry: this CLI crosses the two.

  PYTHONPATH=src python -m repro.launch.scenario --list
  PYTHONPATH=src python -m repro.launch.scenario --info powerlaw --scale 0.05
  PYTHONPATH=src python -m repro.launch.scenario --solve kpartite_heterophilic \
      --backends dense,sparse --scale 0.4
  PYTHONPATH=src python -m repro.launch.scenario --solve powerlaw --scale 1.0 \
      --backends sparse,kernel          # the >=1M-edge cell
  PYTHONPATH=src python -m repro.launch.scenario --cv kpartite5 --folds 4
  PYTHONPATH=src python -m repro.launch.scenario --trace streaming \
      --process bursty
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

# one home for the cross-backend agreement rule: the CLI and the
# CI-gated scenario_matrix suite must never drift apart
from repro.bench.matrix import AGREEMENT_TOL


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--list", action="store_true",
                      help="list registered scenarios")
    mode.add_argument("--info", metavar="NAME",
                      help="generate NAME and print its statistics")
    mode.add_argument("--solve", metavar="NAME",
                      help="solve NAME on one or more backends and score "
                           "planted-edge recovery")
    mode.add_argument("--cv", metavar="NAME",
                      help="k-fold CV against NAME's planted truth")
    mode.add_argument("--trace", metavar="NAME",
                      help="generate a query trace for NAME and print "
                           "its arrival statistics")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="size multiplier passed to the builder")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backends", default="auto",
                    help="comma-separated engine-registry keys")
    ap.add_argument("--devices", type=int, default=None,
                    help="edge-shard count for the sharded backend")
    ap.add_argument("--sigma", type=float, default=1e-4)
    ap.add_argument("--holdout-frac", type=float, default=0.1)
    ap.add_argument("--max-entities", type=int, default=32)
    ap.add_argument("--folds", type=int, default=5)
    ap.add_argument("--process", default="poisson",
                    help="arrival process for --trace")
    ap.add_argument("--rate-qps", type=float, default=50.0)
    ap.add_argument("--horizon-s", type=float, default=4.0)
    ap.add_argument("--json", default=None, help="write the report here")
    return ap


def _emit(report: dict, path) -> None:
    if path:
        with open(path, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"report written to {path}")


def cmd_list() -> dict:
    import repro.scenarios as sc

    rows = sc.list_rows()
    width = max(len(r["name"]) for r in rows)
    for r in rows:
        tags = f" [{','.join(r['tags'])}]" if r["tags"] else ""
        print(f"{r['name']:<{width}}  {r['description']}{tags}")
    print(f"\n{len(rows)} scenarios registered")
    return {"scenarios": rows}


def cmd_info(args) -> dict:
    import repro.scenarios as sc

    t0 = time.time()
    bundle = sc.generate(args.info, scale=args.scale, seed=args.seed)
    desc = bundle.describe()
    desc.pop("arriving_truth", None)
    desc["generate_s"] = round(time.time() - t0, 3)
    for k, v in desc.items():
        print(f"{k:>20}: {v}")
    return desc


def cmd_solve(args) -> dict:
    """Solve on every requested backend; report recovery AUC + agreement.

    The first backend is the reference for the cross-backend agreement
    check (pass ``dense`` first where the dense operator is feasible).
    """
    import repro.scenarios as sc
    from repro.engine import resolve_backend

    bundle = sc.generate(args.solve, scale=args.scale, seed=args.seed)
    net = bundle.network
    print(
        f"[scenario] {bundle.name}: T={net.num_types} types, "
        f"{net.num_nodes} nodes, {net.num_edges} edges"
    )
    problem = sc.make_recovery_problem(
        bundle,
        holdout_frac=args.holdout_frac,
        max_entities=args.max_entities,
        seed=args.seed,
    )
    cfg = sc.default_lp_config(sigma=args.sigma)
    backends = [b.strip() for b in args.backends.split(",") if b.strip()]
    report = {"scenario": bundle.name, "scale": args.scale,
              "nodes": net.num_nodes, "edges": net.num_edges,
              "eval_pair": list(problem.pair), "cells": []}
    F_ref, ref_name = None, None
    for key in backends:
        backend = resolve_backend(key, num_nodes=net.num_nodes, config=cfg)
        kw = (
            {"devices": args.devices}
            if backend == "sharded" and args.devices
            else {}
        )
        t0 = time.time()
        res = sc.solve_recovery(problem, backend, lp=cfg, **kw)
        dt = time.time() - t0
        cell = problem.metrics(res.F)
        cell.update({
            "backend": backend, "requested": key,
            "outer_iters": res.outer_iters, "seconds": round(dt, 3),
        })
        if F_ref is None:
            F_ref, ref_name = res.F, backend
        else:
            diff = float(np.max(np.abs(res.F - F_ref)))
            cell["max_abs_diff_vs_ref"] = diff
            cell["agree_ref"] = bool(diff <= AGREEMENT_TOL)
        report["cells"].append(cell)
        agree = (
            "" if "agree_ref" not in cell
            else f"  agree_vs_{ref_name}={cell['agree_ref']}"
        )
        print(
            f"[scenario] {backend:>10}: auc={cell['recovery_auc']:.4f} "
            f"aupr={cell['recovery_aupr']:.4f} "
            f"iters={res.outer_iters} {dt:.2f}s{agree}"
        )
    return report


def cmd_cv(args) -> dict:
    import repro.scenarios as sc
    from repro.eval.cv import summarize

    bundle = sc.generate(args.cv, scale=args.scale, seed=args.seed)
    backend = args.backends.split(",")[0].strip()
    t0 = time.time()
    results = sc.scenario_cross_validate(
        bundle,
        backend=backend,
        k=args.folds,
        seed=args.seed,
        lp=sc.default_lp_config(sigma=args.sigma),
    )
    summary = summarize(results)
    summary["seconds"] = round(time.time() - t0, 3)
    print(
        f"[scenario] {bundle.name} {args.folds}-fold CV on planted truth "
        f"({backend}): auc={summary['auc']:.4f} aupr={summary['aupr']:.4f} "
        f"best_acc={summary['best_acc']:.4f}"
    )
    return {"scenario": bundle.name, "backend": backend,
            "folds": args.folds, **summary}


def cmd_trace(args) -> dict:
    import repro.scenarios as sc

    bundle = sc.generate(args.trace, scale=args.scale, seed=args.seed)
    trace = sc.build_trace(
        bundle, args.process, rate_qps=args.rate_qps,
        horizon_s=args.horizon_s, seed=args.seed,
    )
    gaps = np.diff(trace.t) if len(trace) > 1 else np.zeros(1)
    uniq = len(np.unique(trace.entity))
    stats = {
        "scenario": bundle.name,
        "process": trace.process,
        "queries": len(trace),
        "offered_qps": round(len(trace) / trace.horizon_s, 2),
        "unique_entities": uniq,
        "gap_p50_ms": round(float(np.percentile(gaps, 50)) * 1e3, 3),
        "gap_p99_ms": round(float(np.percentile(gaps, 99)) * 1e3, 3),
        "deltas": len(bundle.deltas),
    }
    for k, v in stats.items():
        print(f"{k:>16}: {v}")
    return stats


def main() -> None:
    args = build_parser().parse_args()
    if args.list:
        report = cmd_list()
    elif args.info:
        report = cmd_info(args)
    elif args.solve:
        report = cmd_solve(args)
    elif args.cv:
        report = cmd_cv(args)
    else:
        report = cmd_trace(args)
    _emit(report, args.json)


if __name__ == "__main__":
    main()
