"""DEPRECATED entry point — delegates to the unified driver.

``python -m repro.launch.scenario`` listed/generated/solved named
workloads.  The solve/CV cores now run as RunSpecs through the Session
API (DESIGN.md §13); this module forwards its legacy flag surface to the
``repro scenario`` shim and warns.

  PYTHONPATH=src python -m repro run --network scenario:powerlaw \
      --scale 0.05 --eval recovery --backend sparse
  PYTHONPATH=src python -m repro scenario --list
"""

from __future__ import annotations

import sys

from repro.launch.cli import scenario_main


def main() -> None:
    sys.exit(scenario_main(sys.argv[1:]))


if __name__ == "__main__":
    main()
