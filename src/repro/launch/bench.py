"""DEPRECATED entry point — delegates to the unified driver.

The benchmark pass (historically ``python benchmarks/run.py``) now runs
through the shared driver behind ``python -m repro run --bench`` /
RunSpec ``bench`` sections (DESIGN.md §10/§13); this module forwards the
legacy flag surface to the ``repro bench`` shim and warns.

  PYTHONPATH=src python -m repro run --bench            # fast pass
  PYTHONPATH=src python -m repro run --bench --full     # paper scale
"""

from __future__ import annotations

import os
import sys

# sharded cells need fabricated host devices BEFORE any jax import —
# same peek as benchmarks/run.py and repro/__main__.py
_DEVICES = 8 if "--full" in sys.argv else 4
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={_DEVICES}"
)

from repro.launch.cli import bench_main  # noqa: E402


def main() -> None:
    sys.exit(bench_main(sys.argv[1:]))


if __name__ == "__main__":
    main()
