"""Production mesh builders.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to fabricate the device pool; smoke tests and benches never do, and
see the single real CPU device.
"""
from __future__ import annotations

from repro.parallel.hints import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(shape, axes):
    """Small mesh over whatever devices exist (tests, local runs)."""
    return make_mesh_compat(shape, axes)


def data_axes(mesh) -> tuple:
    """The batch/data axes of a mesh: ('pod','data') when multi-pod."""
    names = mesh.axis_names
    return tuple(n for n in names if n in ("pod", "data"))
