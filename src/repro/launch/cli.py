"""The one ``repro`` driver: ``python -m repro run <spec.json|flags>``.

Every workflow the repo has — one-shot solve, recovery/CV evaluation,
online serving, benchmark passes — executes through a declarative
:class:`~repro.api.spec.RunSpec` resolved by a
:class:`~repro.api.session.Session` (DESIGN.md §13).  This module is the
thin argparse layer over that API:

* ``run``       — the driver: a spec file, or flags that build one;
* ``obs``       — render/validate a run's telemetry (DESIGN.md §14);
* ``solve``, ``serve``, ``scenario``, ``bench`` — legacy-surface
  subcommands: the flag surfaces of the retired ``repro.launch.*``
  module entry points, kept as positional subcommands.

The legacy subcommands emit a ``DeprecationWarning``, build a RunSpec,
and execute it through the same Session the driver uses — rankings are
byte-identical to the scripts they replaced.  The old module entry
points (``python -m repro.launch.solve`` etc.) are retired and exit
with a migration hint (:mod:`repro.launch._removed`).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import warnings
from typing import Any, Dict, List, Optional

ARRIVAL_CHOICES = ("poisson", "bursty", "diurnal")


def _warn_deprecated(old: str, hint: str) -> None:
    warnings.warn(
        f"{old} is deprecated — use `python -m repro run` ({hint})",
        DeprecationWarning,
        stacklevel=3,
    )


def _split_csv(s: Optional[str]) -> Optional[List[str]]:
    if s is None:
        return None
    return [p.strip() for p in s.split(",") if p.strip()]


def _parse_pair(s: Optional[str], flag: str) -> Optional[List[int]]:
    if s is None:
        return None
    parts = s.split(",")
    if len(parts) != 2:
        raise SystemExit(f"{flag} expects 'i,j', got {s!r}")
    return [int(p) for p in parts]


# --------------------------------------------------------------------------
# repro run
# --------------------------------------------------------------------------
def _run_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro run",
        description="Execute a declarative RunSpec (file or flag-built).",
    )
    ap.add_argument(
        "spec",
        nargs="?",
        default=None,
        help="RunSpec JSON file; omit to build one from flags",
    )
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated subset of the spec's sections "
        "(solve,eval,serve,bench)",
    )
    ap.add_argument(
        "--results-root",
        default="results",
        help="artifact root (default: results/)",
    )
    ap.add_argument("--run-id", default=None)
    ap.add_argument(
        "--resume",
        default=None,
        metavar="RUN_ID",
        help="restart a checkpointed run: reload "
        "<results-root>/<RUN_ID>/spec.json and continue from the latest "
        "durable step (the spec needs an ft section)",
    )
    ap.add_argument(
        "--no-write",
        action="store_true",
        help="skip writing results/<run_id>/ artifacts",
    )
    ap.add_argument(
        "--dry-run",
        action="store_true",
        help="print the resolved spec JSON and exit",
    )
    # ---- network
    ap.add_argument(
        "--network",
        default=None,
        metavar="KIND[:NAME]",
        help="drugnet | scenario:<name> | file:<path>",
    )
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument(
        "--param",
        action="append",
        default=None,
        metavar="KEY=JSON",
        help="network builder parameter (repeatable)",
    )
    ap.add_argument(
        "--no-cache",
        action="store_true",
        help="bypass the scenario disk cache",
    )
    # ---- solve
    ap.add_argument("--alg", choices=["dhlp1", "dhlp2"], default=None)
    ap.add_argument("--alpha", type=float, default=None)
    ap.add_argument("--sigma", type=float, default=None)
    ap.add_argument("--mode", choices=["batched", "sequential"], default=None)
    ap.add_argument("--seed-mode", choices=["fixed", "drift"], default=None)
    ap.add_argument(
        "--backend",
        "--engine",
        dest="backend",
        default=None,
        help="engine-registry backend key (or 'auto')",
    )
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--momentum", type=float, default=None)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--entity", type=int, default=None)
    ap.add_argument("--rank-pair", default=None, metavar="I,J")
    # ---- eval
    ap.add_argument("--eval", choices=["recovery", "cv"], default=None)
    ap.add_argument("--folds", type=int, default=None)
    ap.add_argument("--holdout-frac", type=float, default=None)
    ap.add_argument("--max-entities", type=int, default=None)
    ap.add_argument("--pair", default=None, metavar="I,J")
    # ---- serve
    ap.add_argument(
        "--serve",
        nargs="?",
        const="zipf",
        default=None,
        choices=("zipf",) + ARRIVAL_CHOICES,
        help="play a workload: zipf (synthetic) or a trace arrival process",
    )
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--zipf", type=float, default=None)
    ap.add_argument("--deltas", type=int, default=None)
    ap.add_argument("--rate-qps", type=float, default=None)
    ap.add_argument("--horizon", type=float, default=None)
    ap.add_argument("--time-scale", type=float, default=None)
    ap.add_argument("--refresh-rounds", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument(
        "--pipeline-depth",
        type=int,
        default=None,
        help="batches in flight (1 = synchronous tick, 2 = double-buffered)",
    )
    ap.add_argument(
        "--cache-shards",
        type=int,
        default=None,
        help="independently-locked column-cache shards",
    )
    ap.add_argument(
        "--early-exit",
        choices=("auto", "on", "off"),
        default=None,
        help="per-column convergence early exit in batch solves",
    )
    ap.add_argument(
        "--priority",
        choices=("interactive", "refresh", "bulk"),
        default=None,
        help="admission class stamped on replayed queries",
    )
    ap.add_argument(
        "--source-type",
        type=int,
        default=None,
        help="zipf workload: query entities of this type (default: eval pair)",
    )
    ap.add_argument(
        "--target-type",
        type=int,
        default=None,
        help="zipf workload: rank candidates of this type (default: eval pair)",
    )
    # ---- obs
    ap.add_argument(
        "--obs",
        nargs="?",
        const="metrics",
        default=None,
        choices=("off", "metrics", "trace", "profile"),
        help="telemetry level; bare --obs means 'metrics' (DESIGN.md §14)",
    )
    # ---- bench
    ap.add_argument(
        "--bench",
        nargs="?",
        const="all",
        default=None,
        metavar="SUITES",
        help="run registered bench suites (comma list or 'all')",
    )
    ap.add_argument(
        "--full",
        action="store_true",
        help="bench at paper scale (needs 8 devices)",
    )
    ap.add_argument("--label", default=None, help="bench report label")
    return ap


_SPEC_FILE_OK = {
    "spec", "only", "results_root", "run_id", "no_write", "dry_run", "resume",
}


def _build_spec_dict(args) -> Dict:
    """Assemble a RunSpec dict from builder flags."""
    from repro.api.spec import SpecError

    net: Dict = {}
    if args.network:
        kind, _, name = args.network.partition(":")
        net["kind"] = kind
        if kind == "scenario" and name:
            net["name"] = name
        elif kind == "file" and name:
            net["path"] = name
        elif name:
            raise SpecError(
                f"--network {args.network!r}: only scenario/file take a "
                "':<name>' suffix"
            )
    else:
        net["kind"] = "drugnet"
    if args.scale is not None:
        net["scale"] = args.scale
    if args.seed is not None:
        net["seed"] = args.seed
    if args.param:
        params = {}
        for kv in args.param:
            key, eq, val = kv.partition("=")
            if not eq:
                raise SpecError(f"--param {kv!r}: expected KEY=JSON")
            try:
                params[key] = json.loads(val)
            except json.JSONDecodeError:
                params[key] = val  # bare strings allowed
        net["params"] = params
    if args.no_cache:
        net["cache"] = False

    solve: Dict = {}
    for flag, key in (
        ("alg", "alg"),
        ("alpha", "alpha"),
        ("sigma", "sigma"),
        ("mode", "mode"),
        ("seed_mode", "seed_mode"),
        ("backend", "backend"),
        ("devices", "devices"),
        ("momentum", "momentum"),
        ("top_k", "top_k"),
        ("entity", "entity"),
    ):
        v = getattr(args, flag)
        if v is not None:
            solve[key] = v
    if args.rank_pair is not None:
        solve["rank_pair"] = _parse_pair(args.rank_pair, "--rank-pair")

    ev: Dict = {}
    if args.eval:
        ev["protocol"] = args.eval
    if args.folds is not None:
        ev["folds"] = args.folds
    if args.holdout_frac is not None:
        ev["holdout_frac"] = args.holdout_frac
    if args.max_entities is not None:
        ev["max_entities"] = args.max_entities
    if args.pair is not None:
        ev["pair"] = _parse_pair(args.pair, "--pair")

    srv: Dict = {}
    if args.serve and args.serve != "zipf":
        srv["trace"] = args.serve  # zipf == the trace-less default
    for flag, key in (
        ("requests", "requests"),
        ("zipf", "zipf"),
        ("deltas", "deltas"),
        ("rate_qps", "rate_qps"),
        ("horizon", "horizon_s"),
        ("time_scale", "time_scale"),
        ("refresh_rounds", "refresh_rounds"),
        ("max_batch", "max_batch"),
        ("pipeline_depth", "pipeline_depth"),
        ("cache_shards", "cache_shards"),
        ("priority", "priority"),
        ("source_type", "source_type"),
        ("target_type", "target_type"),
    ):
        v = getattr(args, flag)
        if v is not None:
            srv[key] = v
    if args.early_exit is not None:
        # the tri-state maps onto ServeSpec.early_exit's None/bool
        srv["early_exit"] = {"auto": None, "on": True, "off": False}[
            args.early_exit
        ]

    bench: Dict = {}
    if args.bench:
        if args.bench != "all":
            bench["suites"] = _split_csv(args.bench)
        bench["fast"] = not args.full
        if args.label:
            bench["label"] = args.label

    # sub-flags never create a stage on their own: `--folds 4` without
    # `--eval cv` (or `--requests` without `--serve`) would otherwise
    # silently run a stage — or a protocol — the user never asked for
    if ev and not args.eval:
        raise SpecError(
            f"eval flags {sorted(ev)} require --eval <recovery|cv>"
        )
    if srv and not args.serve:
        raise SpecError(
            f"serve flags {sorted(srv)} require --serve [zipf|<process>]"
        )

    out: Dict = {"network": net}
    if solve:
        out["solve"] = solve
    if args.eval:
        out["eval"] = ev
    if args.serve:
        out["serve"] = srv
    if bench:
        out["bench"] = bench
    if args.obs:
        out["obs"] = {"level": args.obs}
    if args.run_id:
        out["run_id"] = args.run_id
    return out


def _describe(art) -> List[str]:
    """Human summary lines for one artifact."""
    k = art.kind
    if k == "solve":
        r = art.ranking
        out = [
            f"[solve] {art.alg} on {art.backend}: converged={art.converged} "
            f"outer={art.outer_iters} inner={art.inner_iters} "
            f"supersteps={art.supersteps} in {art.seconds:.2f}s",
            f"[solve] top-{r['top_k']} of type {r['pair'][1]} for entity "
            f"{r['entity']}: {r['candidates']}",
        ]
        if getattr(art, "ft", None):
            ft = art.ft
            line = f"[solve] ft: checkpoints={ft.get('checkpoints', 0)}"
            if ft.get("resumed_from") is not None:
                line += f" resumed_from={ft['resumed_from']}"
            out.append(line)
        return out
    if k == "eval":
        metrics = " ".join(
            f"{key}={val:.4f}" for key, val in sorted(art.metrics.items())
        )
        return [
            f"[eval] {art.protocol} on {art.backend} pair={list(art.pair)}: "
            f"{metrics} ({art.seconds:.2f}s)"
        ]
    if k == "serve":
        r = art.report
        line = (
            f"[serve] {art.mode} on {art.engine}: {r['queries']} queries "
            f"→ {r['qps']:.1f} QPS  p50={r['p50'] * 1e3:.2f}ms "
            f"p95={r['p95'] * 1e3:.2f}ms p99={r['p99'] * 1e3:.2f}ms"
        )
        if "offered_qps" in r:
            line += f"  offered={r['offered_qps']:.1f}"
        if "achieved_vs_offered" in r:
            line += f"  achieved/offered={r['achieved_vs_offered']:.2f}"
        src = ", ".join(f"{s}:{n}" for s, n in sorted(r["sources"].items()))
        out = [line, f"[serve] sources: {src}"]
        if getattr(art, "ft", None):
            ft = art.ft
            out.append(
                f"[serve] ft: checkpoints={ft.get('checkpoints', 0)} "
                f"retries={ft.get('retries', 0)} "
                f"restores={ft.get('restores', 0)}"
            )
        return out
    if k == "bench":
        return [
            f"[bench] label={art.label} suites={len(art.suites)} "
            f"records={art.records} failures={art.failures}"
        ]
    if k == "train":
        return [
            f"[train] {art.arch} ({art.family}): {art.steps} steps "
            f"loss {art.first_loss:.4f}→{art.last_loss:.4f} "
            f"retries={art.retries} restores={art.restores} "
            f"slow={art.slow_steps}{' resumed' if art.resumed else ''} "
            f"({art.seconds:.1f}s)"
        ]
    if k == "dryrun":
        s = art.summary()
        statuses = " ".join(f"{k}:{v}" for k, v in sorted(s["statuses"].items()))
        return [
            f"[dryrun] {s['cells']} cells on mesh={art.mesh}: {statuses} "
            f"({art.seconds:.1f}s)"
        ]
    return [f"[{k}] done in {art.seconds:.2f}s"]


def run_main(argv: Optional[List[str]] = None) -> int:
    ap = _run_parser()
    args = ap.parse_args(argv)

    from repro.api import RunSpec, Session, SpecError

    try:
        if args.spec is not None or args.resume is not None:
            # a spec file (or a stored one, via --resume) is authoritative:
            # builder flags would silently fork it, so they are rejected
            builder_set = [
                f"--{k.replace('_', '-')}"
                for k, v in vars(args).items()
                # identity checks: 0 and 0.0 are real flag values, not
                # absent ones (0 == False would slip through `not in`)
                if k not in _SPEC_FILE_OK
                and v is not None
                and v is not False
            ]
            if builder_set:
                ap.error(
                    f"spec file given; builder flags {builder_set} conflict "
                    "(edit the spec instead)"
                )
        if args.resume is not None:
            if args.spec is not None:
                ap.error("--resume reloads the stored spec; drop the spec file")
            if args.run_id:
                ap.error("--resume fixes the run id; drop --run-id")
            stored = os.path.join(args.results_root, args.resume, "spec.json")
            if not os.path.isfile(stored):
                raise SpecError(
                    f"--resume {args.resume}: no stored spec at {stored}"
                )
            spec = RunSpec.from_file(stored)
            if spec.ft is None:
                raise SpecError(
                    f"--resume {args.resume}: the stored spec has no ft "
                    "section — nothing was checkpointed"
                )
            # the run id pins both the artifact dir and the default
            # checkpoint root the resumed solve restores from
            spec = RunSpec.from_dict({**spec.to_dict(), "run_id": args.resume})
        elif args.spec is not None:
            spec = RunSpec.from_file(args.spec)
            if args.run_id:
                spec = RunSpec.from_dict({**spec.to_dict(), "run_id": args.run_id})
        else:
            spec = RunSpec.from_dict(_build_spec_dict(args))
    except (SpecError, OSError) as e:
        print(f"repro run: {e}", file=sys.stderr)
        return 2

    if args.dry_run:
        print(spec.to_json())
        return 0

    session = Session(spec, results_root=args.results_root)
    try:
        artifacts = session.run(
            sections=_split_csv(args.only), write=not args.no_write
        )
    except SpecError as e:
        print(f"repro run: {e}", file=sys.stderr)
        return 2
    failures = 0
    for art in artifacts:
        for line in _describe(art):
            print(line)
        failures += getattr(art, "failures", 0)
    return 1 if failures else 0


# --------------------------------------------------------------------------
# repro solve (deprecation shim for repro.launch.solve)
# --------------------------------------------------------------------------
def solve_main(argv: Optional[List[str]] = None) -> int:
    _warn_deprecated(
        "the standalone solve CLI",
        "e.g. `python -m repro run --alg dhlp2 --backend dense`",
    )
    ap = argparse.ArgumentParser(prog="repro solve")
    ap.add_argument("--alg", choices=["dhlp1", "dhlp2"], default="dhlp2")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--sigma", type=float, default=1e-3)
    ap.add_argument("--mode", choices=["batched", "sequential"], default="batched")
    ap.add_argument(
        "--backend",
        "--engine",
        dest="backend",
        default="dense",
        help="engine-registry backend "
        "(dense/sparse/kernel/sharded/auto)",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=None,
        help="edge-shard count for --backend sharded",
    )
    ap.add_argument("--drugs", type=int, default=223)
    ap.add_argument("--diseases", type=int, default=150)
    ap.add_argument("--targets", type=int, default=95)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top-k", type=int, default=20)
    ap.add_argument(
        "--entity",
        type=int,
        default=0,
        help="drug id whose target ranking is printed",
    )
    ap.add_argument("--out", default=None, help="write outputs npz here")
    args = ap.parse_args(argv)

    import numpy as np

    from repro.api import NetworkSpec, RunSpec, Session, SolveSpec, SpecError

    try:
        spec = RunSpec(
            network=NetworkSpec(
                kind="drugnet",
                seed=args.seed,
                params={
                    "n_drug": args.drugs,
                    "n_disease": args.diseases,
                    "n_target": args.targets,
                },
            ),
            solve=SolveSpec(
                alg=args.alg,
                alpha=args.alpha,
                sigma=args.sigma,
                mode=args.mode,
                backend=args.backend,
                devices=(args.devices if args.backend == "sharded" else None),
                top_k=args.top_k,
                entity=args.entity,
                rank_pair=(0, 2),
            ),
        )
        session = Session(spec)
        net = session.network
        print(f"[solve] network: {net.sizes} nodes/type, {net.num_edges} edges")
        print(f"[solve] backend: {session.backend}")
    except (SpecError, ValueError) as e:
        # bad spec / unknown backend == usage error; anything raised by
        # the solve itself below is a real failure and keeps its traceback
        ap.error(str(e))
    art = session.solve()
    print(
        f"[solve] {art.alg} converged={art.converged} "
        f"outer={art.outer_iters} inner={art.inner_iters} "
        f"supersteps={art.supersteps} in {art.seconds:.2f}s"
    )
    names = {
        (0, 1): "drug-disease",
        (0, 2): "drug-target",
        (1, 2): "disease-target",
    }
    out = art.outputs
    for pair, name in names.items():
        m = out.interactions[pair]
        print(f"[solve] {name}: {m.shape}, mean score {m.mean():.4g}")
    top = art.ranking["candidates"]
    print(f"[solve] top-{args.top_k} targets for drug {args.entity}: {top}")
    if args.out:
        np.savez_compressed(
            args.out,
            drug_disease=out.interactions[(0, 1)],
            drug_target=out.interactions[(0, 2)],
            disease_target=out.interactions[(1, 2)],
            sim_drug=out.similarities[0],
            sim_disease=out.similarities[1],
            sim_target=out.similarities[2],
        )
        print(f"[solve] outputs written to {args.out}")
    return 0


# --------------------------------------------------------------------------
# repro serve (deprecation shim for repro.launch.serve)
# --------------------------------------------------------------------------
def serve_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro serve")
    ap.add_argument("--alg", choices=["dhlp1", "dhlp2"], default="dhlp2")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--sigma", type=float, default=1e-3)
    ap.add_argument(
        "--engine",
        choices=["dense", "sparse", "kernel", "sharded", "auto"],
        default="dense",
        help="engine-registry backend (sharded uses the host's devices)",
    )
    ap.add_argument(
        "--refresh-rounds",
        type=int,
        default=0,
        help="fused LP rounds to advance stale hints after each delta",
    )
    ap.add_argument("--drugs", type=int, default=223)
    ap.add_argument("--diseases", type=int, default=150)
    ap.add_argument("--targets", type=int, default=95)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top-k", type=int, default=20)
    ap.add_argument(
        "--requests",
        type=int,
        default=200,
        help="number of queries to play",
    )
    ap.add_argument(
        "--zipf",
        type=float,
        default=1.3,
        help="popularity skew; higher = more repeat queries",
    )
    ap.add_argument(
        "--deltas",
        type=int,
        default=0,
        help="graph edits interleaved through the workload",
    )
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--queue-depth", type=int, default=1024)
    ap.add_argument("--cache-columns", type=int, default=4096)
    ap.add_argument("--no-warm-start", action="store_true")
    return ap


def serve_main(argv: Optional[List[str]] = None) -> int:
    _warn_deprecated(
        "the standalone serve CLI",
        "e.g. `python -m repro run --serve --requests 200`",
    )
    ap = serve_parser()
    args = ap.parse_args(argv)
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.zipf <= 1.0:
        ap.error("--zipf must be > 1 (numpy zipf exponent)")

    from repro.api import (
        NetworkSpec,
        RunSpec,
        ServeSpec,
        Session,
        SolveSpec,
        SpecError,
    )

    try:
        spec = RunSpec(
            network=NetworkSpec(
                kind="drugnet",
                seed=args.seed,
                params={
                    "n_drug": args.drugs,
                    "n_disease": args.diseases,
                    "n_target": args.targets,
                },
            ),
            solve=SolveSpec(
                alg=args.alg,
                alpha=args.alpha,
                sigma=args.sigma,
                seed_mode="fixed",
                backend=args.engine,
            ),
            serve=ServeSpec(
                requests=args.requests,
                zipf=args.zipf,
                deltas=args.deltas,
                top_k=args.top_k,
                cache_columns=args.cache_columns,
                warm_start=not args.no_warm_start,
                refresh_rounds=args.refresh_rounds,
                max_batch=args.max_batch,
                max_wait_ms=args.max_wait_ms,
                queue_depth=args.queue_depth,
            ),
        )
        session = Session(spec)
        net = session.network
        print(f"[serve] network: {net.sizes} nodes/type, {net.num_edges} edges")
        _ = session.backend  # resolve now: unknown engine == usage error
    except (SpecError, ValueError) as e:
        ap.error(str(e))
    art = session.serve()
    r = art.report
    for ev in r["deltas"]:
        print(
            f"[serve] delta @req {ev['at']}: +assoc drug {ev['u']} → "
            f"target {ev['v']} (version {ev['version']})"
        )
    print(
        f"[serve] {r['queries']} queries in {r['wall_s']:.2f}s "
        f"→ {r['qps']:.1f} QPS"
    )
    print(
        f"[serve] latency p50={r['p50'] * 1e3:.2f}ms "
        f"p95={r['p95'] * 1e3:.2f}ms p99={r['p99'] * 1e3:.2f}ms"
    )
    for src in ("cache", "warm", "cold"):
        if r["sources"].get(src):
            mr = r["mean_rounds_by_source"][src]
            print(
                f"[serve]   {src:5s}: {r['sources'][src]:5d} queries, "
                f"mean {mr:.1f} LP rounds"
            )
    print(
        f"[serve] batches={r['batches']} "
        f"mean_batch={r['mean_batch_size']:.1f} rejected={r['rejected']}"
    )
    print(
        f"[serve] cache: hit_rate={r['cache_hit_rate']:.2%} "
        f"evictions={r['cache_evictions']} demoted={r['cache_demoted']}"
    )
    s = art.sample
    print(
        f"[serve] sample: drug {s['entity']} top-{len(s['candidates'])} "
        f"targets {s['candidates']}"
    )
    return 0


# --------------------------------------------------------------------------
# repro scenario (deprecation shim for repro.launch.scenario)
# --------------------------------------------------------------------------
def scenario_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro scenario")
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--list", action="store_true", help="list registered scenarios")
    mode.add_argument(
        "--info",
        metavar="NAME",
        help="generate NAME and print its statistics",
    )
    mode.add_argument(
        "--solve",
        metavar="NAME",
        help="solve NAME on one or more backends and score planted-edge "
        "recovery",
    )
    mode.add_argument(
        "--cv",
        metavar="NAME",
        help="k-fold CV against NAME's planted truth",
    )
    mode.add_argument(
        "--trace",
        metavar="NAME",
        help="generate a query trace for NAME and print its arrival "
        "statistics",
    )
    ap.add_argument(
        "--scale",
        type=float,
        default=1.0,
        help="size multiplier passed to the builder",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--backends",
        default="auto",
        help="comma-separated engine-registry keys",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=None,
        help="edge-shard count for the sharded backend",
    )
    ap.add_argument("--sigma", type=float, default=1e-4)
    ap.add_argument("--holdout-frac", type=float, default=0.1)
    ap.add_argument("--max-entities", type=int, default=32)
    ap.add_argument("--folds", type=int, default=5)
    ap.add_argument("--process", default="poisson", help="arrival process for --trace")
    ap.add_argument("--rate-qps", type=float, default=50.0)
    ap.add_argument("--horizon-s", type=float, default=4.0)
    ap.add_argument(
        "--no-cache",
        action="store_true",
        help="bypass the scenario disk cache",
    )
    ap.add_argument("--json", default=None, help="write the report here")
    return ap


def _scenario_spec(args, name: str, backend: str, section: Dict):
    """One RunSpec for a (scenario, backend) cell of the shim sweep."""
    from repro.api import EvalSpec, NetworkSpec, RunSpec, SolveSpec

    return RunSpec(
        network=NetworkSpec(
            kind="scenario",
            name=name,
            scale=args.scale,
            seed=args.seed,
            cache=False if args.no_cache else None,
        ),
        solve=SolveSpec(
            sigma=args.sigma,
            seed_mode="fixed",
            backend=backend,
            devices=(args.devices if backend == "sharded" and args.devices else None),
        ),
        eval=EvalSpec(seed=args.seed, **section),
    )


def scenario_main(argv: Optional[List[str]] = None) -> int:
    _warn_deprecated(
        "the standalone scenario CLI",
        "e.g. `python -m repro run --network scenario:<name> --eval recovery`",
    )
    ap = scenario_parser()
    args = ap.parse_args(argv)

    import time

    import numpy as np

    import repro.scenarios as sc
    from repro.api import Session
    from repro.bench.matrix import AGREEMENT_TOL

    cache = False if args.no_cache else None

    if args.list:
        rows = sc.list_rows()
        width = max(len(r["name"]) for r in rows)
        for r in rows:
            tags = f" [{','.join(r['tags'])}]" if r["tags"] else ""
            print(f"{r['name']:<{width}}  {r['description']}{tags}")
        print(f"\n{len(rows)} scenarios registered")
        report = {"scenarios": rows}
    elif args.info:
        t0 = time.time()
        bundle = sc.generate(args.info, scale=args.scale, seed=args.seed, cache=cache)
        report = bundle.describe()
        report.pop("arriving_truth", None)
        report["generate_s"] = round(time.time() - t0, 3)
        for k, v in report.items():
            print(f"{k:>20}: {v}")
    elif args.solve:
        bundle = sc.generate(args.solve, scale=args.scale, seed=args.seed, cache=cache)
        net = bundle.network
        print(
            f"[scenario] {bundle.name}: T={net.num_types} types, "
            f"{net.num_nodes} nodes, {net.num_edges} edges"
        )
        section = {
            "protocol": "recovery",
            "holdout_frac": args.holdout_frac,
            "max_entities": args.max_entities,
        }
        report = {
            "scenario": bundle.name,
            "scale": args.scale,
            "nodes": net.num_nodes,
            "edges": net.num_edges,
            "eval_pair": list(bundle.eval_pair),
            "cells": [],
        }
        F_ref, ref_name = None, None
        for key in _split_csv(args.backends):
            spec = _scenario_spec(args, args.solve, key, section)
            session = Session(spec, bundle=bundle)
            art = session.evaluate()
            cell = dict(art.metrics)
            cell.update(
                {
                    "backend": art.backend,
                    "requested": key,
                    "outer_iters": art.metrics["outer_iters"],
                    "seconds": round(art.seconds, 3),
                }
            )
            if F_ref is None:
                F_ref, ref_name = art.F, art.backend
            else:
                diff = float(np.max(np.abs(art.F - F_ref)))
                cell["max_abs_diff_vs_ref"] = diff
                cell["agree_ref"] = bool(diff <= AGREEMENT_TOL)
            report["cells"].append(cell)
            agree = (
                ""
                if "agree_ref" not in cell
                else f"  agree_vs_{ref_name}={cell['agree_ref']}"
            )
            print(
                f"[scenario] {art.backend:>10}: "
                f"auc={cell['recovery_auc']:.4f} "
                f"aupr={cell['recovery_aupr']:.4f} "
                f"iters={int(cell['outer_iters'])} "
                f"{art.seconds:.2f}s{agree}"
            )
    elif args.cv:
        bundle = sc.generate(args.cv, scale=args.scale, seed=args.seed, cache=cache)
        backend = _split_csv(args.backends)[0]
        spec = _scenario_spec(
            args, args.cv, backend, {"protocol": "cv", "folds": args.folds}
        )
        session = Session(spec, bundle=bundle)
        art = session.evaluate()
        summary = dict(art.metrics)
        summary["seconds"] = round(art.seconds, 3)
        print(
            f"[scenario] {bundle.name} {args.folds}-fold CV on planted "
            f"truth ({art.backend}): auc={summary['auc']:.4f} "
            f"aupr={summary['aupr']:.4f} "
            f"best_acc={summary['best_acc']:.4f}"
        )
        report = {
            "scenario": bundle.name,
            "backend": art.backend,
            "folds": args.folds,
            **summary,
        }
    else:
        bundle = sc.generate(args.trace, scale=args.scale, seed=args.seed, cache=cache)
        trace = sc.build_trace(
            bundle,
            args.process,
            rate_qps=args.rate_qps,
            horizon_s=args.horizon_s,
            seed=args.seed,
        )
        gaps = np.diff(trace.t) if len(trace) > 1 else np.zeros(1)
        report = {
            "scenario": bundle.name,
            "process": trace.process,
            "queries": len(trace),
            "offered_qps": round(len(trace) / trace.horizon_s, 2),
            "unique_entities": len(np.unique(trace.entity)),
            "gap_p50_ms": round(float(np.percentile(gaps, 50)) * 1e3, 3),
            "gap_p99_ms": round(float(np.percentile(gaps, 99)) * 1e3, 3),
            "deltas": len(bundle.deltas),
        }
        for k, v in report.items():
            print(f"{k:>16}: {v}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"report written to {args.json}")
    return 0


# --------------------------------------------------------------------------
# repro bench (deprecation shim for benchmarks/run.py)
# --------------------------------------------------------------------------
def bench_main(argv: Optional[List[str]] = None) -> int:
    _warn_deprecated(
        "the standalone bench CLI",
        "e.g. `python -m repro run --bench` or a spec with a bench section",
    )
    ap = argparse.ArgumentParser(prog="repro bench")
    ap.add_argument(
        "--full",
        action="store_true",
        help="paper-scale parameters (slow on CPU)",
    )
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument(
        "--label",
        default=None,
        help="report label (default: ci, or full with --full)",
    )
    ap.add_argument(
        "--no-write",
        action="store_true",
        help="skip writing BENCH_<label>.json / results/",
    )
    ap.add_argument(
        "--list",
        action="store_true",
        help="list registered suites and exit",
    )
    args, _ = ap.parse_known_args(argv)

    from repro.bench.driver import (
        BenchSetupError,
        import_suite_modules,
        run_bench,
    )

    if args.list:
        from repro.bench import all_suites

        import_suite_modules()
        for s in all_suites():
            print(f"{s.name}: {s.description}")
        return 0
    try:
        outcome = run_bench(
            fast=not args.full,
            only=_split_csv(args.only),
            label=args.label,
            write=not args.no_write,
            echo=lambda line: print(line, flush=True),
        )
    except BenchSetupError as e:
        print(f"bench: {e}", file=sys.stderr)
        return 2
    print(
        f"suites={len(outcome.suites)} records={outcome.records} "
        f"failures={outcome.failures}",
        file=sys.stderr,
    )
    return 1 if outcome.failures else 0


# --------------------------------------------------------------------------
# repro obs
# --------------------------------------------------------------------------
def _has_telemetry(path: str) -> bool:
    """A telemetry dir is recognizable mid-stream: the consolidated
    events.jsonl only lands at the final flush, so live segments or the
    metrics snapshot also count."""
    import glob
    import os

    if not os.path.isdir(path):
        return False
    return (
        os.path.isfile(os.path.join(path, "events.jsonl"))
        or os.path.isfile(os.path.join(path, "metrics.jsonl"))
        or bool(glob.glob(os.path.join(path, "events-*.jsonl")))
    )


def _latest_run_id(results_root: str) -> Optional[str]:
    """Most recently modified results/<run_id>/ with a telemetry dir."""
    import os

    if not os.path.isdir(results_root):
        return None
    best, best_mtime = None, -1.0
    for name in os.listdir(results_root):
        tel = os.path.join(results_root, name, "telemetry")
        if _has_telemetry(tel):
            mtime = os.path.getmtime(tel)
            if mtime > best_mtime:
                best, best_mtime = name, mtime
    return best


def obs_main(argv: Optional[List[str]] = None) -> int:
    """Render (and optionally validate) a run's telemetry artifacts."""
    import os
    import time as _time

    ap = argparse.ArgumentParser(
        prog="repro obs",
        description="summarize results/<run_id>/telemetry/ (DESIGN.md §14.5)",
    )
    ap.add_argument(
        "run_id",
        nargs="?",
        default=None,
        help="run id under --results-root, or a path to a run/telemetry "
        "dir; omitted = the most recent run with telemetry",
    )
    ap.add_argument("--results-root", default="results")
    ap.add_argument(
        "--validate",
        action="store_true",
        help="schema-check every telemetry line before rendering",
    )
    ap.add_argument(
        "--json",
        action="store_true",
        help="print the summary digest as JSON instead of text",
    )
    ap.add_argument(
        "--follow",
        action="store_true",
        help="tail a live run: re-render the digest as flush ticks land",
    )
    ap.add_argument(
        "--interval",
        type=float,
        default=1.0,
        help="--follow poll interval in seconds (default 1.0)",
    )
    ap.add_argument(
        "--max-ticks",
        type=int,
        default=0,
        help="--follow: stop after N re-renders (0 = until interrupted)",
    )
    args = ap.parse_args(argv)

    if args.run_id is None:
        args.run_id = _latest_run_id(args.results_root)
        if args.run_id is None:
            print(
                f"repro obs: no run with telemetry under "
                f"{args.results_root!r}; pass a run id or path",
                file=sys.stderr,
            )
            return 2
        print(f"[obs] defaulting to most recent run: {args.run_id}")

    candidates = [
        os.path.join(args.results_root, args.run_id, "telemetry"),
        os.path.join(args.run_id, "telemetry"),
        args.run_id,
    ]
    tel_dir = next((c for c in candidates if _has_telemetry(c)), None)
    if tel_dir is None:
        print(
            f"repro obs: no telemetry found for {args.run_id!r} "
            f"(looked in {candidates}); was the run executed with "
            "obs.level != 'off'?",
            file=sys.stderr,
        )
        return 2

    from repro.obs.schema import TelemetryError, validate_dir
    from repro.obs.summary import load_dir, render, summarize

    if args.validate:
        try:
            counts = validate_dir(tel_dir)
        except TelemetryError as e:
            print(f"repro obs: INVALID telemetry: {e}", file=sys.stderr)
            return 1
        kinds = " ".join(f"{k}:{v}" for k, v in sorted(counts.items()))
        print(f"[obs] schema ok: {kinds}")

    def emit() -> None:
        summary = summarize(*load_dir(tel_dir))
        if args.json:
            print(json.dumps(summary, indent=2, sort_keys=True))
        else:
            print(render(summary))

    if not args.follow:
        emit()
        return 0

    # live tail: re-render whenever the streamed snapshot advances
    ticks = 0
    last_sig: Any = None
    try:
        while True:
            names = sorted(
                n for n in os.listdir(tel_dir) if n.endswith((".jsonl", ".prom"))
            )
            sig = tuple(
                (n, os.path.getmtime(os.path.join(tel_dir, n))) for n in names
            )
            if sig != last_sig:
                last_sig = sig
                if ticks:
                    print(f"--- tick {ticks} ---")
                emit()
                ticks += 1
                if args.max_ticks and ticks >= args.max_ticks:
                    break
            _time.sleep(args.interval)
    except KeyboardInterrupt:
        pass
    return 0


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------
_SUBCOMMANDS = {
    "run": run_main,
    "obs": obs_main,
    "solve": solve_main,
    "serve": serve_main,
    "scenario": scenario_main,
    "bench": bench_main,
}


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if not argv or argv[0] in ("-h", "--help"):
        names = " | ".join(_SUBCOMMANDS)
        print(f"usage: python -m repro {{{names}}} ...\n")
        print(
            "`run` executes a declarative RunSpec (DESIGN.md §13); `obs` "
            "renders a\nrun's telemetry (§14); the other subcommands are "
            "deprecation shims for\nthe retired standalone CLIs."
        )
        return 0
    cmd, rest = argv[0], argv[1:]
    if cmd not in _SUBCOMMANDS:
        print(
            f"python -m repro: unknown subcommand {cmd!r} "
            f"(choose from {', '.join(_SUBCOMMANDS)})",
            file=sys.stderr,
        )
        return 2
    return _SUBCOMMANDS[cmd](rest)
