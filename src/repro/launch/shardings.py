"""Named sharding rules per architecture family.

Maps every input of a Cell (params / optimizer state / batch) to a
``NamedSharding`` on the production mesh:

  LM     — TP over ``model`` (heads / ff / experts), DP over pod×data;
           KV caches shard sequence over ``model`` (sequence-parallel
           serving) and batch over pod×data.
  GNN    — node/edge arrays over pod×data, params replicated.
  recsys — embedding tables vocab-sharded over ``model``; batch over
           pod×data; first MLP layer column-sharded.
  lp     — edges over ``model``, seed columns over pod×data (the
           shard_map engine's layout, expressed for pjit).

A dim is sharded only if the axis size divides it — otherwise the spec
drops that axis (GSPMD could pad, but clean splits keep the roofline
numbers honest).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.cells import Cell
from repro.launch.mesh import data_axes

PyTree = Any


def _axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _maybe(mesh, dim_size: int, axes):
    """axes if they divide dim_size, else None (pjit requires INPUT dims to
    divide the mesh axes exactly; every cell pads its sizes to make the
    intended dims divisible — vocab to 128s, graph arrays to 512s)."""
    return axes if dim_size % _axis_size(mesh, axes) == 0 else None


def _ns(mesh, spec) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _replicated(mesh, tree) -> PyTree:
    return jax.tree_util.tree_map(lambda _: _ns(mesh, P()), tree)


# ====================================================================== LM
def _lm_param_specs(mesh, p_specs) -> PyTree:
    """Tensor-parallel placement keyed by parameter name."""
    mdl = "model"

    # rule name → (sharded dim index, dim count); evaluated lazily so a
    # 1-D norm leaf never indexes shape[2].
    _col = {"wq", "wk", "wv", "q_a", "q_b", "kv_b",
            "shared_gate", "shared_up"}          # (L, in, out): out over TP
    _row = {"wo", "o", "shared_down"}            # (L, in, out): in over TP

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        if name == "embed" and nd == 2:
            return P(_maybe(mesh, leaf.shape[0], mdl), None)
        if name == "lm_head" and nd == 2:
            return P(None, _maybe(mesh, leaf.shape[1], mdl))
        if name in ("w_gate", "w_up", "w_down"):
            if nd == 4:      # MoE (L, E, a, b)
                if leaf.shape[1] % mesh.shape[mdl] == 0:
                    # expert-parallel: experts over model
                    return P(None, mdl, None, None)
                # E not divisible (e.g. granite 40e over 16): TP inside
                # each expert on the ff dim instead
                ff_dim = 3 if name in ("w_gate", "w_up") else 2
                spec = [None, None, None, None]
                spec[ff_dim] = _maybe(mesh, leaf.shape[ff_dim], mdl)
                return P(*spec)
            if name == "w_down":   # dense (L, ff, d)
                return P(None, _maybe(mesh, leaf.shape[1], mdl), None)
            return P(None, None, _maybe(mesh, leaf.shape[2], mdl))
        if name in _col and nd == 3:
            return P(None, None, _maybe(mesh, leaf.shape[2], mdl))
        if name in _row and nd == 3:
            return P(None, _maybe(mesh, leaf.shape[1], mdl), None)
        return P()           # norms, routers, kv_a: replicated

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _ns(mesh, spec_for(path, leaf)), p_specs
    )


def _opt_state_specs(mesh, o_specs, p_sharding) -> PyTree:
    """Optimizer moments mirror the parameter shardings; step replicated."""
    # OptState(step, mu, nu) — mu/nu share the param tree structure.
    step_s = _ns(mesh, P())
    mu = p_sharding
    nu = p_sharding if o_specs.nu is not None else None
    return type(o_specs)(step=step_s, mu=mu, nu=nu)


def _cache_spec(mesh, cache, dp) -> NamedSharding:
    """KV cache: batch over dp, sequence over model (sequence-parallel)."""
    shape = cache.shape
    if len(shape) == 6:      # GQA (L, 2, B, S, hkv, hd)
        return _ns(mesh, P(None, None, _maybe(mesh, shape[2], dp),
                           _maybe(mesh, shape[3], "model"), None, None))
    # MLA (L, B, S, r)
    return _ns(mesh, P(None, _maybe(mesh, shape[1], dp),
                       _maybe(mesh, shape[2], "model"), None))


def lm_shardings(mesh, cell: Cell) -> Tuple:
    dp = data_axes(mesh)
    p_specs = cell.input_specs[0]
    p_sh = _lm_param_specs(mesh, p_specs)
    if cell.kind == "train":
        o_specs, batch = cell.input_specs[1], cell.input_specs[2]
        o_sh = _opt_state_specs(mesh, o_specs, p_sh)
        b_sh = {
            k: _ns(mesh, P(_maybe(mesh, v.shape[0], dp), None))
            for k, v in batch.items()
        }
        return (p_sh, o_sh, b_sh)
    if cell.kind == "prefill":
        tokens, cache = cell.input_specs[1], cell.input_specs[2]
        return (
            p_sh,
            _ns(mesh, P(_maybe(mesh, tokens.shape[0], dp), None)),
            _cache_spec(mesh, cache, dp),
        )
    if cell.kind == "decode":
        cache, token = cell.input_specs[1], cell.input_specs[2]
        return (
            p_sh,
            _cache_spec(mesh, cache, dp),
            _ns(mesh, P(_maybe(mesh, token.shape[0], dp), None)),
            _ns(mesh, P()),
        )
    raise ValueError(cell.kind)


# ===================================================================== GNN
def gnn_shardings(mesh, cell: Cell) -> Tuple:
    dp = data_axes(mesh)
    p_specs, o_specs, batch = cell.input_specs
    p_sh = _replicated(mesh, p_specs)
    o_sh = type(o_specs)(
        step=_ns(mesh, P()),
        mu=_replicated(mesh, o_specs.mu),
        nu=None if o_specs.nu is None else _replicated(mesh, o_specs.nu),
    )

    def batch_spec(v):
        lead = _maybe(mesh, v.shape[0], dp)
        return _ns(mesh, P(lead, *([None] * (len(v.shape) - 1))))

    b_sh = {k: batch_spec(v) for k, v in batch.items()}
    return (p_sh, o_sh, b_sh)


# ================================================================== recsys
def recsys_shardings(mesh, cell: Cell) -> Tuple:
    dp = data_axes(mesh)
    mdl = "model"
    p_specs = cell.input_specs[0]

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("table", "wide_table"):
            return _ns(mesh, P(_maybe(mesh, leaf.shape[0], mdl),
                               *([None] * (len(leaf.shape) - 1))))
        if name == "mlp_w" and len(leaf.shape) == 2 and leaf.shape[1] > 64:
            return _ns(mesh, P(None, _maybe(mesh, leaf.shape[1], mdl)))
        return _ns(mesh, P())

    p_sh = jax.tree_util.tree_map_with_path(spec_for, p_specs)

    def batch_spec(v):
        return _ns(mesh, P(_maybe(mesh, v.shape[0], dp),
                           *([None] * (len(v.shape) - 1))))

    if cell.kind == "train":
        o_specs, batch = cell.input_specs[1], cell.input_specs[2]
        o_sh = type(o_specs)(
            step=_ns(mesh, P()),
            mu=p_sh, nu=None if o_specs.nu is None else p_sh,
        )
        return (p_sh, o_sh, {k: batch_spec(v) for k, v in batch.items()})
    rest = tuple(batch_spec(v) for v in cell.input_specs[1:])
    return (p_sh,) + rest


# ====================================================================== LP
def lp_shardings(mesh, cell: Cell) -> Tuple:
    dp = data_axes(mesh)
    src, dst, w, Y, F = cell.input_specs
    edge = _ns(mesh, P(_maybe(mesh, src.shape[0], "model")))
    seeds = _ns(mesh, P(None, _maybe(mesh, Y.shape[1], dp)))
    return (edge, edge, edge, seeds, seeds)


FAMILY_SHARDINGS = {
    "lm": lm_shardings,
    "gnn": gnn_shardings,
    "recsys": recsys_shardings,
    "lp": lp_shardings,
}


def shardings_for(mesh, family: str, cell: Cell) -> Tuple:
    return FAMILY_SHARDINGS[family](mesh, cell)
