"""SLO watchdog: per-flush-window objective evaluation (DESIGN.md §14.9).

Declarative objectives (an ``ObsSpec.slo`` block) are evaluated against
*window deltas* of the metrics registry on every streaming flush tick:
the watchdog snapshots counters / histogram buckets / the residual gauge
per tick and scores each objective on the difference, so a long-running
serve replay is judged on its recent behavior, not its lifetime
averages.  The alerting policy is multi-window burn rate:

* a window **burns** when any configured objective is violated in it;
* ``burn_windows`` *consecutive* burning windows raise a **breach** —
  an ``obs.slo.breach`` event, the ``obs.slo.breaches`` counter, and
  one rung of the degradation ladder;
* while breached, every further ``burn_windows`` burning windows climb
  the next rung;
* ``recovery_windows`` consecutive clean windows emit
  ``obs.slo.recovery`` and restore every degraded knob.

:class:`ServeDegradation` is the serve-side hook the breach callback
drives, over the two knobs that already exist in the tier: first shed
the ``bulk`` admission fraction (``MicroBatcher.set_admit_fraction`` —
backfill load rejects at the edge, interactive traffic keeps its
budget), then widen the early-exit σ
(``LPServeEngine.set_sigma_scale`` — cheaper, coarser solves).  Both
restore exactly on recovery.

Everything is deterministic under an injected clock: the watchdog never
reads time itself — windows are whatever the telemetry flush ticks say
they are.  Import-light on purpose (no jax, no numpy, no api imports —
the spec layer hands over plain attributes via :meth:`SLOWatchdog.from_spec`).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.obs.metrics import Counter, Gauge, Histogram

#: counters the window delta tracks
_COUNTERS = (
    "serve.completed",
    "serve.failed",
    "serve.rejected",
    "serve.cache.hits",
    "serve.cache.misses",
)

#: degradation rungs, in escalation order
LADDER = ("shed_bulk", "widen_sigma")


class ServeDegradation:
    """The serve tier's two-rung degradation ladder.

    ``bulk_fraction`` is the shed target for the bulk admission share
    (rung 1); ``sigma_scale`` the early-exit widening factor (rung 2).
    ``escalate()`` climbs one rung per call and returns the action name
    (None once the ladder is exhausted); ``restore()`` resets every
    engaged knob and returns the actions undone.
    """

    def __init__(
        self,
        engine,
        *,
        bulk_fraction: float = 0.1,
        sigma_scale: float = 4.0,
    ):
        if not 0.0 < bulk_fraction <= 1.0:
            raise ValueError(
                f"bulk_fraction must be in (0, 1], got {bulk_fraction}"
            )
        if sigma_scale < 1.0:
            raise ValueError(f"sigma_scale must be >= 1.0, got {sigma_scale}")
        self._engine = engine
        self._bulk_fraction = bulk_fraction
        self._sigma_scale = sigma_scale
        self._base_bulk = engine.batcher.admit_fraction("bulk")
        self.level = 0

    def escalate(self) -> Optional[str]:
        if self.level >= len(LADDER):
            return None
        action = LADDER[self.level]
        if action == "shed_bulk":
            self._engine.batcher.set_admit_fraction(
                "bulk", min(self._bulk_fraction, self._base_bulk)
            )
        else:  # widen_sigma
            self._engine.set_sigma_scale(self._sigma_scale)
        self.level += 1
        return action

    def restore(self) -> List[str]:
        undone = list(LADDER[: self.level])
        if self.level >= 2:
            self._engine.set_sigma_scale(1.0)
        if self.level >= 1:
            self._engine.batcher.set_admit_fraction("bulk", self._base_bulk)
        self.level = 0
        return undone


class SLOWatchdog:
    """Multi-window burn-rate evaluation over the metrics registry."""

    def __init__(
        self,
        telemetry,
        *,
        latency_p95_ms: Optional[float] = None,
        error_rate: Optional[float] = None,
        cache_hit_floor: Optional[float] = None,
        stall_windows: Optional[int] = None,
        burn_windows: int = 3,
        recovery_windows: int = 2,
        degradation: Optional[ServeDegradation] = None,
        latency_metric: str = "serve.latency_s",
    ):
        if burn_windows < 1:
            raise ValueError(f"burn_windows must be >= 1, got {burn_windows}")
        if recovery_windows < 1:
            raise ValueError(
                f"recovery_windows must be >= 1, got {recovery_windows}"
            )
        self._tel = telemetry
        self.latency_p95_ms = latency_p95_ms
        self.error_rate = error_rate
        self.cache_hit_floor = cache_hit_floor
        self.stall_windows = stall_windows
        self.burn_windows = burn_windows
        self.recovery_windows = recovery_windows
        self.degradation = degradation
        self.latency_metric = latency_metric
        self._prev: Optional[Dict[str, Any]] = None
        self._residual_history: List[Optional[float]] = []
        self._consecutive_burn = 0
        self._consecutive_ok = 0
        self.breached = False
        self.breaches = 0
        self.recoveries = 0
        self.windows = 0
        self.history: List[Dict[str, Any]] = []

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def from_spec(
        cls, slo, telemetry, *, degradation: Optional[ServeDegradation] = None
    ) -> "SLOWatchdog":
        """Build from any object carrying the ``ObsSpec.slo`` attributes
        (the api layer's ``SLOSpec`` — duck-typed so obs never imports
        the spec module)."""
        return cls(
            telemetry,
            latency_p95_ms=getattr(slo, "latency_p95_ms", None),
            error_rate=getattr(slo, "error_rate", None),
            cache_hit_floor=getattr(slo, "cache_hit_floor", None),
            stall_windows=getattr(slo, "stall_windows", None),
            burn_windows=getattr(slo, "burn_windows", 3),
            recovery_windows=getattr(slo, "recovery_windows", 2),
            degradation=degradation,
        )

    def attach(self) -> "SLOWatchdog":
        """Register on the telemetry's flush tick (one eval per window)."""
        self._tel.add_flush_listener(self._on_flush)
        return self

    def detach(self) -> None:
        self._tel.remove_flush_listener(self._on_flush)

    def _on_flush(self, _tel) -> None:
        self.evaluate()

    # ------------------------------------------------------------ snapshots
    def _snapshot(self) -> Dict[str, Any]:
        reg = self._tel.metrics
        snap: Dict[str, Any] = {"counters": {}}
        for name in _COUNTERS:
            inst = reg.peek(name)
            snap["counters"][name] = (
                inst.value if isinstance(inst, Counter) else 0
            )
        hist = reg.peek(self.latency_metric)
        if isinstance(hist, Histogram):
            snap["hist_counts"] = list(hist.counts)
            snap["hist_edges"] = hist.edges
            snap["hist_max"] = hist.max
        residual = reg.peek("solve.residual")
        if isinstance(residual, Gauge) and residual.series:
            snap["residual"] = (len(residual.series), residual.series[-1][1])
        return snap

    def _window_p95(
        self, prev: Dict[str, Any], cur: Dict[str, Any]
    ) -> Optional[float]:
        """p95 of THIS window's latency observations (bucket-delta walk —
        the histogram-mergeability contract run in reverse)."""
        if "hist_counts" not in cur:
            return None
        prev_counts = prev.get("hist_counts") or [0] * len(cur["hist_counts"])
        delta = [c - p for c, p in zip(cur["hist_counts"], prev_counts)]
        n = sum(delta)
        if n <= 0:
            return None
        target = 0.95 * n
        cum = 0
        for i, c in enumerate(delta):
            cum += c
            if cum >= target:
                if i < len(cur["hist_edges"]):
                    return float(cur["hist_edges"][i])
                return float(cur["hist_max"])  # overflow bucket
        return float(cur["hist_max"])

    # ------------------------------------------------------------ evaluation
    def evaluate(self) -> Dict[str, Any]:
        """Score one window; fires breach/recovery as thresholds cross."""
        cur = self._snapshot()
        if self._prev is None:
            # the first tick only anchors the window arithmetic
            self._prev = cur
            return {"window": 0, "burning": False, "violations": []}
        prev, self._prev = self._prev, cur
        self.windows += 1
        violations: List[Dict[str, Any]] = []

        def delta(name: str) -> int:
            return cur["counters"][name] - prev["counters"][name]

        if self.latency_p95_ms is not None:
            p95 = self._window_p95(prev, cur)
            if p95 is not None and p95 * 1e3 > self.latency_p95_ms:
                violations.append(
                    {
                        "objective": "latency_p95_ms",
                        "observed": p95 * 1e3,
                        "threshold": self.latency_p95_ms,
                    }
                )
        if self.error_rate is not None:
            errors = delta("serve.failed") + delta("serve.rejected")
            total = errors + delta("serve.completed")
            if total > 0 and errors / total > self.error_rate:
                violations.append(
                    {
                        "objective": "error_rate",
                        "observed": errors / total,
                        "threshold": self.error_rate,
                    }
                )
        if self.cache_hit_floor is not None:
            hits = delta("serve.cache.hits")
            lookups = hits + delta("serve.cache.misses")
            if lookups > 0 and hits / lookups < self.cache_hit_floor:
                violations.append(
                    {
                        "objective": "cache_hit_floor",
                        "observed": hits / lookups,
                        "threshold": self.cache_hit_floor,
                    }
                )
        if self.stall_windows is not None:
            self._residual_history.append(
                cur["residual"][1]
                if "residual" in cur
                and ("residual" not in prev or cur["residual"][0] > prev["residual"][0])
                else None
            )
            tail = self._residual_history[-(self.stall_windows + 1) :]
            if len(tail) == self.stall_windows + 1 and all(
                v is not None for v in tail
            ):
                # the solve kept stepping for stall_windows windows
                # without the residual improving: convergence stall
                if min(tail[1:]) >= tail[0]:
                    violations.append(
                        {
                            "objective": "convergence_stall",
                            "observed": tail[-1],
                            "threshold": tail[0],
                        }
                    )

        burning = bool(violations)
        if burning:
            self._consecutive_burn += 1
            self._consecutive_ok = 0
        else:
            self._consecutive_ok += 1
            self._consecutive_burn = 0
        self._tel.gauge("obs.slo.burning", 1.0 if burning else 0.0)

        action = None
        if burning and self._consecutive_burn % self.burn_windows == 0:
            # every burn_windows consecutive burning windows: breach (the
            # first time) then one more degradation rung per recurrence
            if not self.breached:
                self.breached = True
                self.breaches += 1
            if self.degradation is not None:
                action = self.degradation.escalate()
            self._tel.count("obs.slo.breaches")
            self._tel.event(
                "obs.slo.breach",
                window=self.windows,
                consecutive=self._consecutive_burn,
                violations=violations,
                action=action,
            )
        elif (
            self.breached and self._consecutive_ok >= self.recovery_windows
        ):
            self.breached = False
            self.recoveries += 1
            restored = (
                self.degradation.restore()
                if self.degradation is not None
                else []
            )
            self._tel.count("obs.slo.recoveries")
            self._tel.event(
                "obs.slo.recovery",
                window=self.windows,
                clean_windows=self._consecutive_ok,
                restored=restored,
            )

        result = {
            "window": self.windows,
            "burning": burning,
            "violations": violations,
            "breached": self.breached,
            "action": action,
        }
        self.history.append(result)
        return result

    # --------------------------------------------------------------- report
    def report(self) -> Dict[str, Any]:
        """Artifact-ready roll-up (lands in the serve report's slo block)."""
        return {
            "windows": self.windows,
            "breaches": self.breaches,
            "recoveries": self.recoveries,
            "breached": self.breached,
            "degradation_level": (
                self.degradation.level if self.degradation is not None else 0
            ),
            "objectives": {
                k: v
                for k, v in (
                    ("latency_p95_ms", self.latency_p95_ms),
                    ("error_rate", self.error_rate),
                    ("cache_hit_floor", self.cache_hit_floor),
                    ("stall_windows", self.stall_windows),
                )
                if v is not None
            },
            "burn_windows": self.burn_windows,
            "recovery_windows": self.recovery_windows,
        }
