"""OpenMetrics / Prometheus text exposition (DESIGN.md §14.8).

:func:`render_openmetrics` turns the metrics registry's JSONL lines into
one OpenMetrics text snapshot: counters become ``*_total`` samples,
gauges expose their last set value, and histograms map the registry's
log-spaced bucket edges onto cumulative ``le``-labelled buckets (plus
the ``+Inf`` overflow) with ``*_sum`` / ``*_count``.  The streaming sink
rotates the snapshot atomically on every flush tick, so a scraper (or
``curl``) pointed at ``results/<run_id>/telemetry/metrics.prom`` always
reads a consistent point-in-time exposition.

:func:`parse_openmetrics` / :func:`lint_openmetrics` are the inverse
direction: a small line parser plus the metric-name and structure lint
CI runs against the quickstart run's snapshot (``repro obs --validate``
applies the same checks).

Import-light on purpose — pure string work over dicts, no jax, no numpy.
"""

from __future__ import annotations

import math
import re
from typing import Any, Dict, List, Optional, Tuple

#: OpenMetrics metric/label name grammar (the lint's anchor).
_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SANITIZE_RE = re.compile(r"[^a-zA-Z0-9_:]")

#: every exported sample is namespaced under this prefix
PREFIX = "repro"


def metric_name(name: str) -> str:
    """Registry name -> OpenMetrics name (``serve.latency_s`` ->
    ``repro_serve_latency_s``)."""
    safe = _SANITIZE_RE.sub("_", name)
    if not safe or not _NAME_RE.match(safe):
        safe = f"_{safe}"
    return f"{PREFIX}_{safe}"


def _fmt(value: float) -> str:
    v = float(value)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def _fmt_le(edge: float) -> str:
    return format(float(edge), ".6g")


def render_openmetrics(
    lines: List[Dict[str, Any]], *, meta: Optional[Dict[str, Any]] = None
) -> str:
    """One OpenMetrics text snapshot from ``MetricsRegistry.to_lines()``.

    Families are name-sorted; the snapshot ends with the mandatory
    ``# EOF`` terminator.  ``meta`` (when given) contributes a leading
    comment naming the run — comments are legal between families.
    """
    out: List[str] = []
    if meta:
        run_id = meta.get("run_id") or "?"
        out.append(f"# run_id {run_id} schema {meta.get('schema', '?')}")
    for line in sorted(lines, key=lambda d: d.get("name", "")):
        kind = line.get("type")
        name = metric_name(line["name"])
        if kind == "counter":
            out.append(f"# TYPE {name} counter")
            out.append(f"{name}_total {_fmt(line['value'])}")
        elif kind == "gauge":
            if line.get("last") is None:
                continue  # a gauge that was never set has no sample
            out.append(f"# TYPE {name} gauge")
            out.append(f"{name} {_fmt(line['last'])}")
        elif kind == "histogram":
            out.append(f"# TYPE {name} histogram")
            cum = 0
            counts = line["counts"]
            for edge, c in zip(line["edges"], counts):
                cum += c
                out.append(
                    f'{name}_bucket{{le="{_fmt_le(edge)}"}} {_fmt(cum)}'
                )
            out.append(f'{name}_bucket{{le="+Inf"}} {_fmt(line["count"])}')
            out.append(f"{name}_sum {_fmt(line['sum'])}")
            out.append(f"{name}_count {_fmt(line['count'])}")
    out.append("# EOF")
    return "\n".join(out) + "\n"


_SAMPLE_RE = re.compile(
    r"^(?P<name>[^\s{]+)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>\S+)\s*$"
)


def parse_openmetrics(
    text: str,
) -> Dict[str, Dict[str, Any]]:
    """Parse an OpenMetrics snapshot into ``{family: {type, samples}}``.

    ``samples`` is a list of ``(sample_name, labels, value)`` tuples.
    Raises ``ValueError`` on lines that are neither comments, blanks,
    nor well-formed samples.
    """
    families: Dict[str, Dict[str, Any]] = {}
    current: Optional[str] = None
    for i, raw in enumerate(text.splitlines(), start=1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                current = parts[2]
                families[current] = {"type": parts[3], "samples": []}
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {i}: not an OpenMetrics sample: {raw!r}")
        labels: Dict[str, str] = {}
        if m.group("labels"):
            for pair in m.group("labels").split(","):
                if "=" not in pair:
                    raise ValueError(f"line {i}: bad label pair {pair!r}")
                k, v = pair.split("=", 1)
                labels[k.strip()] = v.strip().strip('"')
        try:
            value = (
                math.inf
                if m.group("value") == "+Inf"
                else float(m.group("value"))
            )
        except ValueError as e:
            raise ValueError(
                f"line {i}: bad sample value {m.group('value')!r}"
            ) from e
        sample = (m.group("name"), labels, value)
        family = current if current and m.group("name").startswith(current) else None
        if family is None:
            # an undeclared family: record it so the lint can flag it
            family = m.group("name")
            families.setdefault(family, {"type": None, "samples": []})
        families[family]["samples"].append(sample)
    return families


def lint_openmetrics(text: str) -> List[str]:
    """Structure + metric-name lint; returns problems ([] = clean).

    Checks: the ``# EOF`` terminator, sample-name grammar, a ``# TYPE``
    declaration per family, counter samples carrying the ``_total``
    suffix, histogram buckets cumulative with a ``+Inf`` bucket matching
    ``_count``.
    """
    problems: List[str] = []
    if not text.rstrip("\n").endswith("# EOF"):
        problems.append("missing '# EOF' terminator")
    try:
        families = parse_openmetrics(text)
    except ValueError as e:
        return problems + [str(e)]
    for family, info in sorted(families.items()):
        if not _NAME_RE.match(family):
            problems.append(f"{family}: invalid metric name")
        if info["type"] is None:
            problems.append(f"{family}: sample without a # TYPE declaration")
            continue
        names = [s[0] for s in info["samples"]]
        if info["type"] == "counter":
            for n in names:
                if not n.endswith("_total"):
                    problems.append(
                        f"{family}: counter sample {n!r} lacks _total suffix"
                    )
        elif info["type"] == "histogram":
            buckets: List[Tuple[float, float]] = []
            count = None
            for n, labels, v in info["samples"]:
                if n == f"{family}_bucket":
                    le = labels.get("le")
                    if le is None:
                        problems.append(f"{family}: bucket without le label")
                        continue
                    buckets.append(
                        (math.inf if le == "+Inf" else float(le), v)
                    )
                elif n == f"{family}_count":
                    count = v
            cum = [v for _, v in buckets]
            if cum != sorted(cum):
                problems.append(f"{family}: bucket counts not cumulative")
            if not buckets or buckets[-1][0] != math.inf:
                problems.append(f"{family}: missing +Inf bucket")
            elif count is not None and buckets[-1][1] != count:
                problems.append(
                    f"{family}: +Inf bucket {buckets[-1][1]} != "
                    f"_count {count}"
                )
    return problems
