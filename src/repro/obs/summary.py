"""Run-summary assembly + text rendering for ``repro obs`` (DESIGN.md §14.5).

:func:`summarize` folds raw telemetry records (meta + span/event lines +
metric lines) into one JSON-able digest: phase durations, the solve
convergence curve, latency percentile tables, cache hit rates, and
queue/batch occupancy.  :func:`render` turns that digest into the text
report the CLI prints.  Both are pure functions over dicts so they work
identically on an in-memory :class:`~repro.obs.telemetry.Telemetry` and
on a ``results/<run_id>/telemetry/`` directory read back from disk.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple


def _segment_names(path: str) -> List[str]:
    """Live-stream segment files (``events-NNNN.jsonl``), in write order."""
    return sorted(
        n
        for n in os.listdir(path)
        if n.startswith("events-") and n.endswith(".jsonl")
    )


def load_dir(path: str) -> Tuple[Dict, List[Dict], List[Dict]]:
    """Read a telemetry directory back into (meta, events, metric lines).

    Reads the consolidated ``events.jsonl`` plus any live-stream segments
    still on disk (a run being tailed mid-flight has only segments; a
    killed run may have both), deduplicating records by ``(kind, id)``.
    A torn *final* line — the snapshot raced the writer — is tolerated
    and counted in ``meta["truncated_lines"]``; a bad line anywhere else
    is real corruption and still raises.
    """
    truncated = 0

    def read_jsonl(name: str) -> List[Dict]:
        nonlocal truncated
        fp = os.path.join(path, name)
        if not os.path.isfile(fp):
            return []
        out = []
        raw = [ln.strip() for ln in open(fp)]
        raw = [ln for ln in raw if ln]
        for i, line in enumerate(raw):
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(raw) - 1:
                    truncated += 1
                    continue
                raise
        return out

    events = read_jsonl("events.jsonl")
    seen = {(r.get("kind"), r.get("id")) for r in events if "id" in r}
    for name in _segment_names(path):
        for r in read_jsonl(name):
            key = (r.get("kind"), r.get("id"))
            if "id" in r and key in seen:
                continue
            if "id" in r:
                seen.add(key)
            events.append(r)
    metrics = read_jsonl("metrics.jsonl")
    meta = next((r for r in events + metrics if r.get("kind") == "meta"), {})
    if truncated:
        meta = dict(meta)
        meta["truncated_lines"] = truncated
    return (
        meta,
        [r for r in events if r.get("kind") in ("span", "event")],
        [r for r in metrics if r.get("kind") == "metric"],
    )


def _series(metric: Optional[Dict]) -> List[float]:
    if not metric:
        return []
    return [float(v) for _, v in metric.get("series", [])]


def summarize(meta: Dict, events: List[Dict], metrics: List[Dict]) -> Dict[str, Any]:
    """Fold raw telemetry records into the run digest."""
    by_name = {m["name"]: m for m in metrics}
    counters = {
        m["name"]: m["value"] for m in metrics if m.get("type") == "counter"
    }
    out: Dict[str, Any] = {
        "run_id": meta.get("run_id"),
        "level": meta.get("level"),
        "counters": counters,
    }
    if meta.get("truncated_lines"):
        out["truncated_lines"] = meta["truncated_lines"]

    spans: Dict[str, int] = {}
    phases = []
    for record in events:
        if record.get("kind") != "span":
            continue
        kind = record.get("span", "?")
        spans[kind] = spans.get(kind, 0) + 1
        if kind == "phase":
            phases.append(
                {"name": record.get("name"), "dur_s": record.get("dur_s")}
            )
    out["spans"] = spans
    out["events"] = sum(1 for r in events if r.get("kind") == "event")
    if phases:
        out["phases"] = phases

    residuals = _series(by_name.get("solve.residual"))
    if residuals:
        out["convergence"] = {
            "supersteps": len(residuals),
            "first_residual": residuals[0],
            "last_residual": residuals[-1],
            "residuals": residuals,
            "active_columns": _series(by_name.get("solve.active_columns")),
        }

    latency = {}
    for m in metrics:
        if m.get("type") != "histogram" or not m.get("count"):
            continue
        latency[m["name"]] = {
            k: m.get(k) for k in ("count", "p50", "p95", "p99", "min", "max")
        }
    if latency:
        out["latency"] = latency

    hits = counters.get("serve.cache.hits", 0)
    misses = counters.get("serve.cache.misses", 0)
    if hits or misses:
        out["cache"] = {
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / (hits + misses),
            "evictions": counters.get("serve.cache.evictions", 0),
            "invalidations": counters.get("serve.cache.invalidations", 0),
        }

    breaches = counters.get("obs.slo.breaches", 0)
    recoveries = counters.get("obs.slo.recoveries", 0)
    if breaches or recoveries:
        burning = _series(by_name.get("obs.slo.burning"))
        out["slo"] = {
            "breaches": breaches,
            "recoveries": recoveries,
            "burning": bool(burning and burning[-1]),
        }

    ft_keys = {
        "checkpoints": "ft.checkpoints",
        "resumes": "ft.resumes",
        "retries": "ft.retries",
        "restores": "ft.restores",
        "straggler_flags": "ft.straggler_flags",
        "remeshes": "ft.remeshes",
    }
    if any(counters.get(name) for name in ft_keys.values()):
        out["ft"] = {
            short: counters.get(name, 0) for short, name in ft_keys.items()
        }

    depth = _series(by_name.get("serve.queue_depth"))
    if depth:
        out["queue"] = {
            "max_depth": max(depth),
            "mean_depth": sum(depth) / len(depth),
        }
    occupancy = _series(by_name.get("serve.batch_occupancy"))
    if occupancy:
        out["batch"] = {
            "batches": len(occupancy),
            "mean_occupancy": sum(occupancy) / len(occupancy),
            "mean_size": (
                sum(sizes) / len(sizes)
                if (sizes := _series(by_name.get("serve.batch_size")))
                else None
            ),
        }
    return out


def _fmt_ms(v: Optional[float]) -> str:
    return "-" if v is None else f"{v * 1e3:.2f}ms"


def render(summary: Dict[str, Any]) -> str:
    """The ``repro obs <run_id>`` text report."""
    lines = [
        f"run {summary.get('run_id') or '?'}  level={summary.get('level') or '?'}"
    ]
    for phase in summary.get("phases", []):
        lines.append(f"  phase {phase['name']}: {phase['dur_s']:.3f}s")

    conv = summary.get("convergence")
    if conv:
        lines.append(
            f"convergence: {conv['supersteps']} supersteps, residual "
            f"{conv['first_residual']:.3e} -> {conv['last_residual']:.3e}"
        )
        curve = conv["residuals"]
        shown = curve if len(curve) <= 12 else curve[:6] + curve[-6:]
        gap = "" if len(curve) <= 12 else " ..."
        head = " ".join(f"{r:.2e}" for r in shown[:6])
        tail = " ".join(f"{r:.2e}" for r in shown[6:])
        lines.append(f"  curve: {head}{gap} {tail}".rstrip())

    for name, h in sorted(summary.get("latency", {}).items()):
        lines.append(
            f"latency {name}: n={h['count']} p50={_fmt_ms(h['p50'])} "
            f"p95={_fmt_ms(h['p95'])} p99={_fmt_ms(h['p99'])} "
            f"max={_fmt_ms(h['max'])}"
        )

    cache = summary.get("cache")
    if cache:
        lines.append(
            f"cache: hits={cache['hits']} misses={cache['misses']} "
            f"hit_rate={cache['hit_rate']:.2%} "
            f"evictions={cache['evictions']} demoted={cache['invalidations']}"
        )

    slo = summary.get("slo")
    if slo:
        state = "BURNING" if slo["burning"] else "ok"
        lines.append(
            f"slo: breaches={slo['breaches']} recoveries={slo['recoveries']} "
            f"state={state}"
        )

    ft = summary.get("ft")
    if ft:
        lines.append(
            f"ft: checkpoints={ft['checkpoints']} resumes={ft['resumes']} "
            f"retries={ft['retries']} restores={ft['restores']} "
            f"straggler_flags={ft['straggler_flags']}"
            + (f" remeshes={ft['remeshes']}" if ft.get("remeshes") else "")
        )

    queue = summary.get("queue")
    if queue:
        lines.append(
            f"queue: max_depth={queue['max_depth']:.0f} "
            f"mean_depth={queue['mean_depth']:.1f}"
        )
    batch = summary.get("batch")
    if batch:
        size = batch.get("mean_size")
        lines.append(
            f"batches: {batch['batches']} "
            f"mean_occupancy={batch['mean_occupancy']:.2f}"
            + (f" mean_size={size:.1f}" if size is not None else "")
        )

    spans = summary.get("spans", {})
    if spans or summary.get("events"):
        span_txt = " ".join(f"{k}={v}" for k, v in sorted(spans.items()))
        lines.append(f"records: spans[{span_txt}] events={summary.get('events', 0)}")
    if summary.get("truncated_lines"):
        lines.append(
            f"warning: {summary['truncated_lines']} truncated trailing "
            "line(s) skipped (snapshot raced the writer)"
        )
    return "\n".join(lines)
