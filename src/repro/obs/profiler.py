"""Profiler hooks: ``jax.profiler`` traces + kernel timing (DESIGN.md §14.4).

Two opt-in capture paths, both active only at ``obs.level="profile"``:

* :func:`profile_phase` — a ``jax.profiler.trace`` context the Session
  wraps around its solve/serve phases, writing the device trace under
  ``results/<run_id>/telemetry/profile/``;
* the kernel hook — :func:`kernel_clock` / :func:`kernel_time` pairs in
  the ``kernels/`` op wrappers.  Per-variant wall times land in
  ``kernel.<name>.latency_s`` histograms so the roofline suite can
  attribute achieved FLOPs/bandwidth to named kernels.

The kernel hook is a module global, not a parameter: op wrappers are
called from deep inside engine loops where threading a telemetry handle
through every signature would contaminate jit static args.  When no
collector is installed, the cost per op call is one global load + one
``is None`` branch.  Calls made during jit *tracing* return a
``jax.core.Tracer`` — those are skipped (a trace-time wall clock times
program construction, not the kernel), so only eager invocations (e.g.
``engine.round`` refresh paths) are measured, with ``block_until_ready``
making the timing honest about async dispatch.
"""

from __future__ import annotations

import contextlib
import os
import time
from typing import Optional

_COLLECTOR = None


def install_kernel_hook(telemetry) -> None:
    """Route kernel timings into ``telemetry`` (one collector at a time)."""
    global _COLLECTOR
    _COLLECTOR = telemetry


def uninstall_kernel_hook() -> None:
    global _COLLECTOR
    _COLLECTOR = None


def kernel_clock() -> Optional[float]:
    """Timestamp for a kernel-op call; None when no collector is active."""
    if _COLLECTOR is None:
        return None
    return time.perf_counter()


def kernel_time(name: str, t0: Optional[float], out):
    """Record one kernel-op wall time; returns ``out`` unchanged."""
    tel = _COLLECTOR
    if tel is None or t0 is None:
        return out
    import jax

    if isinstance(out, jax.core.Tracer):
        return out
    jax.block_until_ready(out)
    tel.observe(f"kernel.{name}.latency_s", time.perf_counter() - t0)
    tel.count(f"kernel.{name}.calls")
    return out


@contextlib.contextmanager
def profile_phase(telemetry, out_dir: str, phase: str):
    """``jax.profiler.trace`` around one Session phase (profile level only)."""
    if telemetry is None or not telemetry.profile_enabled:
        yield None
        return
    try:
        import jax.profiler as jprof
    except Exception:  # pragma: no cover - jax always present in repo
        yield None
        return
    trace_dir = os.path.join(out_dir, "profile", phase)
    os.makedirs(trace_dir, exist_ok=True)
    telemetry.event("profile.trace", phase=phase, dir=trace_dir)
    with jprof.trace(trace_dir):
        yield trace_dir
