"""Observability subsystem: telemetry, metrics, profiler hooks (DESIGN.md §14).

The cross-cutting layer a :class:`~repro.api.session.Session` threads
through solve/serve/bench/dryrun when the spec carries an ``obs``
section:

* :mod:`repro.obs.telemetry` — structured spans/events + the level gate;
* :mod:`repro.obs.metrics`   — counters, gauges, log-bucket histograms;
* :mod:`repro.obs.schema`    — JSONL schema validation (CI + ``--validate``);
* :mod:`repro.obs.export`    — OpenMetrics text snapshots (render/parse/lint);
* :mod:`repro.obs.slo`       — SLO watchdog + serve degradation ladder;
* :mod:`repro.obs.solve`     — the observed per-superstep solve loop;
* :mod:`repro.obs.profiler`  — ``jax.profiler`` phases + kernel timing;
* :mod:`repro.obs.summary`   — digest + text rendering for ``repro obs``.

Import-light on purpose: importing :mod:`repro.obs` must not pull jax
(the profiler imports it lazily), so the CLI can validate telemetry
artifacts without touching an accelerator runtime.
"""

from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    bucket_index,
)
from repro.obs.export import lint_openmetrics, parse_openmetrics, render_openmetrics
from repro.obs.schema import TelemetryError, validate_dir, validate_file, validate_line
from repro.obs.slo import ServeDegradation, SLOWatchdog
from repro.obs.telemetry import LEVELS, SCHEMA, Span, Telemetry

__all__ = [
    "DEFAULT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "LEVELS",
    "MetricsRegistry",
    "SCHEMA",
    "SLOWatchdog",
    "ServeDegradation",
    "Span",
    "Telemetry",
    "TelemetryError",
    "bucket_index",
    "lint_openmetrics",
    "parse_openmetrics",
    "render_openmetrics",
    "validate_dir",
    "validate_file",
    "validate_line",
]
