"""Observed solve: a host-driven ``engine.round`` loop (DESIGN.md §14.3).

The jitted solvers run their whole iteration inside one
``lax.while_loop`` — per-superstep residuals are unreachable without a
host callback in the hot path.  Instead of instrumenting the jitted
loop, an observability-enabled ``Session.solve()`` drives the SAME fused
update from the host, one ``engine.round`` per superstep, recording the
residual and active-column series the Giraph aggregators report for
free:

    base = Y                        (fixed-seed mode)
    Fn   = round(op, F, Y)          (= β²·base + A_eff @ F)
    Fn  += momentum · (F − F_prev)  (heavy-ball, when configured)
    Fn   = where(active, Fn, F)     (voteToHalt: converged columns freeze)

This replicates the fused DHLP-2 fixed-seed semantics exactly, so the
observed path lands on the same fixed point as the jitted path (the
per-round dispatch overhead is why it is opt-in and never used by the
serve tier).  Eligibility is checked by :func:`supports_observed`:
fused DHLP-2, fixed seeds, batched mode, and a backend implementing
``round`` — anything else falls back to the plain jitted solve.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.solver import SolveResult


def supports_observed(engine) -> bool:
    """Whether ``engine`` can run the per-superstep observed loop."""
    from repro.engine.base import LPEngine

    cfg = engine.config
    if cfg.alg != "dhlp2" or cfg.mode != "batched" or not cfg.fused:
        return False
    if cfg.resolved_seed_mode() != "fixed":
        return False
    # the loop steps with engine.round — a backend that never overrode
    # the (raising) base implementation cannot be observed
    return type(engine).round is not LPEngine.round


def _solve_block(
    engine, op, Y: np.ndarray, F0: Optional[np.ndarray], telemetry
) -> Tuple[np.ndarray, np.ndarray, bool, List[float], List[int]]:
    """One chunk of seed columns through the host round loop."""
    cfg = engine.config
    F = Y.copy() if F0 is None else np.array(F0, dtype=np.float64, copy=True)
    F_prev = F
    ncols = Y.shape[1]
    active = np.ones(ncols, dtype=bool)
    col_iters = np.zeros(ncols, dtype=np.int32)
    residuals: List[float] = []
    actives: List[int] = []
    converged = False
    for _ in range(cfg.max_iter):
        with telemetry.trace_span("superstep", f"superstep:{len(residuals)}"):
            Fn = np.asarray(engine.round(op, F, Y), dtype=np.float64)
            if cfg.momentum:
                Fn = Fn + cfg.momentum * (F - F_prev)
            Fn = np.where(active[None, :], Fn, F)
            delta = np.max(np.abs(Fn - F), axis=0)
            col_iters += active.astype(np.int32)
            still = active & ~(delta < cfg.sigma)
            residual = float(delta[active].max()) if active.any() else 0.0
        residuals.append(residual)
        actives.append(int(still.sum()))
        telemetry.maybe_flush()  # superstep boundary = solve-side streaming pump
        F_prev, F, active = F, Fn, still
        if not active.any():
            converged = True
            break
    return F, col_iters, converged, residuals, actives


def observed_solve(
    engine,
    net,
    seeds: Optional[np.ndarray] = None,
    F0: Optional[np.ndarray] = None,
    *,
    telemetry,
) -> SolveResult:
    """``engine.run`` semantics with per-superstep telemetry.

    Honors ``LPConfig.seed_chunk`` the way the jitted path does: chunks
    solve independently and their residual series merge per superstep
    (max residual, summed active columns) so the recorded convergence
    curve describes the whole solve, not the last chunk.
    """
    from repro.core.network import seeds_identity

    op = engine.prepare(net)
    n = op.num_nodes
    Y = seeds_identity(n) if seeds is None else np.asarray(seeds, dtype=np.float64)
    if Y.ndim == 1:
        Y = Y[:, None]
    if Y.shape[0] != n:
        raise ValueError(f"seeds must have {n} rows, got {Y.shape}")
    if F0 is not None:
        F0 = np.asarray(F0, dtype=np.float64)
        if F0.ndim == 1:
            F0 = F0[:, None]
        if F0.shape != Y.shape:
            raise ValueError(f"F0 shape {F0.shape} must match seeds shape {Y.shape}")

    cfg = engine.config
    ncols = Y.shape[1]
    chunk = cfg.seed_chunk if 0 < cfg.seed_chunk < ncols else ncols
    blocks = []
    for c in range(0, ncols, chunk):
        blocks.append(
            _solve_block(
                engine,
                op,
                np.ascontiguousarray(Y[:, c : c + chunk]),
                None if F0 is None else np.ascontiguousarray(F0[:, c : c + chunk]),
                telemetry=telemetry,
            )
        )
    F = np.concatenate([b[0] for b in blocks], axis=1)
    col_iters = np.concatenate([b[1] for b in blocks])
    converged = all(b[2] for b in blocks)
    outer = max(len(b[3]) for b in blocks)

    # merged per-superstep series: the convergence curve `repro obs` plots
    for step in range(outer):
        residual = max(b[3][step] for b in blocks if step < len(b[3]))
        active = sum(b[4][step] for b in blocks if step < len(b[4]))
        telemetry.gauge("solve.residual", residual)
        telemetry.gauge("solve.active_columns", active)
    telemetry.count("solve.supersteps", outer)
    telemetry.count("solve.columns", ncols)

    return SolveResult(
        F=F,
        outer_iters=outer,
        inner_iters=0,
        converged=converged,
        per_column_iters=col_iters,
    )
