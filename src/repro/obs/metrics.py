"""Counters, gauges, and log-bucketed histograms (DESIGN.md §14.2).

Pure-python instruments with fixed memory per sample class:

* :class:`Counter` — a monotonically increasing number;
* :class:`Gauge`   — a timestamped series of set values (queue depth,
  batch occupancy, per-superstep residual — the "series" artifacts);
* :class:`Histogram` — fixed log-spaced buckets (five per decade over
  ``[1 µs, 100 s]`` by default) so latency distributions accumulate in
  O(1) per observation and merge across runs bucket-by-bucket.

A registry is type-strict: asking for ``counter("x")`` after ``gauge("x")``
is a bug, not a silent re-type.
"""

from __future__ import annotations

import bisect
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

#: log-spaced bucket upper edges: 5 per decade, 1e-6 .. 1e2 seconds.
#: values land in the first bucket whose (inclusive) upper edge reaches
#: them; anything beyond the last edge goes to one overflow bucket.
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    10.0 ** (k / 5.0) for k in range(-30, 11)
)


def bucket_index(value: float, edges: Tuple[float, ...] = DEFAULT_BUCKETS) -> int:
    """Index of the bucket ``value`` falls in (``len(edges)`` = overflow)."""
    return bisect.bisect_left(edges, value)


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def to_line(self) -> Dict[str, Any]:
        return {
            "kind": "metric",
            "type": "counter",
            "name": self.name,
            "value": self.value,
        }


class Gauge:
    __slots__ = ("name", "_clock", "series")

    def __init__(self, name: str, clock):
        self.name = name
        self._clock = clock
        self.series: List[Tuple[float, float]] = []

    def set(self, value: float) -> None:
        self.series.append((self._clock(), float(value)))

    @property
    def value(self) -> Optional[float]:
        return self.series[-1][1] if self.series else None

    def to_line(self) -> Dict[str, Any]:
        return {
            "kind": "metric",
            "type": "gauge",
            "name": self.name,
            "last": self.value,
            "series": [[t, v] for t, v in self.series],
        }


class Histogram:
    __slots__ = ("name", "edges", "counts", "count", "total", "min", "max")

    def __init__(self, name: str, edges: Tuple[float, ...] = DEFAULT_BUCKETS):
        if not edges or list(edges) != sorted(edges):
            raise ValueError("histogram edges must be a sorted non-empty tuple")
        self.name = name
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value: float) -> None:
        value = float(value)
        self.counts[bucket_index(value, self.edges)] += 1
        self.count += 1
        self.total += value
        if self.min is None or value < self.min:
            self.min = value
        if self.max is None or value > self.max:
            self.max = value

    def percentile(self, q: float) -> Optional[float]:
        """Upper bucket edge covering quantile ``q`` (conservative bound),
        clamped into the observed [min, max] envelope."""
        if not self.count:
            return None
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target:
                edge = self.edges[i] if i < len(self.edges) else self.max
                return float(min(max(edge, self.min), self.max))
        return self.max

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into this histogram bucket-by-bucket (in place).

        The mergeability contract the segment-rotation sink and the SLO
        window arithmetic rely on: two histograms over the same edges
        combine exactly (counts add, the [min, max] envelope widens).
        """
        if self.edges != other.edges:
            raise ValueError(
                f"cannot merge histograms with different edges "
                f"({len(self.edges)} vs {len(other.edges)})"
            )
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        if other.min is not None and (self.min is None or other.min < self.min):
            self.min = other.min
        if other.max is not None and (self.max is None or other.max > self.max):
            self.max = other.max
        return self

    @classmethod
    def from_line(cls, line: Dict[str, Any]) -> "Histogram":
        """Rebuild a histogram from its :meth:`to_line` record."""
        h = cls(line["name"], tuple(line["edges"]))
        counts = list(line["counts"])
        if len(counts) != len(h.counts):
            raise ValueError(
                f"histogram line carries {len(counts)} counts for "
                f"{len(h.edges)} edges"
            )
        h.counts = counts
        h.count = int(line["count"])
        h.total = float(line["sum"])
        h.min = None if line.get("min") is None else float(line["min"])
        h.max = None if line.get("max") is None else float(line["max"])
        return h

    def to_line(self) -> Dict[str, Any]:
        return {
            "kind": "metric",
            "type": "histogram",
            "name": self.name,
            "count": self.count,
            "sum": self.total,
            "min": self.min,
            "max": self.max,
            "edges": list(self.edges),
            "counts": list(self.counts),
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
        }


class MetricsRegistry:
    """Name-keyed, type-strict instrument store."""

    def __init__(self, clock=None):
        self._clock = time.monotonic if clock is None else clock
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}

    def _get(self, name: str, cls, factory):
        inst = self._instruments.get(name)
        if inst is None:
            with self._lock:
                inst = self._instruments.setdefault(name, factory())
        if not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} is a {type(inst).__name__}, "
                f"not a {cls.__name__}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name, self._clock))

    def histogram(
        self, name: str, edges: Tuple[float, ...] = DEFAULT_BUCKETS
    ) -> Histogram:
        return self._get(name, Histogram, lambda: Histogram(name, edges))

    def peek(self, name: str) -> Optional[Any]:
        """The instrument registered under ``name``, or None — never
        creates one (the SLO watchdog reads without perturbing)."""
        return self._instruments.get(name)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def to_lines(self) -> List[Dict[str, Any]]:
        """One JSONL-able record per instrument, name-sorted."""
        return [self._instruments[n].to_line() for n in self.names()]
