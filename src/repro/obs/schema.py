"""Telemetry JSONL schema validation (DESIGN.md §14.1).

Every telemetry file leads with a ``meta`` line naming the schema
version; subsequent lines are ``span`` / ``event`` / ``metric`` records.
:func:`validate_dir` is what CI runs against the quickstart run's
``results/<run_id>/telemetry/`` output, and what ``repro obs --validate``
exposes.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Mapping

from repro.obs.telemetry import LEVELS, SCHEMA

_METRIC_TYPES = ("counter", "gauge", "histogram")


class TelemetryError(ValueError):
    """A telemetry line/file does not conform to the schema."""


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise TelemetryError(msg)


def _require_id(d: Mapping[str, Any], key: str, *, nullable: bool = False) -> None:
    v = d.get(key)
    if nullable and v is None:
        return
    _require(
        isinstance(v, int) and not isinstance(v, bool) and v >= 0,
        f"{d.get('kind')}.{key} must be an int >= 0, got {v!r}",
    )


def _require_num(d: Mapping[str, Any], key: str) -> None:
    v = d.get(key)
    _require(
        isinstance(v, (int, float)) and not isinstance(v, bool),
        f"{d.get('kind')}.{key} must be a number, got {v!r}",
    )


def validate_line(d: Any) -> str:
    """Validate one telemetry record; returns its ``kind``."""
    _require(isinstance(d, Mapping), f"line must be a mapping, got {type(d)}")
    kind = d.get("kind")
    if kind == "meta":
        _require(
            d.get("schema") == SCHEMA,
            f"meta.schema must be {SCHEMA!r}, got {d.get('schema')!r}",
        )
        level = d.get("level")
        _require(
            level is None or level in LEVELS,
            f"meta.level must be one of {LEVELS}, got {level!r}",
        )
    elif kind == "span":
        _require_id(d, "id")
        _require_id(d, "parent", nullable=True)
        for key in ("span", "name"):
            _require(
                isinstance(d.get(key), str) and d[key] != "",
                f"span.{key} must be a non-empty string",
            )
        _require_num(d, "t0")
        _require_num(d, "dur_s")
        _require(d["dur_s"] >= 0, f"span.dur_s must be >= 0, got {d['dur_s']}")
    elif kind == "event":
        _require_id(d, "id")
        _require_id(d, "parent", nullable=True)
        _require(
            isinstance(d.get("name"), str) and d["name"] != "",
            "event.name must be a non-empty string",
        )
        _require_num(d, "t")
        attrs = d.get("attrs")
        _require(
            attrs is None or isinstance(attrs, Mapping),
            "event.attrs must be a mapping",
        )
    elif kind == "metric":
        _require(
            d.get("type") in _METRIC_TYPES,
            f"metric.type must be one of {_METRIC_TYPES}, got {d.get('type')!r}",
        )
        _require(
            isinstance(d.get("name"), str) and d["name"] != "",
            "metric.name must be a non-empty string",
        )
        if d["type"] == "counter":
            _require_num(d, "value")
        elif d["type"] == "gauge":
            series = d.get("series")
            _require(isinstance(series, list), "gauge.series must be a list")
            for point in series:
                _require(
                    isinstance(point, list) and len(point) == 2,
                    f"gauge.series points must be [t, value], got {point!r}",
                )
        else:  # histogram
            for key in ("count", "sum"):
                _require_num(d, key)
            edges, counts = d.get("edges"), d.get("counts")
            _require(isinstance(edges, list), "histogram.edges must be a list")
            _require(isinstance(counts, list), "histogram.counts must be a list")
            _require(
                len(counts) == len(edges) + 1,
                f"histogram must carry len(edges)+1 counts, got "
                f"{len(counts)} for {len(edges)} edges",
            )
            _require(
                sum(counts) == d["count"],
                "histogram bucket counts must sum to count",
            )
    else:
        raise TelemetryError(f"unknown record kind {kind!r}")
    return str(kind)


def validate_file(path: str) -> Dict[str, int]:
    """Validate one telemetry JSONL file; returns per-kind line counts."""
    counts: Dict[str, int] = {}
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                raise TelemetryError(f"{path}:{i + 1}: invalid JSON ({e})") from e
            try:
                kind = validate_line(d)
            except TelemetryError as e:
                raise TelemetryError(f"{path}:{i + 1}: {e}") from e
            _require(
                i > 0 or kind == "meta",
                f"{path}: first line must be a meta record, got {kind!r}",
            )
            counts[kind] = counts.get(kind, 0) + 1
    _require(counts.get("meta", 0) >= 1, f"{path}: no meta line")
    return counts


def validate_dir(path: str) -> Dict[str, int]:
    """Validate a ``results/<run_id>/telemetry/`` directory.

    ``events.jsonl`` and ``metrics.jsonl`` are required (segment files
    ``events-NNNN.jsonl`` from a live stream are validated like any other
    JSONL — each leads with its own meta line); ``summary.json`` must be
    a JSON object when present; a ``metrics.prom`` OpenMetrics snapshot
    is parsed and name-linted (the CI telemetry-artifact gate).  Returns
    merged per-kind counts.
    """
    _require(os.path.isdir(path), f"{path} is not a directory")
    for required in ("events.jsonl", "metrics.jsonl"):
        _require(
            os.path.isfile(os.path.join(path, required)),
            f"{path}: missing {required}",
        )
    counts: Dict[str, int] = {}
    for name in sorted(os.listdir(path)):
        if not name.endswith(".jsonl"):
            continue
        for kind, n in validate_file(os.path.join(path, name)).items():
            counts[kind] = counts.get(kind, 0) + n
    summary = os.path.join(path, "summary.json")
    if os.path.isfile(summary):
        with open(summary) as f:
            try:
                doc = json.load(f)
            except json.JSONDecodeError as e:
                raise TelemetryError(f"{summary}: invalid JSON ({e})") from e
        _require(isinstance(doc, dict), f"{summary}: must be a JSON object")
        counts["summary"] = 1
    prom = os.path.join(path, "metrics.prom")
    if os.path.isfile(prom):
        from repro.obs.export import lint_openmetrics, parse_openmetrics

        with open(prom) as f:
            text = f.read()
        problems = lint_openmetrics(text)
        _require(
            not problems,
            f"{prom}: OpenMetrics lint failed: {'; '.join(problems)}",
        )
        counts["openmetrics"] = len(parse_openmetrics(text))
    return counts
