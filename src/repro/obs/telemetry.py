"""Structured telemetry: spans, events, and the metrics facade (DESIGN.md §14).

One :class:`Telemetry` object rides through a
:class:`~repro.api.session.Session` and is threaded (as an optional
keyword) into the serve stack and the observed-solve loop.  Design
constraints, in priority order:

* **off is free** — every recording entry point starts with one branch;
  when ``level == "off"`` the only state change is a host-side
  ``suppressed`` counter increment (no allocation, no lock, no clock
  read, and never a callback into jitted code);
* **spans carry explicit parent ids** — the taxonomy is
  ``run > phase > superstep`` for solves and ``run > batch > query`` for
  serving.  Parentage is tracked per-thread (the micro-batcher closes
  batch spans on its own thread) with an *ambient* fallback: a span
  opened on a thread with an empty stack parents to the innermost open
  ``run``/``phase`` span, so background-thread batches nest under the
  serve phase;
* **deterministic ids** — one process-wide increment under a lock; the
  clock is injectable so tests assert exact timings.

Levels: ``off`` < ``metrics`` (counters/gauges/histograms + structural
spans) < ``trace`` (adds per-superstep / per-query spans) < ``profile``
(adds ``jax.profiler`` + kernel timing hooks, see
:mod:`repro.obs.profiler`).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs.metrics import MetricsRegistry

SCHEMA = "repro.obs/v1"
LEVELS = ("off", "metrics", "trace", "profile")

#: span kinds that update the ambient parent for spans opened on other
#: threads (coarse structural spans only — a batch span must not become
#: the ambient parent of an unrelated phase)
_AMBIENT_KINDS = ("run", "phase")


class _NullSpan:
    """Reusable no-op span: the disabled path allocates nothing."""

    __slots__ = ()
    id = None
    parent = None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **attrs) -> None:
        return None


_NULL_SPAN = _NullSpan()


class Span:
    """One timed, parented region; records itself on ``__exit__``."""

    __slots__ = ("_tel", "id", "parent", "kind", "name", "attrs", "t0", "_prev")

    def __init__(
        self,
        tel: "Telemetry",
        span_id: int,
        parent: Optional[int],
        kind: str,
        name: str,
        attrs: Dict[str, Any],
    ):
        self._tel = tel
        self.id = span_id
        self.parent = parent
        self.kind = kind
        self.name = name
        self.attrs = attrs
        self.t0: Optional[float] = None
        self._prev: Optional[int] = None

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        tel = self._tel
        tel._stack().append(self.id)
        if self.kind in _AMBIENT_KINDS:
            self._prev = tel._ambient
            tel._ambient = self.id
        self.t0 = tel.clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        tel = self._tel
        t1 = tel.clock()
        stack = tel._stack()
        if stack and stack[-1] == self.id:
            stack.pop()
        if self.kind in _AMBIENT_KINDS:
            tel._ambient = self._prev
        record: Dict[str, Any] = {
            "kind": "span",
            "id": self.id,
            "parent": self.parent,
            "span": self.kind,
            "name": self.name,
            "t0": self.t0,
            "dur_s": t1 - (self.t0 if self.t0 is not None else t1),
        }
        if exc_type is not None:
            record["status"] = "error"
            record["error"] = f"{exc_type.__name__}: {exc}"
        if self.attrs:
            record["attrs"] = self.attrs
        tel._append(record)


class Telemetry:
    """The per-run telemetry hub: spans + events + metrics registry."""

    def __init__(
        self,
        level: str = "off",
        *,
        run_id: Optional[str] = None,
        clock=None,
    ):
        if level not in LEVELS:
            raise ValueError(f"obs level must be one of {LEVELS}, got {level!r}")
        self.level = level
        self.run_id = run_id
        self.clock = time.monotonic if clock is None else clock
        #: disabled-path activity counter — the ONLY state the off level
        #: touches, and the overhead-guard tests' zero-event witness
        self.suppressed = 0
        self._lock = threading.Lock()
        self._next_id = 0
        self._events: List[Dict[str, Any]] = []
        self._local = threading.local()
        self._ambient: Optional[int] = None
        self.metrics = MetricsRegistry(clock=self.clock)

    # ---------------------------------------------------------------- levels
    @property
    def enabled(self) -> bool:
        return self.level != "off"

    @property
    def trace_enabled(self) -> bool:
        return self.level in ("trace", "profile")

    @property
    def profile_enabled(self) -> bool:
        return self.level == "profile"

    # ----------------------------------------------------------------- spans
    def _stack(self) -> List[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _current_parent(self) -> Optional[int]:
        stack = self._stack()
        if stack:
            return stack[-1]
        return self._ambient

    def _alloc_id(self) -> int:
        with self._lock:
            i = self._next_id
            self._next_id += 1
        return i

    def _append(self, record: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(record)

    def span(self, kind: str, name: Optional[str] = None, **attrs):
        """Open a structural span (recorded at every enabled level)."""
        if not self.enabled:
            self.suppressed += 1
            return _NULL_SPAN
        return Span(
            self, self._alloc_id(), self._current_parent(), kind, name or kind, attrs
        )

    def trace_span(self, kind: str, name: Optional[str] = None, **attrs):
        """A fine-grained span (superstep/batch/query): trace level only."""
        if not self.trace_enabled:
            if not self.enabled:
                self.suppressed += 1
            return _NULL_SPAN
        return self.span(kind, name, **attrs)

    def event(self, name: str, **attrs) -> None:
        """A point event under the current parent (any enabled level)."""
        if not self.enabled:
            self.suppressed += 1
            return
        record: Dict[str, Any] = {
            "kind": "event",
            "id": self._alloc_id(),
            "parent": self._current_parent(),
            "name": name,
            "t": self.clock(),
        }
        if attrs:
            record["attrs"] = attrs
        self._append(record)

    # --------------------------------------------------------------- metrics
    def count(self, name: str, n: int = 1) -> None:
        if not self.enabled:
            self.suppressed += 1
            return
        self.metrics.counter(name).inc(n)

    def gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            self.suppressed += 1
            return
        self.metrics.gauge(name).set(value)

    def observe(self, name: str, value: float) -> None:
        if not self.enabled:
            self.suppressed += 1
            return
        self.metrics.histogram(name).observe(value)

    # ------------------------------------------------------------ inspection
    def events(self) -> List[Dict[str, Any]]:
        """Snapshot of the recorded span/event records (closed spans only)."""
        with self._lock:
            return list(self._events)

    def meta(self) -> Dict[str, Any]:
        return {
            "kind": "meta",
            "schema": SCHEMA,
            "run_id": self.run_id,
            "level": self.level,
        }

    def summary(self) -> Dict[str, Any]:
        from repro.obs.summary import summarize

        return summarize(self.meta(), self.events(), self.metrics.to_lines())

    # ----------------------------------------------------------------- flush
    def flush(self, dir_path: str) -> List[str]:
        """Write ``events.jsonl`` / ``metrics.jsonl`` / ``summary.json``.

        Each JSONL file leads with a ``meta`` line carrying the schema
        version; returns the written paths ([] when disabled).
        """
        if not self.enabled:
            return []
        os.makedirs(dir_path, exist_ok=True)
        meta = self.meta()
        paths = []
        events_path = os.path.join(dir_path, "events.jsonl")
        with open(events_path, "w") as f:
            for record in [meta] + self.events():
                f.write(json.dumps(record, sort_keys=True) + "\n")
        paths.append(events_path)
        metrics_path = os.path.join(dir_path, "metrics.jsonl")
        with open(metrics_path, "w") as f:
            for record in [meta] + self.metrics.to_lines():
                f.write(json.dumps(record, sort_keys=True) + "\n")
        paths.append(metrics_path)
        summary_path = os.path.join(dir_path, "summary.json")
        with open(summary_path, "w") as f:
            json.dump(self.summary(), f, indent=2, sort_keys=True)
            f.write("\n")
        paths.append(summary_path)
        return paths
