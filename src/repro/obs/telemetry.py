"""Structured telemetry: spans, events, and the metrics facade (DESIGN.md §14).

One :class:`Telemetry` object rides through a
:class:`~repro.api.session.Session` and is threaded (as an optional
keyword) into the serve stack and the observed-solve loop.  Design
constraints, in priority order:

* **off is free** — every recording entry point starts with one branch;
  when ``level == "off"`` the only state change is a host-side
  ``suppressed`` counter increment (no allocation, no lock, no clock
  read, and never a callback into jitted code);
* **spans carry explicit parent ids** — the taxonomy is
  ``run > phase > superstep`` for solves and ``run > batch > query`` for
  serving.  Parentage is tracked per-thread (the micro-batcher closes
  batch spans on its own thread) with an *ambient* fallback: a span
  opened on a thread with an empty stack parents to the innermost open
  ``run``/``phase`` span, so background-thread batches nest under the
  serve phase;
* **deterministic ids** — one process-wide increment under a lock; the
  clock is injectable so tests assert exact timings.

Levels: ``off`` < ``metrics`` (counters/gauges/histograms + structural
spans) < ``trace`` (adds per-superstep / per-query spans) < ``profile``
(adds ``jax.profiler`` + kernel timing hooks, see
:mod:`repro.obs.profiler`).

**Streaming** (DESIGN.md §14.7): :meth:`Telemetry.attach_stream` turns
the end-of-run recorder into a live sink.  Producers call
:meth:`Telemetry.maybe_flush` from their natural pump points (scheduler
tick, observed superstep, replay loop) — one attribute test when no
stream is attached, one clock compare when one is.  Each elapsed
interval appends the not-yet-written events to an append-only segment
file (``events-NNNN.jsonl``, meta line first, rotated every
``segment_records`` lines) and atomically rotates the point-in-time
snapshots (``metrics.jsonl`` / ``summary.json`` / ``metrics.prom``) via
temp-file + ``os.replace``, so a concurrent reader never sees a torn
snapshot.  Flush listeners (the SLO watchdog) run once per tick, after
the write.  The final :meth:`flush` consolidates: it writes the complete
``events.jsonl`` and removes the segments, leaving the same directory
layout a non-streaming run produces.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.obs.metrics import MetricsRegistry

SCHEMA = "repro.obs/v1"
LEVELS = ("off", "metrics", "trace", "profile")

#: span kinds that update the ambient parent for spans opened on other
#: threads (coarse structural spans only — a batch span must not become
#: the ambient parent of an unrelated phase)
_AMBIENT_KINDS = ("run", "phase")


class _NullSpan:
    """Reusable no-op span: the disabled path allocates nothing."""

    __slots__ = ()
    id = None
    parent = None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **attrs) -> None:
        return None


_NULL_SPAN = _NullSpan()


def _atomic_write(path: str, text: str) -> str:
    """Write ``text`` to ``path`` via temp-file + rename (snapshot
    rotation: a concurrent ``--follow`` reader never sees a torn file)."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return path


class _StreamSink:
    """Bookkeeping for one attached streaming directory."""

    __slots__ = (
        "dir",
        "interval_s",
        "segment_records",
        "next_deadline",
        "flushed",
        "seg_index",
        "seg_path",
        "seg_count",
        "ticks",
    )

    def __init__(
        self, dir_path: str, interval_s: float, segment_records: int, now: float
    ):
        self.dir = dir_path
        self.interval_s = interval_s
        self.segment_records = segment_records
        self.next_deadline = now + interval_s
        #: events already written to some segment
        self.flushed = 0
        self.seg_index = 0
        self.seg_path: Optional[str] = None
        self.seg_count = 0
        self.ticks = 0

    def segment_paths(self) -> List[str]:
        if not os.path.isdir(self.dir):
            return []
        return sorted(
            os.path.join(self.dir, n)
            for n in os.listdir(self.dir)
            if n.startswith("events-") and n.endswith(".jsonl")
        )


class Span:
    """One timed, parented region; records itself on ``__exit__``."""

    __slots__ = ("_tel", "id", "parent", "kind", "name", "attrs", "t0", "_prev")

    def __init__(
        self,
        tel: "Telemetry",
        span_id: int,
        parent: Optional[int],
        kind: str,
        name: str,
        attrs: Dict[str, Any],
    ):
        self._tel = tel
        self.id = span_id
        self.parent = parent
        self.kind = kind
        self.name = name
        self.attrs = attrs
        self.t0: Optional[float] = None
        self._prev: Optional[int] = None

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        tel = self._tel
        tel._stack().append(self.id)
        if self.kind in _AMBIENT_KINDS:
            self._prev = tel._ambient
            tel._ambient = self.id
        self.t0 = tel.clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        tel = self._tel
        t1 = tel.clock()
        stack = tel._stack()
        if stack and stack[-1] == self.id:
            stack.pop()
        if self.kind in _AMBIENT_KINDS:
            tel._ambient = self._prev
        record: Dict[str, Any] = {
            "kind": "span",
            "id": self.id,
            "parent": self.parent,
            "span": self.kind,
            "name": self.name,
            "t0": self.t0,
            "dur_s": t1 - (self.t0 if self.t0 is not None else t1),
        }
        if exc_type is not None:
            record["status"] = "error"
            record["error"] = f"{exc_type.__name__}: {exc}"
        if self.attrs:
            record["attrs"] = self.attrs
        tel._append(record)


class Telemetry:
    """The per-run telemetry hub: spans + events + metrics registry."""

    def __init__(
        self,
        level: str = "off",
        *,
        run_id: Optional[str] = None,
        clock=None,
        export: bool = True,
    ):
        if level not in LEVELS:
            raise ValueError(f"obs level must be one of {LEVELS}, got {level!r}")
        self.level = level
        self.run_id = run_id
        self.clock = time.monotonic if clock is None else clock
        #: write OpenMetrics text snapshots (``metrics.prom``) on flush
        self.export = export
        #: disabled-path activity counter — the ONLY state the off level
        #: touches, and the overhead-guard tests' zero-event witness
        self.suppressed = 0
        self._lock = threading.Lock()
        self._next_id = 0
        self._events: List[Dict[str, Any]] = []
        self._local = threading.local()
        self._ambient: Optional[int] = None
        self.metrics = MetricsRegistry(clock=self.clock)
        self._stream: Optional[_StreamSink] = None
        self._flush_lock = threading.Lock()
        self._listeners: List[Callable[["Telemetry"], None]] = []

    # ---------------------------------------------------------------- levels
    @property
    def enabled(self) -> bool:
        return self.level != "off"

    @property
    def trace_enabled(self) -> bool:
        return self.level in ("trace", "profile")

    @property
    def profile_enabled(self) -> bool:
        return self.level == "profile"

    # ----------------------------------------------------------------- spans
    def _stack(self) -> List[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _current_parent(self) -> Optional[int]:
        stack = self._stack()
        if stack:
            return stack[-1]
        return self._ambient

    def _alloc_id(self) -> int:
        with self._lock:
            i = self._next_id
            self._next_id += 1
        return i

    def _append(self, record: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(record)

    def span(self, kind: str, name: Optional[str] = None, **attrs):
        """Open a structural span (recorded at every enabled level)."""
        if not self.enabled:
            self.suppressed += 1
            return _NULL_SPAN
        return Span(
            self, self._alloc_id(), self._current_parent(), kind, name or kind, attrs
        )

    def trace_span(self, kind: str, name: Optional[str] = None, **attrs):
        """A fine-grained span (superstep/batch/query): trace level only."""
        if not self.trace_enabled:
            if not self.enabled:
                self.suppressed += 1
            return _NULL_SPAN
        return self.span(kind, name, **attrs)

    def event(self, name: str, **attrs) -> None:
        """A point event under the current parent (any enabled level)."""
        if not self.enabled:
            self.suppressed += 1
            return
        record: Dict[str, Any] = {
            "kind": "event",
            "id": self._alloc_id(),
            "parent": self._current_parent(),
            "name": name,
            "t": self.clock(),
        }
        if attrs:
            record["attrs"] = attrs
        self._append(record)

    # --------------------------------------------------------------- metrics
    def count(self, name: str, n: int = 1) -> None:
        if not self.enabled:
            self.suppressed += 1
            return
        self.metrics.counter(name).inc(n)

    def gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            self.suppressed += 1
            return
        self.metrics.gauge(name).set(value)

    def observe(self, name: str, value: float) -> None:
        if not self.enabled:
            self.suppressed += 1
            return
        self.metrics.histogram(name).observe(value)

    # ------------------------------------------------------------ inspection
    def events(self) -> List[Dict[str, Any]]:
        """Snapshot of the recorded span/event records (closed spans only)."""
        with self._lock:
            return list(self._events)

    def meta(self) -> Dict[str, Any]:
        return {
            "kind": "meta",
            "schema": SCHEMA,
            "run_id": self.run_id,
            "level": self.level,
        }

    def summary(self) -> Dict[str, Any]:
        from repro.obs.summary import summarize

        return summarize(self.meta(), self.events(), self.metrics.to_lines())

    # ------------------------------------------------------------- streaming
    def attach_stream(
        self,
        dir_path: str,
        *,
        interval_s: float = 1.0,
        segment_records: int = 2048,
    ) -> bool:
        """Enable periodic incremental flush into ``dir_path``.

        After attaching, :meth:`maybe_flush` calls from producer pump
        points write one incremental tick per elapsed ``interval_s``.
        No-op (returns False) when the level is ``off``.
        """
        if not self.enabled:
            return False
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        if segment_records < 1:
            raise ValueError(
                f"segment_records must be >= 1, got {segment_records}"
            )
        os.makedirs(dir_path, exist_ok=True)
        with self._flush_lock:
            self._stream = _StreamSink(
                dir_path, interval_s, segment_records, self.clock()
            )
        return True

    @property
    def streaming(self) -> bool:
        return self._stream is not None

    def add_flush_listener(self, fn: Callable[["Telemetry"], None]) -> None:
        """Register a per-tick callback (runs after each incremental
        write — the SLO watchdog's evaluation hook)."""
        self._listeners.append(fn)

    def remove_flush_listener(self, fn: Callable[["Telemetry"], None]) -> None:
        if fn in self._listeners:
            self._listeners.remove(fn)

    def maybe_flush(self) -> bool:
        """Incremental flush iff a stream is attached and its interval
        elapsed.  The no-stream path is one attribute test — cheap enough
        for per-tick / per-superstep pump points."""
        stream = self._stream
        if stream is None:
            return False
        if self.clock() < stream.next_deadline:
            return False
        return self.flush_tick()

    def flush_tick(self) -> bool:
        """Force one incremental streaming tick (segment append + atomic
        snapshot rotation + listeners).  Returns False when no stream is
        attached or another thread is mid-tick."""
        stream = self._stream
        if stream is None or not self.enabled:
            return False
        if not self._flush_lock.acquire(blocking=False):
            return False  # a concurrent producer is already flushing
        try:
            if self._stream is not stream:  # detached under our feet
                return False
            stream.next_deadline = self.clock() + stream.interval_s
            with self._lock:
                fresh = self._events[stream.flushed :]
                stream.flushed += len(fresh)
            if fresh:
                self._append_segment(stream, fresh)
            self._write_snapshots(stream.dir)
            stream.ticks += 1
        finally:
            self._flush_lock.release()
        # listeners run outside the flush lock: they record events and
        # metrics of their own (picked up by the NEXT tick) and may call
        # back into serve-side knobs
        for fn in list(self._listeners):
            fn(self)
        return True

    def _append_segment(
        self, stream: _StreamSink, records: List[Dict[str, Any]]
    ) -> None:
        """Append ``records`` to the live segment, rotating when full."""
        for record in records:
            if (
                stream.seg_path is None
                or stream.seg_count >= stream.segment_records
            ):
                stream.seg_index += 1
                stream.seg_path = os.path.join(
                    stream.dir, f"events-{stream.seg_index:04d}.jsonl"
                )
                stream.seg_count = 0
                with open(stream.seg_path, "w") as f:
                    f.write(json.dumps(self.meta(), sort_keys=True) + "\n")
            with open(stream.seg_path, "a") as f:
                f.write(json.dumps(record, sort_keys=True) + "\n")
            stream.seg_count += 1

    def _write_snapshots(self, dir_path: str) -> List[str]:
        """Atomically rotate metrics.jsonl / summary.json / metrics.prom."""
        meta = self.meta()
        lines = self.metrics.to_lines()
        paths = [
            _atomic_write(
                os.path.join(dir_path, "metrics.jsonl"),
                "".join(
                    json.dumps(r, sort_keys=True) + "\n"
                    for r in [meta] + lines
                ),
            ),
            _atomic_write(
                os.path.join(dir_path, "summary.json"),
                json.dumps(self.summary(), indent=2, sort_keys=True) + "\n",
            ),
        ]
        if self.export:
            from repro.obs.export import render_openmetrics

            paths.append(
                _atomic_write(
                    os.path.join(dir_path, "metrics.prom"),
                    render_openmetrics(lines, meta=meta),
                )
            )
        return paths

    # ----------------------------------------------------------------- flush
    def flush(self, dir_path: str) -> List[str]:
        """Write the final ``events.jsonl`` / ``metrics.jsonl`` /
        ``summary.json`` (+ ``metrics.prom`` when exporting).

        Each JSONL file leads with a ``meta`` line carrying the schema
        version; returns the written paths ([] when disabled).  When a
        stream was attached to the same directory, its segments are
        consolidated: the complete event log replaces them, so the
        post-run layout matches a non-streaming run.
        """
        if not self.enabled:
            return []
        os.makedirs(dir_path, exist_ok=True)
        meta = self.meta()
        paths = []
        with self._flush_lock:
            stream, self._stream = self._stream, None  # detach: run is over
            events_path = os.path.join(dir_path, "events.jsonl")
            with open(events_path, "w") as f:
                for record in [meta] + self.events():
                    f.write(json.dumps(record, sort_keys=True) + "\n")
            paths.append(events_path)
            paths.extend(self._write_snapshots(dir_path))
            if stream is not None and os.path.realpath(
                stream.dir
            ) == os.path.realpath(dir_path):
                for seg in stream.segment_paths():
                    os.unlink(seg)
        return paths
