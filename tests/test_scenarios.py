"""Scenario & workload subsystem: registry, generators, arrivals,
planted-truth evaluation, and the drugnet adapter contract.

The tentpole invariants (DESIGN.md §12): every registered scenario
produces a well-formed bundle whose planted truth the LP engines can
recover (held-out planted edges rank above true negatives), on networks
well beyond the paper's T=3 — including heterophilic association
structure — and the tri-partite adapter reproduces the historical
``make_drugnet`` RNG streams bit-for-bit.
"""
import numpy as np
import pytest

import repro.scenarios as sc
from repro.data.drugnet import DrugNetSpec, make_drugnet
from repro.eval.cv import cross_validate, kfold_masks, summarize
from repro.scenarios.generators import (
    KPartiteSpec,
    planted_kpartite,
    sizes_for_edges,
)


class TestRegistry:
    def test_at_least_five_scenarios(self):
        names = sc.available_scenarios()
        assert len(names) >= 5
        for expected in (
            "bio_tri",
            "kpartite5",
            "kpartite_heterophilic",
            "powerlaw",
            "streaming",
        ):
            assert expected in names

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError, match="registered:"):
            sc.get_scenario("giraph_net")

    def test_generate_rejects_bad_scale(self):
        with pytest.raises(ValueError, match="scale"):
            sc.generate("bio_tri", scale=0.0)

    def test_bundles_are_well_formed(self):
        for name in sc.available_scenarios():
            scale = 0.02 if name == "powerlaw" else 0.25
            b = sc.generate(name, scale=scale, seed=0)
            net = b.network
            assert b.eval_pair in net.R
            for pair, mask in b.truth.items():
                assert mask.shape == net.R[pair].shape
                # planted positives are present edges
                assert not np.any(mask & (net.R[pair] == 0)), (name, pair)
            d = b.describe()
            assert d["nodes"] == net.num_nodes


class TestDrugnetAdapter:
    """``data/drugnet.py`` is an adapter over the one generator idiom."""

    def test_adapter_matches_generator_exactly(self):
        spec = DrugNetSpec(n_drug=30, n_disease=20, n_target=15, seed=7)
        dn = make_drugnet(spec)
        pk = planted_kpartite(spec.to_kpartite())
        for a, b in zip(dn.network.P, pk.network.P):
            np.testing.assert_array_equal(a, b)
        for k in dn.network.R:
            np.testing.assert_array_equal(dn.network.R[k], pk.network.R[k])
        assert dn.truth is not None
        np.testing.assert_array_equal(dn.truth[(0, 2)], pk.truth[(0, 2)])

    def test_historical_rng_stream_preserved(self):
        """Frozen checksums of the pre-refactor make_drugnet draws: the
        committed bench baselines depend on these exact networks."""
        dn = make_drugnet(
            DrugNetSpec(n_drug=40, n_disease=30, n_target=20, seed=3)
        )
        p_sq = float(sum((p**2).sum() for p in dn.network.P))
        r_sum = float(sum(r.sum() for r in dn.network.R.values()))
        assert repr(p_sq) == "233.22902809050655"
        assert r_sum == 209.0

    def test_bio_tri_scenario_matches_drugnet(self):
        b = sc.generate("bio_tri", scale=1.0, seed=0)
        dn = make_drugnet(DrugNetSpec(seed=0))
        np.testing.assert_array_equal(
            b.network.R[(0, 2)], dn.network.R[(0, 2)]
        )


class TestGenerators:
    def test_heterophilic_truth_is_cross_cluster(self):
        spec = KPartiteSpec(
            sizes=(40, 30, 25), n_clusters=5, heterophily=True, seed=1
        )
        pk = planted_kpartite(spec)
        for (i, j), mask in pk.truth.items():
            same = (
                pk.clusters[i][:, None] == pk.clusters[j][None, :]
            )
            assert not np.any(mask & same), (i, j)
            assert mask.sum() > 0

    def test_homophilic_truth_is_intra_cluster(self):
        pk = planted_kpartite(KPartiteSpec(sizes=(40, 30), n_clusters=5))
        mask = pk.truth[(0, 1)]
        same = pk.clusters[0][:, None] == pk.clusters[1][None, :]
        assert not np.any(mask & ~same)

    def test_powerlaw_degrees_are_skewed(self):
        spec = KPartiteSpec(
            sizes=(400, 300, 200),
            degree="powerlaw",
            sim_density=0.35,
            sim_cross_frac=0.08,
            dense_sim_noise=False,
            seed=0,
        )
        pk = planted_kpartite(spec)
        deg = np.count_nonzero(pk.network.P[0], axis=1)
        # hubs: max degree far above the mean — the cross-cluster support
        # means the tail is not capped at the cluster size n/k
        assert deg.max() > 4 * deg.mean()

    def test_sizes_for_edges_lands_near_target(self):
        spec = KPartiteSpec(sizes=(223, 150, 95))
        sizes = sizes_for_edges(spec, 50_000)
        import dataclasses

        pk = planted_kpartite(dataclasses.replace(spec, sizes=sizes))
        assert 25_000 < pk.network.num_edges < 100_000

    def test_powerlaw_full_scale_targets_million_edges(self):
        # size the full-scale cell WITHOUT generating it (CI-friendly)
        b = sc.generate("powerlaw", scale=0.02, seed=0)
        assert b.network.num_edges > 0.5 * b.meta["target_edges"]
        # the nominal target itself clears 1M with the same headroom
        assert 0.5 * sc.library._POWERLAW_EDGE_TARGET >= 600_000

    def test_non_complete_pair_schema(self):
        b = sc.generate("kpartite5", scale=0.25, seed=0)
        t = b.network.num_types
        assert t == 5
        all_pairs = {(i, j) for i in range(t) for j in range(i + 1, t)}
        assert set(b.network.R) < all_pairs  # strictly sparser schema


class TestArrivals:
    def test_poisson_rate(self):
        rng = np.random.default_rng(0)
        t = sc.arrival_times("poisson", 200.0, 10.0, rng)
        assert np.all(np.diff(t) >= 0) and t[-1] < 10.0
        assert 1500 < len(t) < 2500

    def test_bursty_holds_mean_rate_and_bursts(self):
        rng = np.random.default_rng(0)
        t = sc.arrival_times("bursty", 200.0, 20.0, rng)
        assert np.all(np.diff(t) >= 0)
        assert 0.6 * 4000 < len(t) < 1.4 * 4000
        # burstiness: windowed counts overdispersed vs poisson
        counts, _ = np.histogram(t, bins=40)
        assert counts.var() > 2.0 * counts.mean()

    def test_diurnal_modulates_rate(self):
        rng = np.random.default_rng(0)
        t = sc.arrival_times("diurnal", 400.0, 10.0, rng, depth=0.9)
        first_half = (t < 5.0).sum()  # sin >= 0: the high-rate half
        assert first_half > 0.6 * len(t)

    def test_unknown_process_raises(self):
        rng = np.random.default_rng(0)
        with pytest.raises(ValueError, match="arrival process"):
            sc.arrival_times("constant", 1.0, 1.0, rng)

    def test_build_trace_targets_eval_pair_block(self):
        b = sc.generate("kpartite5", scale=0.25, seed=0)
        trace = sc.build_trace(b, "poisson", rate_qps=100, horizon_s=1.0)
        i, j = b.eval_pair
        lo = b.network.offsets[i]
        hi = lo + b.network.sizes[i]
        assert np.all((trace.entity >= lo) & (trace.entity < hi))
        assert np.all(trace.target_type == j)
        assert np.all(np.diff(trace.t) >= 0)


class TestPlantedTruthEval:
    """Satellite: eval/cv + metrics on a non-tri-partite (T>=4) scenario —
    held-out planted edges must rank above true negatives."""

    @pytest.fixture(scope="class")
    def k5(self):
        return sc.generate("kpartite5", scale=0.3, seed=0)

    def test_recovery_auc_above_09_on_two_backends(self, k5):
        problem = sc.make_recovery_problem(
            k5, holdout_frac=0.15, max_entities=16, seed=0
        )
        F_ref = None
        for backend in ("dense", "sparse"):
            res = sc.solve_recovery(problem, backend)
            m = problem.metrics(res.F)
            assert m["recovery_auc"] > 0.9, backend
            if F_ref is None:
                F_ref = res.F
            else:
                assert np.max(np.abs(res.F - F_ref)) < 5e-3
        assert problem.num_heldout >= 1

    def test_heterophilic_recovery_above_09(self):
        b = sc.generate("kpartite_heterophilic", scale=0.3, seed=0)
        m = sc.recovery_auc(
            b, "dense", holdout_frac=0.15, max_entities=16, seed=0
        )
        assert m["recovery_auc"] > 0.9

    def test_scenario_cross_validate_t5(self, k5):
        results = sc.scenario_cross_validate(k5, backend="dense", k=3)
        assert len(results) == 3
        summary = summarize(results)
        assert summary["auc"] > 0.9
        assert summary["aupr"] > 0.3
        assert 0.5 < summary["best_acc"] <= 1.0

    def test_cv_positives_must_be_present_edges(self, k5):
        pair = k5.eval_pair
        R = k5.network.R[pair]
        bad = np.ones_like(R, dtype=bool)  # claims absent edges as positive
        with pytest.raises(ValueError, match="present"):
            list(kfold_masks(R, k=2, positives=bad))

    def test_cv_folds_hide_only_planted_entries(self, k5):
        pair = k5.eval_pair
        R = k5.network.R[pair]
        planted = k5.truth[pair] & (R > 0)
        union = np.zeros_like(planted)
        for mask in kfold_masks(R, k=3, positives=planted):
            assert not np.any(mask & ~planted)
            union |= mask
        np.testing.assert_array_equal(union, planted)

    def test_cv_scores_noise_edges_nowhere(self, k5):
        """A noise edge (present, not planted) is neither hidden nor a
        negative: spiking its score must not change any fold metric."""
        pair = k5.eval_pair
        R = k5.network.R[pair]
        planted = k5.truth[pair] & (R > 0)
        noise = (R > 0) & ~planted
        if not noise.any():
            pytest.skip("no noise edges drawn at this scale/seed")
        base = np.random.default_rng(0).random(R.shape)
        spiked = base.copy()
        spiked[noise] = 1e9

        res_a = cross_validate(
            k5.network, pair, lambda net: base, k=2, positives=planted
        )
        res_b = cross_validate(
            k5.network, pair, lambda net: spiked, k=2, positives=planted
        )
        for a, b in zip(res_a, res_b):
            assert a.metrics == b.metrics


class TestStreamingScenario:
    def test_deltas_readd_heldout_edges(self):
        b = sc.generate("streaming", scale=1.0, seed=0)
        pair = b.eval_pair
        arriving = b.meta["arriving_truth"]
        assert int(arriving.sum()) == b.meta["heldout_edges"]
        # t=0 network lacks the held-out edges; truth agrees
        assert not np.any((b.network.R[pair] > 0) & arriving)
        assert not np.any(b.truth[pair] & arriving)
        net = b.network
        for td in b.deltas:
            net = net.apply_delta(td.delta)
        R_after = net.R[pair]
        rows, cols = np.nonzero(arriving)
        assert np.all(R_after[rows, cols] > 0)
        # delta times are ordered and inside the trace horizon
        ts = [td.t for td in b.deltas]
        assert ts == sorted(ts)
        assert b.trace is not None and ts[-1] < b.trace.horizon_s

    def test_trace_replay_through_serve_engine(self):
        """End-to-end: the streaming workload drives the serve stack —
        queries at trace pace (compressed), deltas interleaved."""
        from repro.core import LPConfig
        from repro.serve import LPServeEngine, QuerySpec, ServeConfig

        b = sc.generate(
            "streaming", scale=0.5, seed=0, rate_qps=30.0, horizon_s=1.0,
            n_deltas=2,
        )
        engine = LPServeEngine(
            b.network,
            ServeConfig(
                lp=LPConfig(alg="dhlp2", sigma=1e-3, seed_mode="fixed")
            ),
        )
        trace = b.trace
        di = 0
        results = []
        for i in range(min(len(trace), 12)):
            while di < len(b.deltas) and b.deltas[di].t <= float(trace.t[i]):
                engine.apply_delta(b.deltas[di].delta)
                di += 1
            results.append(
                engine.query(
                    QuerySpec(
                        entity=int(trace.entity[i]),
                        target_type=int(trace.target_type[i]),
                        top_k=5,
                    )
                )
            )
        assert len(results) == min(len(trace), 12)
        assert di >= 1  # at least one delta landed mid-trace
        versions = {r.version for r in results}
        assert len(versions) >= 2  # answers span network versions
