"""Fused-superstep kernel, autotune cache, and plan-vs-legacy agreement."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.solver import LPConfig
from repro.engine import autotune, make_engine
from repro.kernels.segment_reduce import (
    csr_round_residual,
    csr_round_residual_ref,
)


RNG = np.random.default_rng(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=5e-4
    )


def _drugnet_norm():
    from repro.data.drugnet import DrugNetSpec, make_drugnet

    dn = make_drugnet(
        DrugNetSpec(n_drug=48, n_disease=32, n_target=24, n_clusters=6)
    )
    return dn.network.normalize()


class TestCSRRoundResidual:
    """Pallas fused superstep (interpret=True) vs the jnp oracle."""

    @pytest.mark.parametrize(
        "m,n,d,s",
        [
            (128, 128, 8, 32),   # aligned
            (200, 150, 11, 37),  # padded tails on every axis
            (64, 300, 33, 16),   # degree > one bd slab
        ],
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, m, n, d, s, dtype):
        nbr = jnp.asarray(RNG.integers(0, n, (m, d)).astype(np.int32))
        wgt = jnp.asarray(
            (RNG.random((m, d)) * (RNG.random((m, d)) < 0.7)), dtype
        )
        F = jnp.asarray(RNG.random((n, s)), dtype)
        base = jnp.asarray(RNG.random((m, s)), jnp.float32)
        prev = jnp.asarray(RNG.random((m, s)), jnp.float32)
        got, gd = csr_round_residual(
            nbr, wgt, F, base, prev, c=0.25, bn=64, bs=32, bd=8,
            interpret=True,
        )
        want, wd = csr_round_residual_ref(nbr, wgt, F, base, prev, 0.25)
        got = np.asarray(got, np.float32)[:m, :s]
        np.testing.assert_allclose(
            got, np.asarray(want, np.float32), **_tol(dtype)
        )
        # kernel delta is a per-row-block partial; reduce then compare
        gd = np.asarray(jnp.max(gd, axis=0))[:s]
        np.testing.assert_allclose(
            gd, np.asarray(wd)[0], **_tol(dtype)
        )

    def test_residual_zero_at_fixed_point(self):
        """delta == 0 exactly when prev equals the kernel's own output."""
        m, n, d, s = 128, 128, 8, 32
        nbr = jnp.asarray(RNG.integers(0, n, (m, d)).astype(np.int32))
        wgt = jnp.asarray(RNG.random((m, d)), jnp.float32)
        F = jnp.asarray(RNG.random((n, s)), jnp.float32)
        base = jnp.asarray(RNG.random((m, s)), jnp.float32)
        out, _ = csr_round_residual(
            nbr, wgt, F, base, base, c=0.3, bn=64, bs=32, bd=8,
            interpret=True,
        )
        _, delta = csr_round_residual(
            nbr, wgt, F, base, out, c=0.3, bn=64, bs=32, bd=8,
            interpret=True,
        )
        assert float(jnp.max(delta)) == 0.0


class TestAutotuneCache:
    @pytest.fixture(autouse=True)
    def _fresh_memo(self):
        autotune.clear_memo()
        yield
        autotune.clear_memo()

    def test_miss_sweep_hit_and_persistence(self, tmp_path):
        norm = _drugnet_norm()
        n, nnz = norm.num_nodes, autotune.network_nnz(norm)
        assert autotune.lookup(n, nnz, cache_dir=tmp_path) is None

        params, hit = autotune.ensure_tuned(
            norm, repeats=1, sweep_panels=False, cache_dir=tmp_path
        )
        assert not hit
        assert (params.block_rows, params.width_mult) in autotune.LAYOUT_GRID
        assert autotune.cache_path(tmp_path).exists()

        again, hit2 = autotune.ensure_tuned(
            norm, repeats=1, sweep_panels=False, cache_dir=tmp_path
        )
        assert hit2 and again == params

        # memo dropped -> the persisted file alone must answer the lookup
        autotune.clear_memo()
        assert autotune.lookup(n, nnz, cache_dir=tmp_path) == params

    def test_corrupt_cache_is_cold(self, tmp_path):
        norm = _drugnet_norm()
        n, nnz = norm.num_nodes, autotune.network_nnz(norm)
        autotune.save(n, nnz, autotune.TunedParams(), cache_dir=tmp_path)
        autotune.cache_path(tmp_path).write_text("not json{")
        autotune.clear_memo()
        assert autotune.lookup(n, nnz, cache_dir=tmp_path) is None

    def test_shape_class_buckets_nearby_sizes(self):
        assert autotune.shape_class(1000, 8000) == autotune.shape_class(
            1100, 8800
        )
        assert autotune.shape_class(1000, 8000) != autotune.shape_class(
            1000, 64000
        )

    def test_engine_consults_cache(self, tmp_path, monkeypatch):
        monkeypatch.setattr(autotune, "DEFAULT_CACHE_DIR", tmp_path)
        norm = _drugnet_norm()
        tuned = autotune.TunedParams(block_rows=32, width_mult=4)
        autotune.save(
            norm.num_nodes, autotune.network_nnz(norm), tuned
        )
        eng = make_engine(
            "sparse", LPConfig(alg="dhlp2", seed_mode="fixed", autotune=True)
        )
        op = eng.prepare(norm)
        assert op.payload.layout == (32, 4)

    def test_autotune_off_uses_defaults(self, tmp_path, monkeypatch):
        monkeypatch.setattr(autotune, "DEFAULT_CACHE_DIR", tmp_path)
        norm = _drugnet_norm()
        autotune.save(
            norm.num_nodes,
            autotune.network_nnz(norm),
            autotune.TunedParams(block_rows=32, width_mult=4),
        )
        eng = make_engine(
            "sparse", LPConfig(alg="dhlp2", seed_mode="fixed", autotune=False)
        )
        op = eng.prepare(norm)
        assert op.payload.layout == (
            autotune.DEFAULT_PARAMS.block_rows,
            autotune.DEFAULT_PARAMS.width_mult,
        )


class TestFusedSuperstepEngine:
    @pytest.mark.parametrize("alg", ["dhlp1", "dhlp2"])
    def test_fused_matches_legacy(self, alg):
        norm = _drugnet_norm()
        Y = np.eye(norm.num_nodes, dtype=np.float32)[:, :12]
        cfg = LPConfig(alg=alg, sigma=1e-4, seed_mode="fixed", autotune=False)
        ref = make_engine("sparse", cfg, fused_superstep=False).run(
            norm, seeds=Y
        )
        got = make_engine("sparse", cfg).run(norm, seeds=Y)
        np.testing.assert_allclose(got.F, ref.F, rtol=1e-5, atol=1e-6)
        assert got.outer_iters == ref.outer_iters

    def test_bf16_storage_agrees_within_tolerance(self):
        norm = _drugnet_norm()
        Y = np.eye(norm.num_nodes, dtype=np.float32)[:, :12]
        f32 = make_engine(
            "sparse",
            LPConfig(alg="dhlp2", sigma=1e-4, seed_mode="fixed",
                     autotune=False),
        ).run(norm, seeds=Y)
        bf16 = make_engine(
            "sparse",
            LPConfig(alg="dhlp2", sigma=1e-4, seed_mode="fixed",
                     autotune=False, storage_dtype="bf16"),
        ).run(norm, seeds=Y)
        assert float(np.max(np.abs(bf16.F - f32.F))) < 5e-3

    def test_tightened_plan_never_pads_more_than_block_layout(self):
        from repro.core.blocked_csr import blocked_csr_from_network
        from repro.engine.sparse import _tighten_buckets

        norm = _drugnet_norm()
        bcsr = blocked_csr_from_network(
            norm, alpha=0.01, hetero_scale=0.5, block_rows=64, width_mult=8
        )
        blocks = bcsr.width_buckets()
        block_padded = sum(b.nbr.size for b in blocks)
        tight = _tighten_buckets(blocks)
        tight_padded = sum(nbr.size for _, nbr, _ in tight)
        assert tight_padded <= block_padded
        # every row appears exactly once in the tightened order
        rows = np.sort(np.concatenate([r for r, _, _ in tight]))
        np.testing.assert_array_equal(
            rows, np.sort(np.concatenate([b.rows for b in blocks]))
        )

    def test_round_with_residual_matches_legacy(self):
        norm = _drugnet_norm()
        Y = np.eye(norm.num_nodes, dtype=np.float32)[:, :8]
        cfg = LPConfig(
            alg="dhlp2", sigma=1e-4, seed_mode="fixed", autotune=False
        )
        fused = make_engine("sparse", cfg)
        legacy = make_engine("sparse", cfg, fused_superstep=False)
        out_f, d_f = fused.round_with_residual(fused.prepare(norm), Y, Y)
        out_l, d_l = legacy.round_with_residual(legacy.prepare(norm), Y, Y)
        np.testing.assert_allclose(out_f, out_l, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(d_f, d_l, rtol=1e-5, atol=1e-6)
