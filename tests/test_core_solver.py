"""Unit tests for the DHLP solvers (dense engine)."""
import numpy as np
import pytest

from repro.core import (
    HeteroLP,
    HeteroNetwork,
    LPConfig,
    fixed_seed_solution,
    dhlp1_inner_solution,
)


def rand_net(seed=0, n=(12, 9, 7), density=0.4):
    rng = np.random.default_rng(seed)
    P = []
    for ni in n:
        a = (rng.random((ni, ni)) < density) * rng.random((ni, ni))
        np.fill_diagonal(a, 0)
        P.append((a + a.T) / 2)
    R = {
        (i, j): (rng.random((n[i], n[j])) < density).astype(float)
        for (i, j) in [(0, 1), (0, 2), (1, 2)]
    }
    return HeteroNetwork(P=P, R=R)


@pytest.fixture(scope="module")
def net():
    return rand_net()


@pytest.fixture(scope="module")
def closed_form(net):
    norm = net.normalize()
    H, M = norm.assemble_dense()
    scale = LPConfig().resolved_hetero_scale(norm.num_types)
    return fixed_seed_solution(H * scale, M, np.eye(norm.num_nodes), 0.5)


class TestFixedPoint:
    def test_dhlp1_matches_closed_form(self, net, closed_form):
        res = HeteroLP(
            LPConfig(alg="dhlp1", sigma=1e-7, max_iter=500, max_inner=500)
        ).run(net)
        np.testing.assert_allclose(res.F, closed_form, atol=5e-6)
        assert res.converged

    def test_dhlp2_fixed_matches_closed_form(self, net, closed_form):
        res = HeteroLP(
            LPConfig(alg="dhlp2", seed_mode="fixed", sigma=1e-7, max_iter=5000)
        ).run(net)
        np.testing.assert_allclose(res.F, closed_form, atol=5e-6)

    def test_dhlp1_and_dhlp2_share_fixed_point(self, net):
        r1 = HeteroLP(
            LPConfig(alg="dhlp1", sigma=1e-7, max_iter=500, max_inner=500)
        ).run(net)
        r2 = HeteroLP(
            LPConfig(alg="dhlp2", seed_mode="fixed", sigma=1e-7, max_iter=5000)
        ).run(net)
        np.testing.assert_allclose(r1.F, r2.F, atol=1e-5)

    def test_inner_solution_closed_form(self, net):
        norm = net.normalize()
        S = norm.S_homo[0]
        rng = np.random.default_rng(3)
        yp = rng.random((S.shape[0], 4))
        f = dhlp1_inner_solution(S, yp, 0.5)
        # fixed point of f = 0.5*yp + 0.5*S f
        np.testing.assert_allclose(f, 0.5 * yp + 0.5 * (S @ f), atol=1e-10)


class TestModes:
    def test_fused_equals_unfused(self, net):
        kw = dict(alg="dhlp2", seed_mode="fixed", sigma=1e-7, max_iter=5000)
        rf = HeteroLP(LPConfig(fused=True, **kw)).run(net)
        ru = HeteroLP(LPConfig(fused=False, **kw)).run(net)
        np.testing.assert_allclose(rf.F, ru.F, atol=2e-6)

    def test_sequential_equals_batched(self, net):
        kw = dict(alg="dhlp2", seed_mode="fixed", sigma=1e-7)
        Y = np.eye(net.num_nodes)[:, :4]
        rs = HeteroLP(LPConfig(mode="sequential", **kw)).run(net, seeds=Y)
        rb = HeteroLP(LPConfig(mode="batched", **kw)).run(net, seeds=Y)
        np.testing.assert_allclose(rs.F, rb.F, atol=2e-6)

    def test_seed_chunking(self, net):
        kw = dict(alg="dhlp2", seed_mode="fixed", sigma=1e-7)
        rc = HeteroLP(LPConfig(seed_chunk=5, **kw)).run(net)
        rb = HeteroLP(LPConfig(**kw)).run(net)
        np.testing.assert_allclose(rc.F, rb.F, atol=2e-6)

    def test_drift_mode_converges_with_paper_sigma(self, net):
        res = HeteroLP(LPConfig(alg="dhlp2", sigma=1e-3)).run(net)
        assert res.converged
        assert np.isfinite(res.F).all()

    def test_literal_hetero_scale_divergence_is_reported(self, net):
        # uniform-α over all hetero neighbors (paper-literal) can diverge
        # with T=3 types; the solver must NOT report converged, and the
        # NaN/∞ columns must not be masked as converged.
        res = HeteroLP(
            LPConfig(alg="dhlp2", sigma=1e-4, hetero_scale=1.0, max_iter=200)
        ).run(net)
        assert not res.converged

    def test_per_column_iters_reported(self, net):
        res = HeteroLP(LPConfig(alg="dhlp2", sigma=1e-3)).run(net)
        assert res.per_column_iters is not None
        assert res.per_column_iters.shape == (net.num_nodes,)
        assert (res.per_column_iters <= res.outer_iters).all()
        assert res.supersteps >= res.outer_iters


class TestKernelPath:
    def test_pallas_kernel_in_loop_identical(self):
        """use_kernel routes the fused round through lp_blockspmm
        (interpret mode here); results must match the jnp path exactly."""
        net2 = rand_net(seed=9, n=(60, 45, 35), density=0.2)
        rj = HeteroLP(
            LPConfig(alg="dhlp2", seed_mode="fixed", sigma=1e-5)
        ).run(net2)
        rk = HeteroLP(
            LPConfig(alg="dhlp2", seed_mode="fixed", sigma=1e-5,
                     use_kernel=True)
        ).run(net2)
        np.testing.assert_array_equal(rk.F, rj.F)
        assert rk.outer_iters == rj.outer_iters


class TestHomogeneousSpecialCase:
    def test_single_type_is_classic_lp(self):
        """T=1 (no hetero blocks) reduces to Zhou et al. label propagation
        and classifies a planted-partition graph well above chance."""
        from repro.data.graphs import planted_partition_graph

        data = planted_partition_graph(200, 1200, 4, 8, homophily=0.85,
                                       train_frac=0.15, seed=3)
        net1 = HeteroNetwork(P=[data.edges.to_dense()], R={})
        y = np.zeros((200, 4))
        for c in range(4):
            y[(data.labels == c) & data.train_mask, c] = 1.0
        res = HeteroLP(
            LPConfig(alg="dhlp2", seed_mode="fixed", alpha=0.9, sigma=1e-4)
        ).run(net1, seeds=y)
        pred = np.argmax(res.F, axis=1)
        test = ~data.train_mask
        acc = (pred[test] == data.labels[test]).mean()
        assert acc > 0.6


class TestSigmaBehaviour:
    def test_smaller_sigma_more_iterations(self, net):
        """Paper Table 7: runtime (iterations) grows as σ shrinks."""
        iters = []
        for sigma in [0.2, 0.05, 0.01, 0.002]:
            res = HeteroLP(
                LPConfig(alg="dhlp2", seed_mode="fixed", sigma=sigma)
            ).run(net)
            iters.append(res.outer_iters)
        assert iters == sorted(iters)

    def test_alpha_bounds(self, net):
        for alpha in [0.1, 0.9]:
            res = HeteroLP(
                LPConfig(alg="dhlp2", seed_mode="fixed", alpha=alpha,
                         sigma=1e-6, max_iter=20000)
            ).run(net)
            assert res.converged
            assert np.isfinite(res.F).all()


class TestTwoTypes:
    def test_bipartite_network(self):
        """T=2 (e.g. drug-target only) must work; hetero scale is 1."""
        rng = np.random.default_rng(7)
        P = []
        for ni in (10, 8):
            a = rng.random((ni, ni)) * (rng.random((ni, ni)) < 0.5)
            np.fill_diagonal(a, 0)
            P.append((a + a.T) / 2)
        net2 = HeteroNetwork(P=P, R={(0, 1): (rng.random((10, 8)) < 0.4).astype(float)})
        norm = net2.normalize()
        H, M = norm.assemble_dense()
        want = fixed_seed_solution(H, M, np.eye(18), 0.5)
        res = HeteroLP(
            LPConfig(alg="dhlp2", seed_mode="fixed", sigma=1e-7, max_iter=5000)
        ).run(net2)
        np.testing.assert_allclose(res.F, want, atol=5e-6)
