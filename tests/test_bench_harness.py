"""Tier-1 tests for the repro.bench harness: timer statistics with an
injected clock, BENCH schema round-trip/validation, backend-matrix
expansion, report writing, and the compare gate's pass/regress/missing
paths.  Pure host-side logic — no solver runs, no device work."""
from __future__ import annotations

import json

import pytest

from repro.bench import (
    SCHEMA_VERSION,
    BenchRecord,
    BenchReport,
    SchemaError,
    record_key,
    register_suite,
    stats_from_samples,
    time_callable,
    validate_record,
    validate_report,
)
from repro.bench.compare import compare_reports
from repro.bench.compare import main as compare_main
from repro.bench.matrix import BackendSpec, expand_matrix, lp_backend_specs
from repro.bench.registry import run_suites
from repro.bench.report import legacy_csv_line, load_report
from repro.bench.timing import derived_throughput


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------
class FakeClock:
    """Scripted clock: each call returns the next scheduled instant."""

    def __init__(self, deltas):
        self.t = 0.0
        self.deltas = list(deltas)
        self.calls = 0

    def __call__(self):
        v = self.t
        self.calls += 1
        if self.deltas:
            self.t += self.deltas.pop(0)
        return v


def test_time_callable_deterministic_with_injected_clock():
    # 5 measured reps with durations 1,2,3,4,5 (clock advances once per
    # call: start->stop advance = duration, stop->next-start advance = 0)
    deltas = []
    for d in (1.0, 2.0, 3.0, 4.0, 5.0):
        deltas += [d, 0.0]
    clock = FakeClock(deltas)
    calls = {"n": 0}

    def fn():
        calls["n"] += 1

    stats = time_callable(fn, warmup=2, repeats=5, clock=clock, sync=lambda v: v)
    assert calls["n"] == 7  # 2 warmup + 5 measured
    assert stats.repeats == 5 and stats.warmup == 2
    assert stats.median_s == 3.0
    assert stats.min_s == 1.0 and stats.max_s == 5.0
    assert stats.mean_s == 3.0
    assert stats.p10_s == pytest.approx(1.4)
    assert stats.p90_s == pytest.approx(4.6)


def test_time_callable_rejects_zero_repeats():
    with pytest.raises(ValueError):
        time_callable(lambda: None, repeats=0)


def test_stats_from_samples_single_sample_and_roundtrip():
    s = stats_from_samples([0.25])
    assert s.median_s == s.min_s == s.max_s == 0.25
    assert type(s).from_dict(s.to_dict()) == s
    with pytest.raises(ValueError):
        stats_from_samples([])


def test_derived_throughput_uses_median_and_supersteps():
    s = stats_from_samples([2.0])
    d = derived_throughput(s, edges=100, supersteps=10, queries=4, flops=2e9)
    assert d["edges_per_s"] == pytest.approx(100 * 10 / 2.0)
    assert d["supersteps_per_s"] == pytest.approx(5.0)
    assert d["qps"] == pytest.approx(2.0)
    assert d["gflops"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------
def _record(
    name="case",
    suite="suite",
    backend="dense",
    median=1.0,
    derived=None,
    strict=(),
    error=None,
):
    stats = {}
    if error is None:
        stats = stats_from_samples([median]).to_dict()
    return {
        "suite": suite,
        "name": name,
        "backend": backend,
        "params": {"n": 8},
        "stats": stats,
        "derived": dict(derived or {}),
        "strict": list(strict),
        **({"error": error} if error is not None else {}),
    }


def _report(records, env=None, label="ci"):
    environment = {
        "platform": "linux",
        "machine": "x86_64",
        "backend": "cpu",
        "device_kind": "cpu",
        "device_count": 1,
    }
    environment.update(env or {})
    return {
        "schema_version": SCHEMA_VERSION,
        "label": label,
        "created_unix": 1_000.0,
        "environment": environment,
        "records": list(records),
    }


def test_record_roundtrip_and_key():
    rec = BenchRecord(
        suite="lp_matrix",
        name="dhlp2_dense",
        backend="dense",
        params={"alg": "dhlp2"},
        stats=stats_from_samples([0.5]).to_dict(),
        derived={"outer_iters": 13.0},
        strict=["outer_iters"],
    )
    d = rec.to_dict()
    validate_record(d)
    assert "error" not in d
    assert BenchRecord.from_dict(d) == rec
    assert record_key(rec) == "lp_matrix/dhlp2_dense@dense"
    assert record_key(d) == record_key(rec)


@pytest.mark.parametrize(
    "mutate",
    [
        lambda d: d.pop("suite"),
        lambda d: d.__setitem__("name", ""),
        lambda d: d["stats"].pop("median_s"),
        lambda d: d["stats"].__setitem__("repeats", 0),
        lambda d: d["stats"].__setitem__("median_s", 99.0),  # > max_s
        lambda d: d.__setitem__("strict", ["not_in_derived"]),
        lambda d: d.__setitem__("stats", {}),  # no stats and no error
    ],
)
def test_record_validation_rejects(mutate):
    d = _record(derived={"x": 1.0})
    mutate(d)
    with pytest.raises(SchemaError):
        validate_record(d)


def test_error_record_is_valid_without_stats():
    d = _record(error="boom")
    d["stats"] = {}
    validate_record(d)
    assert legacy_csv_line(d).endswith("error=boom")


def test_report_validation_duplicate_keys_and_version():
    doc = _report([_record(), _record()])
    with pytest.raises(SchemaError, match="duplicate"):
        validate_report(doc)
    doc = _report([_record()])
    doc["schema_version"] = 999
    with pytest.raises(SchemaError, match="schema_version"):
        validate_report(doc)
    validate_report(_report([_record()]))


# ---------------------------------------------------------------------------
# report writing
# ---------------------------------------------------------------------------
def test_bench_report_write_and_load(tmp_path):
    report = BenchReport("ci", environment=_report([])["environment"])
    report.add(BenchRecord.from_dict(_record(name="a", derived={"m": 1.0})))
    report.add(BenchRecord.from_dict(_record(name="b")))
    with pytest.raises(ValueError, match="duplicate"):
        report.add(BenchRecord.from_dict(_record(name="a")))
    paths = report.write(str(tmp_path))
    assert paths[0] == str(tmp_path / "BENCH_ci.json")
    assert (tmp_path / "results").is_dir()
    doc = load_report(paths[0])
    assert doc["label"] == "ci"
    assert len(doc["records"]) == 2
    assert report.suites == ["suite"]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_runs_suites_and_propagates_failures():
    @register_suite("_test_ok", description="test-only")
    def ok(fast):
        return [BenchRecord.from_dict(_record(suite="_test_ok", name="x"))]

    @register_suite("_test_boom", description="test-only")
    def boom(fast):
        raise RuntimeError("kaput")

    report = BenchReport("t", environment=_report([])["environment"])
    failures = run_suites(report, only=["_test_ok", "_test_boom"], fast=True)
    assert failures == 1
    assert [r.name for r in report.records] == ["x"]
    assert report.errors and "kaput" in report.errors[0]["error"]
    # error records inside a suite also count as failures
    @register_suite("_test_errrec", description="test-only")
    def errrec(fast):
        return [
            BenchRecord.from_dict(
                _record(suite="_test_errrec", name="y", error="bad")
            )
        ]

    report2 = BenchReport("t2", environment=_report([])["environment"])
    assert run_suites(report2, only=["_test_errrec"], fast=True) == 1


def test_registry_duplicate_record_key_fails_suite_not_driver():
    @register_suite("_test_dup", description="test-only")
    def dup(fast):
        rec = _record(suite="_test_dup", name="same")
        return [BenchRecord.from_dict(rec), BenchRecord.from_dict(rec)]

    @register_suite("_test_after_dup", description="test-only")
    def after(fast):
        return [BenchRecord.from_dict(_record(suite="_test_after_dup"))]

    report = BenchReport("t3", environment=_report([])["environment"])
    failures = run_suites(report, only=["_test_dup", "_test_after_dup"], fast=True)
    # the duplicate fails its suite but the driver moves on
    assert failures == 1
    assert "duplicate" in report.errors[0]["error"]
    assert [r.suite for r in report.records][-1] == "_test_after_dup"


# ---------------------------------------------------------------------------
# backend matrix
# ---------------------------------------------------------------------------
def test_matrix_expansion_filters_by_device_count():
    backends = lp_backend_specs()  # fast pass: registry + sharded 1/2/4
    params = [{"alg": "dhlp1"}, {"alg": "dhlp2"}]
    cells, skipped = expand_matrix(backends, params, device_count=2)
    names = {b.name for b, _ in cells}
    assert names == {
        "dense", "kernel", "sparse", "sharded1", "sharded2",
    }
    assert [b.name for b in skipped] == ["sharded4"]
    assert len(cells) == 5 * 2
    # params are copied per cell, not shared
    cells[0][1]["alg"] = "mutated"
    assert params[0]["alg"] == "dhlp1"
    cells4, skipped4 = expand_matrix(backends, params, device_count=4)
    assert not skipped4 and len(cells4) == 6 * 2
    assert BackendSpec("sharded8", "sharded", devices=8).available(4) is False


def test_matrix_specs_iterate_registry():
    """Every registered (non-sharded) backend is a matrix column, and the
    full pass grows the sharded fan-out to 8."""
    from repro.engine import available_backends

    fast = {s.name for s in lp_backend_specs()}
    for name in available_backends():
        if name != "sharded":
            assert name in fast
    full = {s.name for s in lp_backend_specs(full=True)}
    assert "sharded8" in full and "sharded8" not in fast


# ---------------------------------------------------------------------------
# compare
# ---------------------------------------------------------------------------
def test_compare_pass_improvement_and_new_records():
    base = _report([_record(name="a", median=1.0)])
    cand = _report(
        [_record(name="a", median=0.5), _record(name="extra", median=1.0)]
    )
    res = compare_reports(base, cand, tolerance=0.30)
    assert res.ok and res.compared == 1
    assert [f.kind for f in res.improvements] == ["timing"]
    assert res.new_keys == ["suite/extra@dense"]


def test_compare_timing_regression_gates_only_on_env_match():
    base = _report([_record(name="a", median=1.0)])
    cand = _report([_record(name="a", median=1.5)])
    res = compare_reports(base, cand, tolerance=0.30)
    assert not res.ok and res.regressions[0].kind == "timing"
    # same regression on different hardware: warning, not failure
    cand_other = _report([_record(name="a", median=1.5)], env={"machine": "arm64"})
    res2 = compare_reports(base, cand_other, tolerance=0.30)
    assert res2.ok and not res2.env_match
    assert [f.kind for f in res2.warnings] == ["timing"]
    # host_class alone also breaks the fingerprint (CPU platform/machine
    # are identical across most linux x86_64 hosts)
    cand_host = _report([_record(name="a", median=1.5)], env={"host_class": "ci"})
    res_host = compare_reports(base, cand_host, tolerance=0.30)
    assert res_host.ok and not res_host.env_match
    # within tolerance passes
    res3 = compare_reports(
        base, _report([_record(name="a", median=1.2)]), tolerance=0.30
    )
    assert res3.ok


def test_compare_strict_metrics_hard_fail_even_on_env_mismatch():
    base = _report(
        [_record(name="a", derived={"outer_iters": 13.0}, strict=["outer_iters"])]
    )
    cand = _report(
        [_record(name="a", derived={"outer_iters": 40.0}, strict=["outer_iters"])],
        env={"machine": "arm64"},
    )
    res = compare_reports(base, cand)
    assert not res.ok
    assert res.regressions[0].kind == "strict"
    assert res.regressions[0].metric == "outer_iters"


def test_compare_missing_and_error_records_fail():
    base = _report([_record(name="a"), _record(name="b")])
    cand = _report([_record(name="a", error="exploded")])
    res = compare_reports(base, cand)
    kinds = sorted(f.kind for f in res.regressions)
    assert kinds == ["error", "missing"]


def test_compare_cli_paths(tmp_path, capsys):
    base_path = tmp_path / "baseline.json"
    cand_path = tmp_path / "BENCH_ci.json"
    cand_path.write_text(json.dumps(_report([_record(name="a", median=1.0)])))

    # missing baseline: exit 2, or 0 with --allow-missing
    argv = ["--baseline", str(base_path), "--candidate", str(cand_path)]
    assert compare_main(argv) == 2
    assert compare_main(argv + ["--allow-missing"]) == 0

    # pass path + json summary
    base_path.write_text(json.dumps(_report([_record(name="a", median=1.0)])))
    out_json = tmp_path / "summary.json"
    assert compare_main(argv + ["--json", str(out_json)]) == 0
    assert json.loads(out_json.read_text())["ok"] is True

    # regression path
    cand_path.write_text(json.dumps(_report([_record(name="a", median=9.0)])))
    assert compare_main(argv) == 1
    assert "REGRESSIONS" in capsys.readouterr().out

    # corrupt baseline: unreadable (2), never waived by --allow-missing
    base_path.write_text("{not json")
    assert compare_main(argv) == 2
    assert compare_main(argv + ["--allow-missing"]) == 2
    # schema-invalid candidate: also unreadable
    base_path.write_text(json.dumps(_report([_record(name="a")])))
    cand_path.write_text(json.dumps({"schema_version": 999}))
    assert compare_main(argv) == 2
