"""Pipelined serving tier tests (DESIGN.md §9): priority-class
fairness and admission control, clean shutdown with batches in flight,
sharded-cache equivalence, and early-exit numerical agreement."""
import queue
import threading
import time

import numpy as np
import pytest

from repro.core import LPConfig
from repro.serve import (
    ColumnCache,
    LPServeEngine,
    MicroBatcher,
    PRIORITY_CLASSES,
    QuerySpec,
    ServeConfig,
    ShardedColumnCache,
)
from repro.serve.types import QueryResult

from test_serve import SIGMA, serve_cfg, small_net

# Strict agreement gate, same tolerance as bench.matrix.agree_dense.
AGREEMENT_TOL = 5e-3


def _fake_result(spec: QuerySpec) -> QueryResult:
    return QueryResult(
        spec=spec,
        candidates=np.arange(spec.top_k),
        scores=np.zeros(spec.top_k),
        target_offset=0,
        version=0,
        source="cold",
        rounds=1,
    )


def _fake_solve(specs):
    return [_fake_result(s) for s in specs]


def _spec(entity, priority="interactive"):
    return QuerySpec(entity=entity, target_type=2, top_k=3, priority=priority)


class TestPriorityFairness:
    def test_wrr_drain_shares_one_tick(self):
        """One tick over a mixed backlog follows the 8/4/2 drain weights:
        interactive drains fully, no class is starved."""
        mb = MicroBatcher(_fake_solve, max_batch=16, max_wait_s=1e-4)
        futs = {}
        futs["bulk"] = [mb.submit(_spec(i, "bulk")) for i in range(30)]
        futs["refresh"] = [mb.submit(_spec(i, "refresh")) for i in range(5)]
        futs["interactive"] = [
            mb.submit(_spec(i, "interactive")) for i in range(3)
        ]
        served = mb.run_once(wait=False)
        assert served == 16
        done = {c: sum(f.done() for f in fs) for c, fs in futs.items()}
        # every non-empty class got a slot; interactive fully drained
        assert done["interactive"] == 3
        assert done["refresh"] == 5
        assert done["bulk"] == 16 - 3 - 5
        mb.drain()
        assert all(f.done() for fs in futs.values() for f in fs)

    def test_bulk_not_starved_by_interactive_backlog(self):
        """Even with interactive demand exceeding max_batch every tick,
        bulk requests get at least one slot per tick."""
        mb = MicroBatcher(_fake_solve, max_batch=8, max_wait_s=1e-4)
        bulk = [mb.submit(_spec(i, "bulk")) for i in range(3)]
        for i in range(40):
            mb.submit(_spec(i, "interactive"))
        ticks = 0
        while not all(f.done() for f in bulk):
            assert mb.run_once(wait=False) > 0
            ticks += 1
            assert ticks <= 3, "bulk starved beyond its 1-slot/tick floor"
        mb.drain()

    def test_per_class_stats(self):
        mb = MicroBatcher(_fake_solve, max_batch=64, max_wait_s=1e-4)
        for i in range(4):
            mb.submit(_spec(i, "bulk"))
        for i in range(2):
            mb.submit(_spec(i, "refresh"))
        mb.drain()
        by = mb.stats.by_class
        assert set(by) == set(PRIORITY_CLASSES)
        assert by["bulk"]["submitted"] == by["bulk"]["completed"] == 4
        assert by["refresh"]["submitted"] == by["refresh"]["completed"] == 2
        assert by["interactive"]["submitted"] == 0

    def test_unknown_priority_rejected_at_submit(self):
        mb = MicroBatcher(_fake_solve)
        with pytest.raises(ValueError, match="priority"):
            mb.submit(_spec(0, "urgent"))


class TestAdmissionControl:
    def test_bulk_shed_before_interactive(self):
        """bulk admits up to 50% of queue_depth, interactive up to 100%:
        under backlog, bulk is rejected while interactive still admits."""
        mb = MicroBatcher(_fake_solve, queue_depth=8, max_wait_s=1e-4)
        for i in range(4):
            mb.submit(_spec(i, "bulk"))
        with pytest.raises(queue.Full):
            mb.submit(_spec(99, "bulk"), block=False)
        # interactive and refresh still have headroom at pending=4
        mb.submit(_spec(0, "refresh"), block=False)
        mb.submit(_spec(0, "interactive"), block=False)
        assert mb.stats.rejected == 1
        assert mb.stats.by_class["bulk"]["rejected"] == 1
        assert mb.stats.by_class["interactive"]["rejected"] == 0
        mb.drain()

    def test_interactive_full_queue_rejects(self):
        mb = MicroBatcher(_fake_solve, queue_depth=4, max_wait_s=1e-4)
        for i in range(4):
            mb.submit(_spec(i))
        with pytest.raises(queue.Full):
            mb.submit(_spec(9), block=False)
        with pytest.raises(queue.Full):
            mb.submit(_spec(9), timeout=0.01)
        assert mb.stats.rejected == 2
        mb.drain()

    def test_blocking_submit_waits_for_drain(self):
        mb = MicroBatcher(_fake_solve, queue_depth=2, max_wait_s=1e-4)
        mb.submit(_spec(0))
        mb.submit(_spec(1))
        done = threading.Event()

        def late():
            mb.submit(_spec(2), timeout=5.0)
            done.set()

        t = threading.Thread(target=late)
        t.start()
        time.sleep(0.02)
        assert not done.is_set()
        mb.run_once(wait=False)
        t.join(timeout=5.0)
        assert done.is_set()
        mb.drain()
        assert mb.stats.completed == 3


class TestPipelinedShutdown:
    def test_stop_resolves_all_inflight_futures(self):
        """stop() with batches in flight joins both pipeline threads and
        leaves no stranded future."""
        net = small_net()
        engine = LPServeEngine(
            net, serve_cfg(pipeline_depth=2, cache_shards=2, max_batch=8)
        )
        engine.start()
        try:
            futs = [
                engine.submit(QuerySpec(entity=e % 18, target_type=2, top_k=3))
                for e in range(24)
            ]
        finally:
            engine.stop()
        for f in futs:
            r = f.result(timeout=1.0)
            assert r.version == 0
            assert np.all(np.diff(r.scores) <= 1e-12)
        assert engine.batcher.stats.completed == 24
        assert engine.batcher.pending == 0
        # all pipeline threads joined
        assert not any(
            t.name.startswith("lp-serve") for t in threading.enumerate()
        )

    def test_assembly_failure_fails_only_its_batch(self):
        """An exception in the assemble stage fails that batch's futures
        without wedging the collector/solver pipeline."""
        calls = {"n": 0}

        def assemble(specs):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("assembly boom")
            return list(specs)

        def execute(prepared):
            return [_fake_result(s) for s in prepared]

        mb = MicroBatcher(
            _fake_solve, max_batch=4, max_wait_s=1e-3,
            pipeline_depth=2, assemble=assemble, execute=execute,
        )
        mb.start()
        try:
            bad = [mb.submit(_spec(i)) for i in range(4)]
            for f in bad:  # first batch fails
                with pytest.raises(RuntimeError, match="assembly boom"):
                    f.result(timeout=5.0)
            good = [mb.submit(_spec(i)) for i in range(4)]
            for f in good:  # pipeline still alive
                assert f.result(timeout=5.0).rounds == 1
        finally:
            mb.stop()
        assert mb.stats.failed == 4
        assert mb.stats.completed == 4

    def test_pipeline_depth_requires_stage_hooks(self):
        with pytest.raises(ValueError, match="assemble"):
            MicroBatcher(_fake_solve, pipeline_depth=2)

    def test_pipelined_results_match_sync(self):
        """The two-stage pipeline returns the same rankings as the
        synchronous scheduler on an identical cold workload."""
        net = small_net()
        specs = [
            QuerySpec(entity=e, target_type=2, top_k=4) for e in range(10)
        ]
        sync = LPServeEngine(net, serve_cfg())
        sync_futs = [sync.submit(s) for s in specs]
        sync.batcher.drain()

        pipe = LPServeEngine(net, serve_cfg(pipeline_depth=3, cache_shards=2))
        pipe.start()
        try:
            pipe_futs = [pipe.submit(s) for s in specs]
            results = [f.result(timeout=30.0) for f in pipe_futs]
        finally:
            pipe.stop()
        for fs, r in zip(sync_futs, results):
            s = fs.result(timeout=1.0)
            np.testing.assert_array_equal(s.candidates, r.candidates)
            np.testing.assert_allclose(s.scores, r.scores, atol=1e-9)


class TestShardedCacheEquivalence:
    def _exercise(self, cache):
        rng = np.random.default_rng(7)
        type_of = np.zeros(40, dtype=np.int64)
        type_of[20:] = 1
        log = []
        for step in range(200):
            node = int(rng.integers(0, 40))
            op = rng.random()
            if op < 0.5:
                cache.put(0, node, np.full(8, float(node)))
                log.append(("put", node))
            elif op < 0.8:
                col = cache.get(0, node)
                log.append(("get", node, None if col is None else col[0]))
            elif op < 0.9:
                hint = cache.stale_hint(node)
                log.append(
                    ("hint", node, None if hint is None else hint[0])
                )
            else:
                cache.invalidate_for_delta(
                    0, 1, frozenset({node % 2}), type_of
                )
                log.append(("delta", node))
        log.append(("len", len(cache)))
        s = cache.stats
        log.append(
            ("stats", s.hits, s.misses, s.evictions,
             s.invalidations, s.warm_hints)
        )
        return log

    def test_one_shard_identical_to_flat_cache(self):
        """shards=1 reproduces the flat ColumnCache exactly: same hits,
        misses, evictions, LRU order, and stale-hint behavior."""
        flat = self._exercise(ColumnCache(capacity=16))
        sharded = self._exercise(ShardedColumnCache(16, shards=1))
        assert flat == sharded

    def test_multi_shard_same_contents_different_layout(self):
        """shards>1 changes eviction locality but not correctness: every
        lookup that hits returns the same column."""
        flat = ColumnCache(capacity=64)
        sharded = ShardedColumnCache(64, shards=4)
        for node in range(32):
            col = np.full(4, float(node))
            flat.put(0, node, col)
            sharded.put(0, node, col)
        for node in range(32):
            np.testing.assert_array_equal(
                flat.get(0, node), sharded.get(0, node)
            )
        assert len(sharded) == len(flat) == 32
        assert sharded.stats.hits == flat.stats.hits == 32

    def test_capacity_split_and_validation(self):
        c = ShardedColumnCache(10, shards=4)
        for node in range(40):
            c.put(0, node, np.zeros(2))
        assert len(c) <= 12  # ceil(10/4)=3 per shard, 4 shards
        with pytest.raises(ValueError):
            ShardedColumnCache(2, shards=4)
        with pytest.raises(ValueError):
            ShardedColumnCache(8, shards=0)


class TestEarlyExitAgreement:
    def test_agrees_with_full_solve_strict(self):
        """Per-column early exit matches the full-superstep solver within
        the bench agree_dense tolerance on every cached column."""
        net = small_net()
        specs = [
            QuerySpec(entity=e, target_type=2, top_k=5) for e in range(12)
        ]
        full = LPServeEngine(net, serve_cfg(early_exit=False))
        early = LPServeEngine(net, serve_cfg(early_exit=True))
        r_full = full._solve_batch(list(specs))
        r_early = early._solve_batch(list(specs))
        worst = 0.0
        for e in range(12):
            cf = full.columns.get(0, e)
            ce = early.columns.get(0, e)
            assert cf is not None and ce is not None
            worst = max(worst, float(np.max(np.abs(cf - ce))))
        assert worst <= AGREEMENT_TOL
        for a, b in zip(r_full, r_early):
            np.testing.assert_array_equal(a.candidates, b.candidates)

    def test_columns_converge_at_different_rounds(self):
        """Early exit tracks per-column round counts; a mixed batch with a
        warm hint should show heterogeneous counts."""
        net = small_net()
        engine = LPServeEngine(net, serve_cfg(early_exit=True))
        engine._solve_batch([QuerySpec(entity=0, target_type=2, top_k=3)])
        # re-solving a cached column is a hit: no rounds at all
        rehit = engine._solve_batch(
            [QuerySpec(entity=0, target_type=2, top_k=3)]
        )
        assert rehit[0].source == "cache"
        assert rehit[0].rounds == 0
        cold = engine._solve_batch(
            [QuerySpec(entity=5, target_type=2, top_k=3)]
        )
        assert cold[0].source == "cold"
        assert cold[0].rounds >= 1

    def test_early_exit_requires_dhlp2(self):
        with pytest.raises(ValueError, match="dhlp2"):
            ServeConfig(
                lp=LPConfig(alg="dhlp1", seed_mode="fixed"), early_exit=True
            )

    def test_early_exit_momentum_conflict(self):
        with pytest.raises(ValueError, match="momentum"):
            ServeConfig(
                lp=LPConfig(
                    alg="dhlp2", seed_mode="fixed", momentum=0.5
                ),
                early_exit=True,
            )

    def test_config_knob_validation(self):
        with pytest.raises(ValueError, match="pipeline_depth"):
            serve_cfg(pipeline_depth=0)
        with pytest.raises(ValueError, match="cache_shards"):
            serve_cfg(cache_shards=0)
        with pytest.raises(ValueError, match="cache_shards"):
            serve_cfg(cache_shards=128, cache_columns=64)


class TestPriorityTelemetry:
    def test_per_class_gauges_and_shard_counters(self):
        from repro.obs import Telemetry

        tel = Telemetry("metrics", run_id="pipeline-tel")
        net = small_net()
        engine = LPServeEngine(
            net, serve_cfg(cache_shards=2, max_batch=8), telemetry=tel
        )
        for e in range(6):
            engine.submit(
                QuerySpec(entity=e, target_type=2, top_k=3, priority="bulk")
            )
        engine.batcher.drain()
        depth = tel.metrics.gauge("serve.queue_depth.bulk")
        assert depth.series, "per-class queue gauge missing"
        shard_counts = sum(
            tel.metrics.counter(f"serve.cache.shard{i}.misses").value
            for i in range(2)
        )
        assert shard_counts == tel.metrics.counter("serve.cache.misses").value
        assert shard_counts == engine.columns.stats.misses > 0
