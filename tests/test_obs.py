"""Telemetry subsystem tests (DESIGN.md §14).

Covers the ISSUE-6 acceptance surface: span nesting/parenting under an
injected clock, histogram bucket-edge arithmetic, the JSONL round-trip
through schema validation and the summary loader, the off-level
zero-event overhead guard, and serve-replay counters matching the
scheduler/cache's own bookkeeping.
"""
import json
import threading

import numpy as np
import pytest

from repro.obs import (
    DEFAULT_BUCKETS,
    Histogram,
    MetricsRegistry,
    Telemetry,
    TelemetryError,
    bucket_index,
    validate_dir,
    validate_line,
)
from repro.obs.summary import load_dir, render, summarize


class FakeClock:
    """Deterministic monotonic clock: each read advances by ``step``."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        t = self.t
        self.t += self.step
        return t


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
class TestSpans:
    def test_nesting_assigns_parent_ids(self):
        tel = Telemetry("trace", run_id="t", clock=FakeClock())
        with tel.span("run", "r") as run:
            with tel.span("phase", "solve") as phase:
                with tel.trace_span("superstep", "s0") as step:
                    tel.event("residual", value=0.5)
        events = tel.events()
        # spans record on __exit__, so the innermost closes first
        by_name = {e.get("name"): e for e in events}
        assert run.id == 0 and phase.id == 1 and step.id == 2
        assert by_name["r"]["parent"] is None
        assert by_name["solve"]["parent"] == run.id
        assert by_name["s0"]["parent"] == phase.id
        assert by_name["residual"]["parent"] == step.id
        assert [e["kind"] for e in events] == ["event", "span", "span", "span"]

    def test_injected_clock_times_spans_exactly(self):
        clock = FakeClock(step=1.0)
        tel = Telemetry("metrics", clock=clock)
        with tel.span("run", "r"):
            pass  # t0=0 on enter, t1=1 on exit
        (rec,) = tel.events()
        assert rec["t0"] == 0.0
        assert rec["dur_s"] == 1.0

    def test_background_thread_parents_to_ambient_phase(self):
        """A span opened on a fresh thread (empty stack) nests under the
        innermost open run/phase — the micro-batcher's situation."""
        tel = Telemetry("trace", clock=FakeClock())
        seen = {}

        def worker():
            with tel.span("batch", "b0") as sp:
                seen["parent"] = sp.parent

        with tel.span("run", "r"):
            with tel.span("phase", "serve") as phase:
                t = threading.Thread(target=worker)
                t.start()
                t.join()
        assert seen["parent"] == phase.id

    def test_batch_span_never_becomes_ambient_parent(self):
        tel = Telemetry("trace", clock=FakeClock())
        with tel.span("run", "r") as run:
            with tel.span("batch", "b"):
                pass
            seen = {}

            def worker():
                with tel.span("batch", "b2") as sp:
                    seen["parent"] = sp.parent

            t = threading.Thread(target=worker)
            t.start()
            t.join()
        assert seen["parent"] == run.id  # not the closed batch span

    def test_error_exit_marks_span(self):
        tel = Telemetry("metrics", clock=FakeClock())
        with pytest.raises(RuntimeError):
            with tel.span("phase", "boom"):
                raise RuntimeError("x")
        (rec,) = tel.events()
        assert rec["status"] == "error"
        assert rec["error"].startswith("RuntimeError")

    def test_trace_span_is_null_at_metrics_level(self):
        tel = Telemetry("metrics", clock=FakeClock())
        with tel.trace_span("superstep", "s0") as sp:
            assert sp.id is None
        assert tel.events() == []


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
class TestHistogramBuckets:
    def test_default_edges_five_per_decade(self):
        assert len(DEFAULT_BUCKETS) == 41
        assert DEFAULT_BUCKETS[0] == pytest.approx(1e-6)
        assert DEFAULT_BUCKETS[-1] == pytest.approx(1e2)
        ratios = np.diff(np.log10(DEFAULT_BUCKETS))
        np.testing.assert_allclose(ratios, 0.2, atol=1e-12)

    def test_bucket_index_edges(self):
        # exact edges are inclusive upper bounds (bisect_left)
        assert bucket_index(1e-6) == 0
        assert bucket_index(10.0 ** (-29 / 5.0)) == 1
        assert bucket_index(1e2) == 40
        assert bucket_index(1e9) == 41  # overflow bucket
        assert bucket_index(0.0) == 0

    def test_observe_accumulates_and_bounds(self):
        h = Histogram("lat")
        for v in (1e-4, 2e-4, 5e-1):
            h.observe(v)
        assert h.count == 3
        assert h.total == pytest.approx(0.5003)
        assert h.min == pytest.approx(1e-4)
        assert h.max == pytest.approx(0.5)
        assert sum(h.counts) == 3
        # p100 is clamped to the observed max, not a bucket edge
        assert h.percentile(1.0) == pytest.approx(0.5)
        p50 = h.percentile(0.5)
        assert 1e-4 <= p50 <= 0.5

    def test_registry_is_type_strict(self):
        reg = MetricsRegistry(clock=FakeClock())
        reg.counter("x").inc()
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_registry_peek_never_creates(self):
        reg = MetricsRegistry(clock=FakeClock())
        assert reg.peek("nope") is None
        assert reg.names() == []
        reg.counter("x").inc(3)
        assert reg.peek("x").value == 3


class TestHistogramMerge:
    def test_merge_adds_counts_and_widens_envelope(self):
        a, b = Histogram("lat"), Histogram("lat")
        for v in (1e-4, 2e-4):
            a.observe(v)
        for v in (5e-1, 3.0):
            b.observe(v)
        a.merge(b)
        assert a.count == 4
        assert a.total == pytest.approx(1e-4 + 2e-4 + 0.5 + 3.0)
        assert a.min == pytest.approx(1e-4)
        assert a.max == pytest.approx(3.0)
        assert sum(a.counts) == 4

    def test_merge_equals_single_histogram(self):
        """Two halves merged == everything observed in one instrument —
        the segment-rotation / SLO-window mergeability contract."""
        values = [10.0 ** (v / 3.0) for v in range(-12, 6)]
        whole = Histogram("lat")
        a, b = Histogram("lat"), Histogram("lat")
        for i, v in enumerate(values):
            whole.observe(v)
            (a if i % 2 else b).observe(v)
        a.merge(b)
        assert a.counts == whole.counts
        assert a.count == whole.count
        assert a.total == pytest.approx(whole.total)
        for q in (0.5, 0.95, 0.99, 1.0):
            assert a.percentile(q) == whole.percentile(q)

    def test_merge_rejects_mismatched_edges(self):
        a = Histogram("lat")
        b = Histogram("lat", edges=(0.1, 1.0, 10.0))
        with pytest.raises(ValueError, match="different edges"):
            a.merge(b)

    def test_merge_empty_is_identity(self):
        a, b = Histogram("lat"), Histogram("lat")
        a.observe(0.25)
        a.merge(b)
        assert a.count == 1
        assert a.min == a.max == pytest.approx(0.25)
        b.merge(a)  # empty absorbing non-empty adopts its envelope
        assert b.min == b.max == pytest.approx(0.25)

    def test_from_line_round_trip(self):
        h = Histogram("lat")
        for v in (1e-5, 1e-3, 0.2, 250.0):
            h.observe(v)
        h2 = Histogram.from_line(h.to_line())
        assert h2.counts == h.counts
        assert h2.count == h.count
        assert h2.total == pytest.approx(h.total)
        assert h2.min == pytest.approx(h.min)
        assert h2.max == pytest.approx(h.max)
        assert h2.percentile(0.95) == h.percentile(0.95)

    def test_from_line_rejects_bad_counts(self):
        line = Histogram("lat").to_line()
        line["counts"] = line["counts"][:-1]
        with pytest.raises(ValueError, match="counts"):
            Histogram.from_line(line)


class TestPercentileEdgeCases:
    def test_empty_histogram_has_no_percentiles(self):
        h = Histogram("lat")
        for q in (0.0, 0.5, 0.95, 1.0):
            assert h.percentile(q) is None

    def test_single_observation_every_quantile(self):
        h = Histogram("lat")
        h.observe(0.042)
        for q in (0.01, 0.5, 0.95, 1.0):
            assert h.percentile(q) == pytest.approx(0.042)

    def test_overflow_bucket_percentile_clamps_to_max(self):
        h = Histogram("lat")
        h.observe(1e5)  # beyond the last edge: the overflow bucket
        h.observe(2e5)
        assert h.counts[-1] == 2
        # the overflow bucket has no sub-resolution: its conservative
        # bound is the observed max for every quantile it covers
        assert h.percentile(0.5) == pytest.approx(2e5)
        assert h.percentile(1.0) == pytest.approx(2e5)

    def test_percentiles_monotone_in_q(self):
        h = Histogram("lat")
        for v in (1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0):
            h.observe(v)
        qs = (0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0)
        ps = [h.percentile(q) for q in qs]
        assert ps == sorted(ps)
        assert h.min <= ps[0] and ps[-1] <= h.max


# ---------------------------------------------------------------------------
# JSONL round-trip
# ---------------------------------------------------------------------------
class TestRoundTrip:
    def _recorded(self) -> Telemetry:
        tel = Telemetry("trace", run_id="rt", clock=FakeClock())
        with tel.span("run", "rt"):
            with tel.span("phase", "solve"):
                tel.gauge("solve.residual", 0.25)
                tel.count("solve.supersteps", 3)
            with tel.span("phase", "serve"):
                tel.observe("serve.latency_s", 1e-3)
                tel.event("serve.delta", at=0.5)
        return tel

    def test_flush_validate_load_summarize(self, tmp_path):
        tel = self._recorded()
        paths = tel.flush(str(tmp_path))
        assert [p.rsplit("/", 1)[1] for p in paths] == [
            "events.jsonl", "metrics.jsonl", "summary.json", "metrics.prom",
        ]
        counts = validate_dir(str(tmp_path))
        assert counts["meta"] == 2
        assert counts["span"] == 3
        assert counts["event"] == 1
        assert counts["metric"] == 3
        meta, events, metrics = load_dir(str(tmp_path))
        assert meta["run_id"] == "rt"
        assert len(events) == 4 and len(metrics) == 3
        summary = summarize(meta, events, metrics)
        assert summary["run_id"] == "rt"
        assert render(summary)  # renders without raising

    def test_first_line_is_meta_with_schema(self, tmp_path):
        self._recorded().flush(str(tmp_path))
        for name in ("events.jsonl", "metrics.jsonl"):
            with open(tmp_path / name) as f:
                first = json.loads(f.readline())
            assert first["kind"] == "meta"
            assert first["schema"] == "repro.obs/v1"

    def test_validator_rejects_malformed_lines(self, tmp_path):
        with pytest.raises(TelemetryError, match="schema"):
            validate_line({"kind": "meta", "schema": "bogus/v9"})
        with pytest.raises(TelemetryError):
            validate_line({"kind": "span", "id": -1})
        self._recorded().flush(str(tmp_path))
        with open(tmp_path / "events.jsonl", "a") as f:
            f.write('{"kind": "span", "id": "nope"}\n')
        with pytest.raises(TelemetryError, match="events.jsonl"):
            validate_dir(str(tmp_path))


# ---------------------------------------------------------------------------
# overhead guard
# ---------------------------------------------------------------------------
class TestOffIsFree:
    def test_disabled_records_nothing(self, tmp_path):
        calls = []

        def loud_clock():
            calls.append(1)
            return 0.0

        tel = Telemetry("off", clock=loud_clock)
        with tel.span("run", "r"):
            with tel.trace_span("superstep", "s"):
                tel.event("x")
                tel.count("c")
                tel.gauge("g", 1.0)
                tel.observe("h", 1e-3)
        assert tel.events() == []
        assert tel.metrics.to_lines() == []
        assert tel.suppressed == 6
        assert calls == []            # disabled path never reads the clock
        assert tel.flush(str(tmp_path)) == []
        assert list(tmp_path.iterdir()) == []  # no artifact dir contents

    def test_null_span_is_shared_singleton(self):
        tel = Telemetry("off")
        assert tel.span("run", "a") is tel.span("phase", "b")


# ---------------------------------------------------------------------------
# serve counters mirror scheduler/cache bookkeeping
# ---------------------------------------------------------------------------
class TestServeCounters:
    def _net(self, seed=0, n=(18, 12, 9)):
        from repro.core import HeteroNetwork

        rng = np.random.default_rng(seed)
        P = []
        for ni in n:
            a = (rng.random((ni, ni)) < 0.35) * rng.random((ni, ni))
            np.fill_diagonal(a, 0)
            P.append((a + a.T) / 2)
        R = {(i, j): (rng.random((n[i], n[j])) < 0.3).astype(float)
             for (i, j) in [(0, 1), (0, 2), (1, 2)]}
        return HeteroNetwork(P=P, R=R)

    def test_cache_and_batch_counters_match_stats(self):
        from repro.core import LPConfig
        from repro.serve import LPServeEngine, QuerySpec, ServeConfig

        tel = Telemetry("metrics", run_id="serve-counters")
        engine = LPServeEngine(
            self._net(),
            ServeConfig(
                lp=LPConfig(alg="dhlp2", seed_mode="fixed", sigma=1e-6),
                max_wait_s=1e-3, max_batch=4,
            ),
            telemetry=tel,
        )
        futs = [
            engine.submit(QuerySpec(entity=e, target_type=2, top_k=3))
            for e in range(6)
        ]
        engine.batcher.drain()
        # repeat: the second pass should be pure cache hits
        futs += [
            engine.submit(QuerySpec(entity=e, target_type=2, top_k=3))
            for e in range(6)
        ]
        engine.batcher.drain()
        for f in futs:
            f.result(timeout=60)

        def counter(name):
            return tel.metrics.counter(name).value

        cache = engine.columns.stats
        assert counter("serve.cache.misses") == cache.misses
        assert counter("serve.cache.hits") == cache.hits
        assert cache.hits >= 6
        assert counter("serve.batches") == engine.batcher.stats.batches
        assert counter("serve.completed") == engine.batcher.stats.completed
        assert counter("serve.completed") == 12
        # gauges tracked one sample per tick
        depth = tel.metrics.gauge("serve.queue_depth")
        occ = tel.metrics.gauge("serve.batch_occupancy")
        assert len(depth.series) == engine.batcher.stats.batches
        assert occ.series and max(v for _, v in occ.series) <= 1.0

    def test_standalone_components_accept_no_telemetry(self):
        """telemetry=None (the default) leaves serve components silent."""
        from repro.core import LPConfig
        from repro.serve import LPServeEngine, QuerySpec, ServeConfig

        engine = LPServeEngine(
            self._net(),
            ServeConfig(
                lp=LPConfig(alg="dhlp2", seed_mode="fixed", sigma=1e-6),
                max_wait_s=1e-3,
            ),
        )
        fut = engine.submit(QuerySpec(entity=0, target_type=2, top_k=3))
        engine.batcher.drain()
        assert fut.result(timeout=60).candidates.size > 0
