"""Checkpoint store + fault-tolerance runtime behaviour."""
import os
import shutil
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.ft import (
    ElasticController,
    FailureInjector,
    StepGuard,
    StragglerWatch,
    TransientWorkerError,
    is_retryable,
)


class _Tel:
    """Minimal telemetry double: records counter/gauge calls."""

    def __init__(self):
        self.counts = {}
        self.gauges = {}

    def count(self, name, n=1):
        self.counts[name] = self.counts.get(name, 0) + n

    def gauge(self, name, value):
        self.gauges[name] = value


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.random((8, 4)).astype(np.float32)),
        "b": [jnp.asarray(rng.random(4).astype(np.float32)),
              jnp.asarray(np.int32(seed))],
    }


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last=2)
        t = tree(1)
        cm.save(5, t)
        step, restored = cm.restore_latest(tree(0))
        assert step == 5
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.asarray(t["w"]))
        assert int(restored["b"][1]) == 1

    def test_keep_last_gc(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last=2)
        for s in [1, 2, 3, 4]:
            cm.save(s, tree(s))
        assert cm.steps() == [3, 4]

    def test_atomicity_partial_ignored(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last=3)
        cm.save(1, tree(1))
        # fabricate a partial (tmp) checkpoint — must be invisible
        os.makedirs(tmp_path / "step_00000009.tmp")
        assert cm.steps() == [1]

    def test_corrupt_latest_falls_back(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last=3)
        cm.save(1, tree(1))
        cm.save(2, tree(2))
        # corrupt step 2's manifest
        with open(tmp_path / "step_00000002" / "manifest.json", "w") as f:
            f.write("{broken")
        step, restored = cm.restore_latest(tree(0))
        assert step == 1

    def test_async_write(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last=2, async_write=True)
        cm.save(7, tree(7))
        cm.wait()
        assert cm.steps() == [7]

    def test_shape_mismatch_raises(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, tree(1))
        bad = {"w": jnp.zeros((3, 3)), "b": [jnp.zeros(4), jnp.int32(0)]}
        with pytest.raises(ValueError):
            cm.restore(1, bad)

    def test_elastic_restore_with_shardings(self, tmp_path):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.parallel.hints import make_mesh_compat

        cm = CheckpointManager(str(tmp_path))
        t = tree(3)
        cm.save(1, t)
        mesh = make_mesh_compat((1,), ("data",))
        sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), t
        )
        _, restored = cm.restore_latest(t, shardings=sh)
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.asarray(t["w"]))


class TestCheckpointLifecycle:
    def test_close_drains_async_queue(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), async_write=True)
        cm.save(3, tree(3))
        cm.close()
        # the queued snapshot is durable even though wait() was never called
        assert cm.steps() == [3]

    def test_save_after_close_raises(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.close()
        with pytest.raises(RuntimeError, match="closed"):
            cm.save(1, tree(1))

    def test_close_idempotent_context_manager(self, tmp_path):
        with CheckpointManager(str(tmp_path), async_write=True) as cm:
            cm.save(1, tree(1))
        cm.close()  # second close is a no-op
        assert cm.steps() == [1]

    def test_async_write_error_surfaces_on_wait(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), async_write=True)
        # a regular file squatting on the step's tmp path makes the
        # writer thread fail; the error must surface on wait(), not die
        # silently in the daemon
        open(tmp_path / "step_00000005.tmp", "w").close()
        cm.save(5, tree(5))
        with pytest.raises(OSError):
            cm.wait()
        assert cm.steps() == []


class TestRestoreValidation:
    def test_dtype_mismatch_rejected_cast_opts_in(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, {"w": np.ones((2, 2), np.float32)})
        like64 = {"w": np.zeros((2, 2), np.float64)}
        with pytest.raises(ValueError, match="dtype"):
            cm.restore(1, like64)
        out = cm.restore(1, like64, cast=True)
        assert np.asarray(out["w"]).dtype == np.float64

    def test_treedef_mismatch_rejected(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, {"a": np.ones(3), "b": np.zeros(3)})
        # same leaf count + shapes, different structure
        with pytest.raises(ValueError, match="treedef"):
            cm.restore(1, [np.ones(3), np.zeros(3)])

    def test_tmp_checkpoint_not_restored(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, tree(1))
        cm.save(2, tree(2))
        # crash mid-write of step 3: fully-formed leaves still under the
        # .tmp name (the atomic rename never happened) — invisible
        shutil.copytree(
            tmp_path / "step_00000002", tmp_path / "step_00000003.tmp"
        )
        step, _ = cm.restore_latest(tree(0))
        assert step == 2

    def test_restore_latest_flat_roundtrip(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        leaves = [np.arange(4, dtype=np.int64), np.ones((3, 2))]
        cm.save(7, leaves, metadata={"version": 9})
        step, out, meta = cm.restore_latest_flat()
        assert step == 7
        assert meta["version"] == 9
        np.testing.assert_array_equal(out[0], leaves[0])
        np.testing.assert_array_equal(out[1], leaves[1])

    def test_restore_latest_flat_empty_root(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        assert cm.restore_latest_flat() == (None, None, {})


class TestStepGuard:
    def test_retries_transient(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise TransientWorkerError("boom")
            return "ok"

        g = StepGuard(max_retries=3, backoff_s=0.0)
        assert g.run(flaky) == "ok"
        assert g.retries == 2

    def test_fatal_not_retried(self):
        def fatal():
            raise ValueError("shape mismatch")

        g = StepGuard(max_retries=3, backoff_s=0.0)
        with pytest.raises(ValueError):
            g.run(fatal)
        assert g.retries == 0

    def test_restore_path(self):
        state = {"restored": False}

        def always_fails_until_restore():
            if not state["restored"]:
                raise TransientWorkerError("dead worker")
            return "recovered"

        def restore():
            state["restored"] = True
            return 0, None

        g = StepGuard(max_retries=1, backoff_s=0.0, restore_fn=restore)
        assert g.run(always_fails_until_restore) == "recovered"
        assert g.restores == 1

    def test_is_retryable_classification(self):
        assert is_retryable(TransientWorkerError("x"))
        assert is_retryable(RuntimeError("gRPC UNAVAILABLE: socket closed"))
        assert not is_retryable(ValueError("bad shape"))

    def test_injectable_clock_no_wall_sleep(self):
        sleeps = []
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 4:
                raise TransientWorkerError("x")
            return "ok"

        g = StepGuard(max_retries=3, backoff_s=0.1, sleep=sleeps.append)
        t0 = time.perf_counter()
        assert g.run(flaky) == "ok"
        # the injected clock recorded the exponential schedule; no wall
        # time was spent sleeping
        assert sleeps == pytest.approx([0.1, 0.2, 0.4])
        assert time.perf_counter() - t0 < 0.09

    def test_second_exhaustion_reraises(self):
        def dead():
            raise TransientWorkerError("still dead")

        g = StepGuard(
            max_retries=1,
            backoff_s=0.0,
            restore_fn=lambda: (0, None),
            sleep=lambda s: None,
        )
        with pytest.raises(TransientWorkerError):
            g.run(dead)
        assert g.restores == 1  # one restore per run(), then re-raise

    def test_replay_after_restore_gets_fresh_budget(self):
        state = {"restored": False}
        calls = {"n": 0}

        def fn():
            calls["n"] += 1
            # pre-restore: always fails.  post-restore: fails once more
            # (a transient during the replay), then succeeds.
            if not state["restored"]:
                raise TransientWorkerError("dead")
            if calls["n"] < 4:
                raise TransientWorkerError("replay hiccup")
            return "recovered"

        def restore():
            state["restored"] = True

        g = StepGuard(
            max_retries=1, backoff_s=0.0, restore_fn=restore,
            sleep=lambda s: None,
        )
        assert g.run(fn) == "recovered"
        assert g.restores == 1
        assert g.retries >= 2  # pre-restore retry + guarded replay retry

    def test_telemetry_counters(self):
        tel = _Tel()
        state = {"ok": False}

        def fn():
            if not state["ok"]:
                raise TransientWorkerError("x")
            return 1

        g = StepGuard(
            max_retries=1,
            backoff_s=0.0,
            restore_fn=lambda: state.__setitem__("ok", True),
            sleep=lambda s: None,
            telemetry=tel,
        )
        assert g.run(fn) == 1
        assert tel.counts == {"ft.retries": 1, "ft.restores": 1}


class TestStragglerWatch:
    def test_flags_outlier(self):
        w = StragglerWatch(threshold=2.0)
        for _ in range(10):
            assert not w.observe(0.1)
        assert w.observe(0.5)
        assert w.slow_steps == 1

    def test_mean_tracks(self):
        w = StragglerWatch()
        for _ in range(50):
            w.observe(0.2)
        assert abs(w.mean_step_time - 0.2) < 0.02

    def test_ewma_discounts_outliers(self):
        w = StragglerWatch(alpha=0.1, threshold=2.0)
        for _ in range(20):
            w.observe(0.1)
        w.observe(1.0)  # flagged → quarter-weight EWMA update
        assert w.mean_step_time < 0.15  # one outlier barely moves the mean

    def test_telemetry_counts_flags(self):
        tel = _Tel()
        w = StragglerWatch(threshold=2.0, telemetry=tel)
        for _ in range(5):
            w.observe(0.1)
        w.observe(0.5)
        assert tel.counts.get("ft.straggler_flags") == 1
        assert tel.gauges["ft.step_time_mean"] == pytest.approx(
            w.mean_step_time
        )


class TestElastic:
    def test_no_change_no_plan(self):
        c = ElasticController()
        assert c.plan(256, 256) is None

    def test_shrink_to_power_of_two(self):
        c = ElasticController()
        plan = c.plan(250, 256)
        assert plan["to"] == 128
        assert c.history

    def test_below_minimum_raises(self):
        c = ElasticController(min_devices=8)
        with pytest.raises(RuntimeError):
            c.plan(4, 256)


class TestFailureInjector:
    def test_fires_once(self):
        inj = FailureInjector(fail_at=(3,))
        inj.maybe_fail(2)
        with pytest.raises(TransientWorkerError):
            inj.maybe_fail(3)
        inj.maybe_fail(3)  # second pass: already fired


class TestRemesh:
    def test_remesh_end_to_end(self, tmp_path):
        """save-unsharded → restore-with-new-shardings, full circle."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.ft import remesh
        from repro.parallel.hints import make_mesh_compat

        cm = CheckpointManager(str(tmp_path))
        t = tree(5)

        def make_shardings(n):
            mesh = make_mesh_compat((n,), ("data",))
            return jax.tree_util.tree_map(
                lambda _: NamedSharding(mesh, P()), t
            )

        tel = _Tel()
        restored, plan = remesh(
            cm,
            t,
            healthy_devices=1,
            current_devices=2,
            make_shardings=make_shardings,
            step=4,
            telemetry=tel,
        )
        assert plan["from"] == 2 and plan["to"] == 1
        np.testing.assert_allclose(
            np.asarray(restored["w"]), np.asarray(t["w"])
        )
        assert cm.steps() == [4]  # the pre-remesh snapshot is durable
        assert cm.manifest(4)["metadata"]["elastic"] == plan
        assert tel.counts.get("ft.remeshes") == 1
        assert tel.gauges["ft.mesh_devices"] == 1

    def test_remesh_no_change_is_identity(self, tmp_path):
        from repro.ft import remesh

        cm = CheckpointManager(str(tmp_path))
        t = tree(1)
        out, plan = remesh(cm, t, healthy_devices=4, current_devices=4)
        assert plan is None and out is t
        assert cm.steps() == []  # no snapshot for a no-op plan


def _small_net(seed=0, n=(12, 9, 7)):
    from repro.core import HeteroNetwork

    rng = np.random.default_rng(seed)
    P = []
    for ni in n:
        a = (rng.random((ni, ni)) < 0.4) * rng.random((ni, ni))
        np.fill_diagonal(a, 0)
        P.append((a + a.T) / 2)
    R = {
        (i, j): (rng.random((n[i], n[j])) < 0.3).astype(float)
        for (i, j) in [(0, 1), (0, 2), (1, 2)]
    }
    return HeteroNetwork(P=P, R=R)


class TestCheckpointedSolve:
    def test_crash_resume_byte_identical(self, tmp_path):
        from repro.core import LPConfig
        from repro.engine import make_engine
        from repro.ft import checkpointed_solve

        norm = _small_net().normalize()
        cfg = LPConfig(alg="dhlp2", seed_mode="fixed", sigma=1e-5)
        engine = make_engine("dense", cfg)

        clean, _ = checkpointed_solve(
            engine, norm,
            manager=CheckpointManager(str(tmp_path / "clean")), interval=3,
        )

        cm = CheckpointManager(str(tmp_path / "crash"))
        inj = FailureInjector(fail_at=(4,))
        with pytest.raises(TransientWorkerError):
            checkpointed_solve(engine, norm, manager=cm, interval=3,
                               injector=inj)
        assert cm.steps()  # a durable barrier predates the kill

        # same injector still armed: a resumed run never re-fires
        resumed, stats = checkpointed_solve(
            engine, norm, manager=cm, interval=3, injector=inj
        )
        assert stats["resumed_from"] == 3
        assert float(np.max(np.abs(resumed.F - clean.F))) == 0.0
        assert resumed.outer_iters == clean.outer_iters
        np.testing.assert_array_equal(
            resumed.per_column_iters, clean.per_column_iters
        )

    def test_checkpoint_cadence_and_final_barrier(self, tmp_path):
        from repro.core import LPConfig
        from repro.engine import make_engine
        from repro.ft import checkpointed_solve

        norm = _small_net(seed=2).normalize()
        cfg = LPConfig(alg="dhlp2", seed_mode="fixed", sigma=1e-5)
        cm = CheckpointManager(str(tmp_path), keep_last=100)
        res, stats = checkpointed_solve(
            make_engine("dense", cfg), norm, manager=cm, interval=4
        )
        assert res.converged
        steps = cm.steps()
        # every interval boundary plus the converged step is durable
        assert steps[-1] == res.outer_iters
        assert all(s % 4 == 0 for s in steps[:-1])
        assert stats["checkpoints"] == len(steps)
        assert stats["resumed_from"] is None


class TestDataPipelines:
    def test_lm_determinism_and_sharding(self):
        from repro.data.lm import LMDataConfig, sample_batch

        cfg = LMDataConfig(vocab=1000, batch=8, seq_len=32)
        a = sample_batch(cfg, step=3)
        b = sample_batch(cfg, step=3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        # shards are disjoint slices of the global batch
        s0 = sample_batch(cfg, step=3, shard=0, num_shards=2)
        s1 = sample_batch(cfg, step=3, shard=1, num_shards=2)
        np.testing.assert_array_equal(
            np.concatenate([s0["tokens"], s1["tokens"]]), a["tokens"]
        )
        # labels are next-token shifted
        np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])

    def test_ctr_batch(self):
        from repro.data.recsys import CTRDataConfig, sample_ctr_batch

        cfg = CTRDataConfig(n_sparse=5, n_dense=3, vocab_per_field=100)
        b = sample_ctr_batch(cfg, 64)
        assert b["sparse"].shape == (64, 5)
        assert b["sparse"].max() < 100
        assert set(np.unique(b["labels"])) <= {0.0, 1.0}

    def test_planted_graph_learnable(self):
        from repro.data.graphs import planted_partition_graph

        d = planted_partition_graph(200, 800, 4, 16, seed=1)
        assert d.feats.shape == (200, 16)
        assert d.edges.num_nodes == 200
        # homophily: most edges connect same-class nodes
        e = d.edges
        same = (d.labels[e.src] == d.labels[e.dst]).mean()
        assert same > 0.5
