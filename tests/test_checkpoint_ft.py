"""Checkpoint store + fault-tolerance runtime behaviour."""
import os
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.ft import (
    ElasticController,
    FailureInjector,
    StepGuard,
    StragglerWatch,
    TransientWorkerError,
    is_retryable,
)


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.random((8, 4)).astype(np.float32)),
        "b": [jnp.asarray(rng.random(4).astype(np.float32)),
              jnp.asarray(np.int32(seed))],
    }


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last=2)
        t = tree(1)
        cm.save(5, t)
        step, restored = cm.restore_latest(tree(0))
        assert step == 5
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.asarray(t["w"]))
        assert int(restored["b"][1]) == 1

    def test_keep_last_gc(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last=2)
        for s in [1, 2, 3, 4]:
            cm.save(s, tree(s))
        assert cm.steps() == [3, 4]

    def test_atomicity_partial_ignored(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last=3)
        cm.save(1, tree(1))
        # fabricate a partial (tmp) checkpoint — must be invisible
        os.makedirs(tmp_path / "step_00000009.tmp")
        assert cm.steps() == [1]

    def test_corrupt_latest_falls_back(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last=3)
        cm.save(1, tree(1))
        cm.save(2, tree(2))
        # corrupt step 2's manifest
        with open(tmp_path / "step_00000002" / "manifest.json", "w") as f:
            f.write("{broken")
        step, restored = cm.restore_latest(tree(0))
        assert step == 1

    def test_async_write(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last=2, async_write=True)
        cm.save(7, tree(7))
        cm.wait()
        assert cm.steps() == [7]

    def test_shape_mismatch_raises(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, tree(1))
        bad = {"w": jnp.zeros((3, 3)), "b": [jnp.zeros(4), jnp.int32(0)]}
        with pytest.raises(ValueError):
            cm.restore(1, bad)

    def test_elastic_restore_with_shardings(self, tmp_path):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.parallel.hints import make_mesh_compat

        cm = CheckpointManager(str(tmp_path))
        t = tree(3)
        cm.save(1, t)
        mesh = make_mesh_compat((1,), ("data",))
        sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), t
        )
        _, restored = cm.restore_latest(t, shardings=sh)
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.asarray(t["w"]))


class TestStepGuard:
    def test_retries_transient(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise TransientWorkerError("boom")
            return "ok"

        g = StepGuard(max_retries=3, backoff_s=0.0)
        assert g.run(flaky) == "ok"
        assert g.retries == 2

    def test_fatal_not_retried(self):
        def fatal():
            raise ValueError("shape mismatch")

        g = StepGuard(max_retries=3, backoff_s=0.0)
        with pytest.raises(ValueError):
            g.run(fatal)
        assert g.retries == 0

    def test_restore_path(self):
        state = {"restored": False}

        def always_fails_until_restore():
            if not state["restored"]:
                raise TransientWorkerError("dead worker")
            return "recovered"

        def restore():
            state["restored"] = True
            return 0, None

        g = StepGuard(max_retries=1, backoff_s=0.0, restore_fn=restore)
        assert g.run(always_fails_until_restore) == "recovered"
        assert g.restores == 1

    def test_is_retryable_classification(self):
        assert is_retryable(TransientWorkerError("x"))
        assert is_retryable(RuntimeError("gRPC UNAVAILABLE: socket closed"))
        assert not is_retryable(ValueError("bad shape"))


class TestStragglerWatch:
    def test_flags_outlier(self):
        w = StragglerWatch(threshold=2.0)
        for _ in range(10):
            assert not w.observe(0.1)
        assert w.observe(0.5)
        assert w.slow_steps == 1

    def test_mean_tracks(self):
        w = StragglerWatch()
        for _ in range(50):
            w.observe(0.2)
        assert abs(w.mean_step_time - 0.2) < 0.02


class TestElastic:
    def test_no_change_no_plan(self):
        c = ElasticController()
        assert c.plan(256, 256) is None

    def test_shrink_to_power_of_two(self):
        c = ElasticController()
        plan = c.plan(250, 256)
        assert plan["to"] == 128
        assert c.history

    def test_below_minimum_raises(self):
        c = ElasticController(min_devices=8)
        with pytest.raises(RuntimeError):
            c.plan(4, 256)


class TestFailureInjector:
    def test_fires_once(self):
        inj = FailureInjector(fail_at=(3,))
        inj.maybe_fail(2)
        with pytest.raises(TransientWorkerError):
            inj.maybe_fail(3)
        inj.maybe_fail(3)  # second pass: already fired


class TestDataPipelines:
    def test_lm_determinism_and_sharding(self):
        from repro.data.lm import LMDataConfig, sample_batch

        cfg = LMDataConfig(vocab=1000, batch=8, seq_len=32)
        a = sample_batch(cfg, step=3)
        b = sample_batch(cfg, step=3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        # shards are disjoint slices of the global batch
        s0 = sample_batch(cfg, step=3, shard=0, num_shards=2)
        s1 = sample_batch(cfg, step=3, shard=1, num_shards=2)
        np.testing.assert_array_equal(
            np.concatenate([s0["tokens"], s1["tokens"]]), a["tokens"]
        )
        # labels are next-token shifted
        np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])

    def test_ctr_batch(self):
        from repro.data.recsys import CTRDataConfig, sample_ctr_batch

        cfg = CTRDataConfig(n_sparse=5, n_dense=3, vocab_per_field=100)
        b = sample_ctr_batch(cfg, 64)
        assert b["sparse"].shape == (64, 5)
        assert b["sparse"].max() < 100
        assert set(np.unique(b["labels"])) <= {0.0, 1.0}

    def test_planted_graph_learnable(self):
        from repro.data.graphs import planted_partition_graph

        d = planted_partition_graph(200, 800, 4, 16, seed=1)
        assert d.feats.shape == (200, 16)
        assert d.edges.num_nodes == 200
        # homophily: most edges connect same-class nodes
        e = d.edges
        same = (d.labels[e.src] == d.labels[e.dst]).mean()
        assert same > 0.5
