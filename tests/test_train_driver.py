"""Integration: the training driver end-to-end (fault injection, resume,
checkpoint round-trip through a real optimizer loop)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
ENV = {**os.environ, "PYTHONPATH": SRC}


def run_driver(*args, timeout=420):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *args],
        capture_output=True, text=True, env=ENV, timeout=timeout,
    )


class TestTrainDriver:
    def test_gcn_converges_with_fault_injection(self, tmp_path):
        out = run_driver(
            "--arch", "gcn-cora", "--steps", "25", "--log-every", "24",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
            "--inject-fault", "12",
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "retries=1" in out.stdout
        # loss must improve despite the injected fault
        line = [l for l in out.stdout.splitlines() if "done" in l][0]
        first = float(line.split("first loss")[1].split("→")[0])
        last = float(line.split("last")[1].split(";")[0])
        assert last < first * 0.5

    def test_resume_from_checkpoint(self, tmp_path):
        out1 = run_driver(
            "--arch", "wide-deep", "--steps", "10", "--batch", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
        )
        assert out1.returncode == 0, out1.stderr[-2000:]
        out2 = run_driver(
            "--arch", "wide-deep", "--steps", "14", "--batch", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
        )
        assert out2.returncode == 0, out2.stderr[-2000:]
        assert "resumed from step" in out2.stdout

    def test_lp_family_points_to_solve(self):
        out = run_driver("--arch", "dhlp-bio", "--steps", "1")
        assert out.returncode != 0
        assert "solve" in (out.stdout + out.stderr)


class TestSolveDriver:
    def test_end_to_end(self, tmp_path):
        out = subprocess.run(
            [sys.executable, "-m", "repro", "solve",
             "--alg", "dhlp2", "--drugs", "30", "--diseases", "20",
             "--targets", "15", "--sigma", "1e-3",
             "--out", str(tmp_path / "out.npz")],
            capture_output=True, text=True, env=ENV, timeout=420,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "converged=True" in out.stdout
        assert (tmp_path / "out.npz").exists()
        import numpy as np

        z = np.load(tmp_path / "out.npz")
        assert z["drug_target"].shape == (30, 15)
        assert np.isfinite(z["drug_target"]).all()
