"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import (
    attention_ref,
    csr_aggregate,
    csr_aggregate_ref,
    embedding_bag,
    embedding_bag_ref,
    flash_attention,
    gqa_attention_op,
    lp_round,
    lp_round_op,
    lp_round_ref,
)

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=5e-4
    )


class TestLPBlockSpmm:
    @pytest.mark.parametrize("n,s", [(128, 128), (257, 130), (384, 96), (64, 640)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, n, s, dtype):
        A = jnp.asarray(RNG.random((n, n)), dtype) / n
        F = jnp.asarray(RNG.random((n, s)), dtype)
        base = jnp.asarray(RNG.random((n, s)), dtype)
        got = lp_round(A, F, base, c=0.36, bm=128, bs=128, bk=128,
                       interpret=True)
        want = lp_round_ref(A, F, base, 0.36)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype),
        )

    @pytest.mark.parametrize("blocks", [(64, 64, 64), (128, 256, 128)])
    def test_block_shape_invariance(self, blocks):
        bm, bs, bk = blocks
        n, s = 256, 256
        A = jnp.asarray(RNG.random((n, n)), jnp.float32) / n
        F = jnp.asarray(RNG.random((n, s)), jnp.float32)
        base = jnp.zeros((n, s), jnp.float32)
        got = lp_round(A, F, base, c=0.25, bm=bm, bs=bs, bk=bk, interpret=True)
        want = lp_round_ref(A, F, base, 0.25)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_op_fallback_small(self):
        n, s = 16, 8
        A = jnp.asarray(RNG.random((n, n)), jnp.float32)
        F = jnp.asarray(RNG.random((n, s)), jnp.float32)
        base = jnp.asarray(RNG.random((n, s)), jnp.float32)
        got = lp_round_op(A, F, base, c=0.1)
        want = lp_round_ref(A, F, base, 0.1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


class TestCSRAggregate:
    @pytest.mark.parametrize("n,d,s", [(128, 8, 32), (200, 11, 37), (256, 33, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, n, d, s, dtype):
        nbr = jnp.asarray(RNG.integers(0, n, (n, d)).astype(np.int32))
        wgt = jnp.asarray(
            (RNG.random((n, d)) * (RNG.random((n, d)) < 0.7)), dtype
        )
        F = jnp.asarray(RNG.random((n, s)), dtype)
        got = csr_aggregate(nbr, wgt, F, bn=64, bs=32, bd=8, interpret=True)
        want = csr_aggregate_ref(nbr, wgt, F)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype),
        )

    def test_matches_dense_spmm(self):
        """CSR kernel ≡ dense A @ F when built from the same graph."""
        from repro.graph import PaddedCSR, erdos_renyi

        edges = erdos_renyi(150, 800, seed=3)
        csr = PaddedCSR.from_edgelist(edges)
        A = edges.to_dense()
        F = RNG.random((150, 20)).astype(np.float32)
        got = csr_aggregate(
            jnp.asarray(csr.nbr), jnp.asarray(csr.wgt), jnp.asarray(F),
            bn=64, bs=16, bd=8, interpret=True,
        )
        np.testing.assert_allclose(np.asarray(got), A @ F, rtol=1e-4, atol=1e-5)


class TestEmbeddingBag:
    @pytest.mark.parametrize("v,d,b,k", [
        (1000, 32, 128, 5), (4096, 16, 300, 8), (512, 64, 64, 40),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, v, d, b, k, dtype):
        tab = jnp.asarray(RNG.random((v, d)), dtype)
        idx = jnp.asarray(RNG.integers(0, v, (b, k)).astype(np.int32))
        w = jnp.asarray(RNG.random((b, k)), dtype)
        got = embedding_bag(tab, idx, w, bb=64, bv=256, interpret=True)
        want = embedding_bag_ref(tab, idx, w)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype),
        )

    def test_panel_sweep_counts_each_index_once(self):
        v, d = 100, 8
        tab = jnp.asarray(np.eye(v, d).astype(np.float32))
        idx = jnp.asarray(np.array([[3, 3, 3]], dtype=np.int32))
        w = jnp.ones((1, 3), jnp.float32)
        got = embedding_bag(tab, idx, w, bb=8, bv=16, interpret=True)
        want = embedding_bag_ref(tab, idx, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,lq,lk,d,window,q_offset", [
        (2, 4, 128, 128, 64, None, 0),     # causal prefill
        (1, 2, 100, 100, 32, 48, 0),       # sliding window
        (2, 2, 1, 256, 64, None, 255),     # single-token decode
        (1, 3, 130, 200, 64, None, 70),    # chunked prefill (kv > q)
        (1, 1, 64, 512, 128, 128, 448),    # windowed decode chunk
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, b, h, lq, lk, d, window, q_offset, dtype):
        q = jnp.asarray(RNG.standard_normal((b, h, lq, d)), dtype)
        k = jnp.asarray(RNG.standard_normal((b, h, lk, d)), dtype)
        v = jnp.asarray(RNG.standard_normal((b, h, lk, d)), dtype)
        got = flash_attention(q, k, v, causal=True, window=window,
                              q_offset=q_offset, bq=64, bk=64, interpret=True)
        want = attention_ref(q, k, v, causal=True, window=window,
                             q_offset=q_offset)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype),
        )

    def test_gqa_grouping(self):
        b, hq, hkv, l, d = 1, 8, 2, 64, 32
        q = jnp.asarray(RNG.standard_normal((b, hq, l, d)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, hkv, l, d)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, hkv, l, d)), jnp.float32)
        got = gqa_attention_op(q, k, v, use_kernel=True, bq=32, bk=32)
        want = attention_ref(
            q, jnp.repeat(k, 4, axis=1), jnp.repeat(v, 4, axis=1)
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=5e-4)

    def test_window_equals_full_when_large(self):
        b, h, l, d = 1, 2, 96, 32
        q = jnp.asarray(RNG.standard_normal((b, h, l, d)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, h, l, d)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, h, l, d)), jnp.float32)
        full = flash_attention(q, k, v, causal=True, window=None,
                               bq=32, bk=32, interpret=True)
        win = flash_attention(q, k, v, causal=True, window=4 * l,
                              bq=32, bk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(full), np.asarray(win),
                                   rtol=1e-6)
