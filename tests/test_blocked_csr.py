"""Blocked-CSR operator format: construction, buckets, edges, kernels."""
import numpy as np
import pytest

from repro.core.blocked_csr import (
    BlockedCSR,
    blocked_csr_from_network,
    split_blocked_csr_from_network,
)
from repro.data.drugnet import DrugNetSpec, make_drugnet


@pytest.fixture(scope="module")
def skewed():
    """Degree-skewed random matrix (hub rows stress per-block widths)."""
    rng = np.random.default_rng(7)
    n = 130
    A = (rng.random((n, n)) < 0.03).astype(np.float64) * rng.random((n, n))
    A[:3, :] = rng.random((3, n))  # three hub rows
    return A


class TestConstruction:
    def test_dense_round_trip(self, skewed):
        b = BlockedCSR.from_dense(skewed, block_rows=16, width_mult=8)
        np.testing.assert_allclose(b.to_dense(), skewed, atol=1e-6)

    def test_row_ptr_accounts_all_slots(self, skewed):
        b = BlockedCSR.from_dense(skewed, block_rows=16, width_mult=8)
        assert b.row_ptr[0] == 0
        spans = np.diff(b.row_ptr)
        np.testing.assert_array_equal(
            spans, b.widths.astype(np.int64) * b.block_rows
        )
        assert b.total_slots == b.col_idx.shape[0] == b.val.shape[0]

    def test_widths_are_quantized_and_blockwise(self, skewed):
        b = BlockedCSR.from_dense(skewed, block_rows=16, width_mult=8)
        assert (b.widths % 8 == 0).all()
        # the hub block must be wider than a typical leaf block
        assert b.widths[0] > b.widths[-1]
        # per-block widths beat one uniform max-degree rectangle
        uniform_slots = b.num_rows * b.max_width
        assert b.total_slots < uniform_slots

    def test_ragged_last_block(self):
        A = np.triu(np.ones((21, 21)))
        b = BlockedCSR.from_dense(A, block_rows=8, width_mult=4)
        assert b.num_blocks == 3
        np.testing.assert_allclose(b.to_dense(), A, atol=1e-6)

    def test_zero_weight_edges_dropped(self):
        src = np.array([0, 1, 2], np.int32)
        dst = np.array([1, 2, 0], np.int32)
        w = np.array([1.0, 0.0, 2.0], np.float32)
        b = BlockedCSR.from_edges(src, dst, w, num_rows=3)
        assert b.nnz == 2

    def test_bad_params_raise(self):
        with pytest.raises(ValueError, match="block_rows"):
            BlockedCSR.from_edges(
                np.zeros(0, np.int32), np.zeros(0, np.int32),
                np.zeros(0, np.float32), num_rows=4, block_rows=0,
            )


class TestBuckets:
    def test_buckets_partition_rows(self, skewed):
        b = BlockedCSR.from_dense(skewed, block_rows=16, width_mult=8)
        rows = np.concatenate([bk.rows for bk in b.width_buckets()])
        assert sorted(rows.tolist()) == list(range(b.num_rows))

    def test_bucket_rectangles_match_widths(self, skewed):
        b = BlockedCSR.from_dense(skewed, block_rows=16, width_mult=8)
        for bk in b.width_buckets():
            assert bk.nbr.shape == (bk.rows.shape[0], bk.width)
            assert bk.wgt.shape == bk.nbr.shape

    def test_bucket_aggregation_equals_matmul(self, skewed):
        b = BlockedCSR.from_dense(skewed, block_rows=16, width_mult=8)
        rng = np.random.default_rng(0)
        F = rng.random((b.num_rows, 5)).astype(np.float32)
        out = np.zeros_like(F)
        for bk in b.width_buckets():
            out[bk.rows] = np.einsum(
                "rw,rws->rs", bk.wgt, F[bk.nbr]
            )
        np.testing.assert_allclose(out, skewed @ F, rtol=1e-4, atol=1e-4)


class TestToEdges:
    def test_round_trip_with_pads(self, skewed):
        b = BlockedCSR.from_dense(skewed, block_rows=16, width_mult=8)
        src, dst, w = b.to_edges()
        assert src.shape == dst.shape == w.shape == (b.total_slots,)
        A = np.zeros_like(skewed)
        np.add.at(A, (dst, src), w)
        np.testing.assert_allclose(A, skewed, atol=1e-6)

    def test_dst_sorted_and_in_range(self, skewed):
        b = BlockedCSR.from_dense(skewed, block_rows=16, width_mult=8)
        _, dst, _ = b.to_edges()
        assert (np.diff(dst) >= 0).all()  # destination-contiguous shards
        assert dst.min() >= 0 and dst.max() < b.num_rows


class TestNetworkBuilders:
    def test_fused_matches_assemble_effective(self):
        dn = make_drugnet(DrugNetSpec(n_drug=20, n_disease=15, n_target=10))
        norm = dn.network.normalize()
        scale = 1.0 / (norm.num_types - 1)
        b = blocked_csr_from_network(
            norm, alpha=0.5, hetero_scale=scale, block_rows=8
        )
        H, M = norm.assemble_dense()
        A_eff = 0.5 * 0.5 * scale * H + 0.5 * M
        np.testing.assert_allclose(b.to_dense(), A_eff, atol=1e-6)

    def test_split_supports_disjoint(self):
        dn = make_drugnet(DrugNetSpec(n_drug=20, n_disease=15, n_target=10))
        norm = dn.network.normalize()
        het, hom = split_blocked_csr_from_network(
            norm, hetero_scale=0.5, block_rows=8
        )
        H, M = norm.assemble_dense()
        np.testing.assert_allclose(het.to_dense(), 0.5 * H, atol=1e-6)
        np.testing.assert_allclose(hom.to_dense(), M, atol=1e-6)


class TestFusedRoundKernel:
    def test_csr_round_matches_ref(self, skewed):
        import jax.numpy as jnp

        from repro.kernels import csr_round, csr_round_ref

        b = BlockedCSR.from_dense(skewed, block_rows=16, width_mult=8)
        rng = np.random.default_rng(1)
        F = jnp.asarray(rng.random((b.num_rows, 6)), jnp.float32)
        for bk in b.width_buckets():
            base = jnp.asarray(rng.random((bk.rows.shape[0], 6)), jnp.float32)
            nbr, wgt = jnp.asarray(bk.nbr), jnp.asarray(bk.wgt)
            got = csr_round(
                nbr, wgt, F, base, c=0.25, bn=32, bs=8, bd=8, interpret=True
            )
            want = csr_round_ref(nbr, wgt, F, base, 0.25)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
            )

    def test_rectangular_aggregate(self):
        """M output rows gathering from a wider N-row panel."""
        import jax.numpy as jnp

        from repro.kernels import csr_aggregate, csr_aggregate_ref

        rng = np.random.default_rng(2)
        m, n, d, s = 24, 100, 5, 9
        nbr = jnp.asarray(rng.integers(0, n, (m, d)), jnp.int32)
        wgt = jnp.asarray(rng.random((m, d)), jnp.float32)
        F = jnp.asarray(rng.random((n, s)), jnp.float32)
        got = csr_aggregate(nbr, wgt, F, bn=8, bs=8, bd=4, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(csr_aggregate_ref(nbr, wgt, F)),
            rtol=1e-5, atol=1e-5,
        )
