"""Session resolution, artifacts, deprecation shims, scenario disk cache.

Covers the ISSUE-5 acceptance surface: shim CLIs produce identical
artifacts to the spec-driven driver, one prepared engine is shared
across solve→serve, the scenario disk cache round-trips, and the
deleted ``sparse_coo`` backend stays gone from spec resolution.
"""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro.api import (
    EvalSpec,
    NetworkSpec,
    RunSpec,
    ServeSpec,
    Session,
    SolveSpec,
    SpecError,
)

TINY = {"n_drug": 30, "n_disease": 20, "n_target": 15}


def tiny_spec(**kw) -> RunSpec:
    return RunSpec(
        network=NetworkSpec(kind="drugnet", seed=0, params=dict(TINY)),
        solve=SolveSpec(
            alg="dhlp2", sigma=1e-3, backend="dense", top_k=5,
            rank_pair=(0, 2), **kw.pop("solve_kw", {}),
        ),
        **kw,
    )


# --------------------------------------------------------------- resolution
def test_session_solve_matches_direct_engine():
    from repro.core.solver import LPConfig
    from repro.data.drugnet import DrugNetSpec, make_drugnet
    from repro.engine import make_engine

    session = Session(tiny_spec())
    art = session.solve()

    net = make_drugnet(DrugNetSpec(seed=0, **TINY)).network
    cfg = LPConfig(alg="dhlp2", sigma=1e-3)
    res = make_engine("dense", cfg).run(net.normalize())
    np.testing.assert_array_equal(art.F, res.F)
    assert art.converged and art.outer_iters == res.outer_iters


def test_session_shares_one_prepared_engine_across_solve_and_serve():
    spec = tiny_spec(
        solve_kw={"seed_mode": "fixed"},
        serve=ServeSpec(requests=4, max_batch=4),
    )
    session = Session(spec)
    session.solve()
    prepared = session.engine._op_cache
    assert prepared is not None and prepared[1].norm is session.norm
    serve_engine = session.serve_engine()
    # the serve engine runs the SAME engine object on the SAME normalized
    # view — its first query hits the already-prepared operator
    assert serve_engine._engine is session.engine
    assert serve_engine.state.norm is session.norm
    from repro.serve import QuerySpec

    serve_engine.query(QuerySpec(entity=0, target_type=2, top_k=3))
    assert session.engine._op_cache is prepared  # no re-prepare happened


def test_session_auto_backend_resolution():
    spec = RunSpec(network=NetworkSpec(kind="drugnet", params=dict(TINY)))
    assert Session(spec).backend == "dense"  # tiny net → dense policy


def test_session_run_writes_artifacts(tmp_path):
    spec = tiny_spec(run_id="t-art", eval=EvalSpec(max_entities=4))
    arts = Session(spec, results_root=str(tmp_path)).run(echo=lambda _: None)
    run_dir = tmp_path / "t-art"
    assert (run_dir / "spec.json").exists()
    assert (run_dir / "solve.json").exists()
    assert (run_dir / "solve_outputs.npz").exists()
    assert (run_dir / "eval.json").exists()
    with open(run_dir / "spec.json") as f:
        assert RunSpec.from_dict(json.load(f)) == spec
    with open(run_dir / "eval.json") as f:
        metrics = json.load(f)["metrics"]
    assert 0.0 <= metrics["recovery_auc"] <= 1.0
    assert {a.kind for a in arts} == {"solve", "eval"}


def test_file_network_round_trip(tmp_path):
    from repro.core.network import HeteroNetwork
    from repro.data.drugnet import DrugNetSpec, make_drugnet

    net = make_drugnet(DrugNetSpec(seed=0, **TINY)).network
    path = str(tmp_path / "net.npz")
    net.save_npz(path)
    loaded = HeteroNetwork.load_npz(path)
    assert loaded.sizes == net.sizes
    for (i, j), r in net.R.items():
        np.testing.assert_array_equal(loaded.R[(i, j)], r)
    assert tuple(loaded.type_names) == tuple(net.type_names)

    spec = RunSpec(
        network=NetworkSpec(kind="file", path=path),
        solve=SolveSpec(backend="dense", top_k=3),
    )
    art = Session(spec).solve()
    assert art.converged
    # file networks carry no truth: evaluate refuses at runtime too
    with pytest.raises(SpecError, match="ground truth"):
        Session(spec).evaluate()


# ------------------------------------------------- retired launch shims
@pytest.mark.parametrize("name", ["solve", "serve", "scenario", "bench"])
def test_launch_module_entry_points_removed(name, capsys):
    """The ``repro.launch.*`` module shims are retired: they exit 2 with
    a migration hint instead of forwarding."""
    import importlib

    mod = importlib.import_module(f"repro.launch.{name}")
    with pytest.raises(SystemExit) as exc:
        mod.main()
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert f"repro.launch.{name} has been removed" in err
    assert "repro run" in err
    assert f"repro {name}" in err


# ------------------------------------------- legacy-surface subcommands
def _run_shim(main_fn, argv):
    with pytest.warns(DeprecationWarning, match="repro run"):
        rc = main_fn(argv)
    assert rc == 0


def test_solve_shim_identical_to_spec_driver(tmp_path):
    from repro.launch.cli import solve_main

    out = str(tmp_path / "shim.npz")
    argv = [
        "--drugs", "30", "--diseases", "20", "--targets", "15",
        "--sigma", "1e-3", "--backend", "dense", "--top-k", "5",
        "--out", out,
    ]
    _run_shim(solve_main, argv)

    art = Session(tiny_spec()).solve()
    shim = np.load(out)
    np.testing.assert_array_equal(
        shim["drug_target"], art.outputs.interactions[(0, 2)]
    )
    np.testing.assert_array_equal(
        shim["sim_drug"], art.outputs.similarities[0]
    )
    # the ranking the old CLI printed == the artifact's ranking
    order = np.argsort(-shim["drug_target"][0], kind="stable")[:5]
    assert art.ranking["candidates"] == [int(x) for x in order]


def test_serve_shim_runs_and_warns(capsys):
    from repro.launch.cli import serve_main

    argv = [
        "--drugs", "30", "--diseases", "20", "--targets", "15",
        "--requests", "6", "--max-batch", "4",
    ]
    _run_shim(serve_main, argv)
    out = capsys.readouterr().out
    assert "queries" in out and "QPS" in out


def test_scenario_shim_recovery_and_agreement(capsys):
    from repro.launch.cli import scenario_main

    argv = [
        "--solve", "bipartite", "--scale", "0.25",
        "--backends", "dense,sparse",
    ]
    _run_shim(scenario_main, argv)
    out = capsys.readouterr().out
    assert "agree_vs_dense=True" in out


def test_run_driver_flags_build_valid_spec(capsys):
    from repro.launch.cli import run_main

    rc = run_main([
        "--network", "drugnet", "--param", "n_drug=30",
        "--param", "n_disease=20", "--param", "n_target=15",
        "--backend", "dense", "--top-k", "5", "--dry-run",
    ])
    assert rc == 0
    spec = RunSpec.from_json(capsys.readouterr().out)
    assert spec.network.params["n_drug"] == 30
    assert spec.sections() == ("solve",)


def test_run_driver_rejects_builder_flags_with_spec_file(tmp_path):
    from repro.launch.cli import run_main

    p = tmp_path / "s.json"
    p.write_text(tiny_spec().to_json())
    with pytest.raises(SystemExit):
        run_main([str(p), "--backend", "sparse"])
    # zero-valued flags are real values, not absent ones (0 == False trap)
    with pytest.raises(SystemExit):
        run_main([str(p), "--seed", "0"])


def test_run_driver_sub_flags_require_stage_trigger(capsys):
    from repro.launch.cli import run_main

    assert run_main(["--network", "drugnet", "--folds", "4", "--dry-run"]) == 2
    assert "--eval" in capsys.readouterr().err
    assert run_main(["--network", "drugnet", "--requests", "9", "--dry-run"]) == 2
    assert "--serve" in capsys.readouterr().err


def test_trace_serve_couples_builder_horizon():
    # scenarios that schedule their own timed deltas must schedule them
    # within THIS spec's replay horizon (else tail deltas silently never
    # apply); the session forwards serve.horizon_s into the builder
    import repro.scenarios as sc  # noqa: F401 - scenario registry import

    from repro.api import ServeSpec

    spec = RunSpec(
        network=NetworkSpec(kind="scenario", name="streaming", scale=0.4),
        solve=SolveSpec(seed_mode="fixed", backend="dense"),
        serve=ServeSpec(trace="poisson", rate_qps=25.0, horizon_s=1.5),
    )
    session = Session(spec)
    assert session.bundle.deltas, "streaming bundle must carry deltas"
    assert max(d.t for d in session.bundle.deltas) < 1.5


def test_save_npz_returns_openable_path(tmp_path):
    from repro.core.network import HeteroNetwork
    from repro.data.drugnet import DrugNetSpec, make_drugnet

    net = make_drugnet(DrugNetSpec(seed=0, **TINY)).network
    returned = net.save_npz(str(tmp_path / "bare_name"))  # no .npz suffix
    assert returned.endswith(".npz")
    assert HeteroNetwork.load_npz(returned).sizes == net.sizes


# ------------------------------------------------------ scenario disk cache
def test_scenario_disk_cache_round_trip(tmp_path, monkeypatch):
    import repro.scenarios as sc
    import repro.scenarios.base as base

    monkeypatch.setenv("REPRO_SCENARIO_CACHE_DIR", str(tmp_path))
    monkeypatch.setattr(base, "CACHE_MIN_EDGES", 0)
    first = sc.generate("bipartite", scale=0.25, seed=3)
    files = list(tmp_path.glob("bipartite-*.pkl"))
    assert len(files) == 1
    # second generation loads the pickle — identical bundle content
    second = sc.generate("bipartite", scale=0.25, seed=3)
    np.testing.assert_array_equal(
        first.network.R[(0, 1)], second.network.R[(0, 1)]
    )
    # a different seed is a different cache key
    sc.generate("bipartite", scale=0.25, seed=4)
    assert len(list(tmp_path.glob("bipartite-*.pkl"))) == 2
    # cache=False bypasses read AND write
    sc.generate("bipartite", scale=0.3, seed=3, cache=False)
    assert len(list(tmp_path.glob("bipartite-*.pkl"))) == 2


def test_scenario_cache_disabled_by_env(tmp_path, monkeypatch):
    import repro.scenarios as sc
    import repro.scenarios.base as base

    monkeypatch.setenv("REPRO_SCENARIO_CACHE_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_SCENARIO_CACHE", "0")
    monkeypatch.setattr(base, "CACHE_MIN_EDGES", 0)
    sc.generate("bipartite", scale=0.25, seed=0)
    assert not list(tmp_path.glob("*.pkl"))


def test_small_bundles_not_cached_by_default(tmp_path, monkeypatch):
    import repro.scenarios as sc

    monkeypatch.setenv("REPRO_SCENARIO_CACHE_DIR", str(tmp_path))
    sc.generate("bipartite", scale=0.25, seed=0)  # far below CACHE_MIN_EDGES
    assert not list(tmp_path.glob("*.pkl"))


# ------------------------------------------------------- bipartite scenario
def test_bipartite_scenario_registered_and_recoverable():
    import repro.scenarios as sc

    assert "bipartite" in sc.available_scenarios()
    bundle = sc.generate("bipartite", scale=0.25, seed=0)
    assert bundle.network.num_types == 2
    assert set(bundle.network.R) == {(0, 1)}
    out = sc.recovery_auc(bundle, "dense", max_entities=6)
    assert out["recovery_auc"] > 0.8


# ------------------------------------------------------ sparse_coo removal
def test_sparse_coo_backend_deleted():
    from repro.core.solver import LPConfig
    from repro.engine import UnknownBackendError, make_engine, select_backend

    with pytest.raises(UnknownBackendError):
        make_engine("sparse_coo", LPConfig(alg="dhlp2"))
    # the auto policy is unchanged by the deletion
    assert select_backend(100) == "dense"
    assert select_backend(1_000_000) == "sparse"
